//! Serving scenario: concurrent clients against the coordinator.
//!
//! Spawns several client threads firing classification requests at the
//! server (dynamic batching over the backend's batch sizes), reports
//! throughput, latency percentiles, batch occupancy and the aggregate
//! activation-bandwidth saving Zebra delivered across all requests —
//! i.e. the paper's metric measured on a *serving* workload rather
//! than a benchmark loop.
//!
//! Backend selection mirrors `zebra serve`: PJRT over AOT artifacts
//! when built with `--features pjrt` and `make artifacts` has run,
//! the pure-Rust reference backend (synthetic test set) otherwise.
//!
//! Run: `cargo run --release --example serve_classify`

use std::sync::Arc;
use std::time::{Duration, Instant};

use zebra::backend::reference::RefSpec;
use zebra::backend::{synth_images, synth_labels, testset_matches};
use zebra::coordinator::server::BatchExecutor;
use zebra::coordinator::{reference_executor, Server, ServerConfig};
use zebra::tensor::{read_zten, read_zten_i32, Tensor};

const MODEL: &str = "rn18-c10-t0.1";

fn make_executor(
    art: &std::path::Path,
) -> anyhow::Result<Arc<dyn BatchExecutor>> {
    #[cfg(feature = "pjrt")]
    if art.join("manifest.json").exists() {
        println!("using the pjrt backend over {art:?}");
        return Ok(Arc::new(zebra::coordinator::pjrt_executor(
            art.to_path_buf(),
            MODEL,
        )?));
    }
    let _ = art;
    println!("using the pure-Rust reference backend");
    Ok(Arc::new(reference_executor(RefSpec::from_key(MODEL)?)?))
}

fn main() -> anyhow::Result<()> {
    let art = zebra::artifacts_dir();
    let exec = make_executor(&art)?;
    let hw = exec.image_hw();
    println!("batch sizes: {:?}", exec.batch_sizes());
    let server = Arc::new(Server::start(
        exec,
        ServerConfig {
            max_wait: Duration::from_millis(5),
            workers: 1,
            max_queue: 512,
            max_batch: 0,
            ship_spills: None,
            spill_sink: None,
        },
    ));

    // Exported test set when present (and matching this backend's
    // resolution — a mismatched export would scramble the slicing
    // below), deterministic noise otherwise.
    let (images, labels) = match (
        read_zten(art.join("testset_images.zten")),
        read_zten_i32(art.join("testset_labels.zten")),
    ) {
        (Ok(im), Ok((_, lb)))
            if testset_matches(&im, hw) && lb.len() >= im.shape()[0] =>
        {
            (im, lb)
        }
        _ => {
            println!("(no {hw}px test set — synthetic one, accuracy is chance)");
            (synth_images(hw, 32, 0xC1A5), synth_labels(32, 10, 0xC1A5))
        }
    };
    let images = Arc::new(images);
    let labels = Arc::new(labels);
    let per = 3 * hw * hw;
    let n_avail = images.shape()[0];

    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 24;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for client in 0..CLIENTS {
        let srv = server.clone();
        let imgs = images.clone();
        let labs = labels.clone();
        handles.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            for i in 0..PER_CLIENT {
                let idx = (client * PER_CLIENT + i) % n_avail;
                let x = Tensor::from_vec(
                    &[3, hw, hw],
                    imgs.data()[idx * per..(idx + 1) * per].to_vec(),
                );
                match srv.classify(x) {
                    Ok(resp) => {
                        if resp.predicted as i32 == labs[idx] {
                            correct += 1;
                        }
                    }
                    Err(e) => eprintln!("client {client}: {e}"),
                }
            }
            correct
        }));
    }
    let correct: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();
    let total = CLIENTS * PER_CLIENT;

    println!(
        "\n{total} requests from {CLIENTS} clients in {wall:.2}s \
         ({:.1} req/s), top-1 {:.1}%",
        total as f64 / wall,
        100.0 * correct as f64 / total as f64
    );
    println!("coordinator: {}", server.metrics.summary());
    println!(
        "aggregate activation-bandwidth saving across the workload: {:.1}%",
        server.metrics.reduction_pct()
    );
    assert!(
        server.metrics.mean_batch() > 1.2,
        "dynamic batching should engage under 4-way client load"
    );
    Ok(())
}
