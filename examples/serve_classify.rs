//! Serving scenario: concurrent clients against the coordinator.
//!
//! Spawns several client threads firing classification requests at the
//! server (dynamic batching over the {1,4,8} AOT artifacts), reports
//! throughput, latency percentiles, batch occupancy and the aggregate
//! activation-bandwidth saving Zebra delivered across all requests —
//! i.e. the paper's metric measured on a *serving* workload rather
//! than a benchmark loop.
//!
//! Run: `make artifacts && cargo run --release --example serve_classify`

use std::sync::Arc;
use std::time::{Duration, Instant};

use zebra::coordinator::server::BatchExecutor;
use zebra::coordinator::{PjrtExecutor, Server, ServerConfig};
use zebra::tensor::{read_zten, read_zten_i32, Tensor};

fn main() -> anyhow::Result<()> {
    let art = zebra::artifacts_dir();
    let exec = Arc::new(PjrtExecutor::new(art.clone(), "rn18-c10-t0.1")?);
    println!("artifact batches: {:?}", exec.batch_sizes());
    let server = Arc::new(Server::start(
        exec,
        ServerConfig {
            max_wait: Duration::from_millis(5),
            workers: 1,
            max_queue: 512,
        },
    ));

    let images = Arc::new(read_zten(art.join("testset_images.zten"))?);
    let (_, labels) = read_zten_i32(art.join("testset_labels.zten"))?;
    let labels = Arc::new(labels);
    let hw = images.shape()[2];
    let per = 3 * hw * hw;
    let n_avail = images.shape()[0];

    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 24;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for client in 0..CLIENTS {
        let srv = server.clone();
        let imgs = images.clone();
        let labs = labels.clone();
        handles.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            for i in 0..PER_CLIENT {
                let idx = (client * PER_CLIENT + i) % n_avail;
                let x = Tensor::from_vec(
                    &[3, hw, hw],
                    imgs.data()[idx * per..(idx + 1) * per].to_vec(),
                );
                match srv.classify(x) {
                    Ok(resp) => {
                        if resp.predicted as i32 == labs[idx] {
                            correct += 1;
                        }
                    }
                    Err(e) => eprintln!("client {client}: {e}"),
                }
            }
            correct
        }));
    }
    let correct: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();
    let total = CLIENTS * PER_CLIENT;

    println!(
        "\n{total} requests from {CLIENTS} clients in {wall:.2}s \
         ({:.1} req/s), top-1 {:.1}%",
        total as f64 / wall,
        100.0 * correct as f64 / total as f64
    );
    println!("coordinator: {}", server.metrics.summary());
    println!(
        "aggregate activation-bandwidth saving across the workload: {:.1}%",
        server.metrics.reduction_pct()
    );
    assert!(
        server.metrics.mean_batch() > 1.2,
        "dynamic batching should engage under 4-way client load"
    );
    Ok(())
}
