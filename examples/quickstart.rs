//! Quickstart: the whole stack in ~40 lines, with zero external
//! dependencies.
//!
//! Classifies one image through the pure-Rust reference backend (a
//! deterministic spill-plan-shaped CNN with the paper's fused
//! ReLU + Zebra block-prune after every conv) and prints the paper's
//! headline quantity for that single inference: how many activation
//! bytes the accelerator would NOT have to move.
//!
//! Uses the exported test set when `make artifacts` has run; falls
//! back to a synthetic image otherwise. For the PJRT/XLA path over AOT
//! HLO artifacts, build with `--features pjrt` and run
//! `zebra serve --backend pjrt` (see rust/docs/backends.md).
//!
//! Run: `cargo run --release --example quickstart`

use zebra::backend::reference::{RefSpec, ReferenceBackend};
use zebra::backend::{synth_images, testset_matches, InferenceBackend};
use zebra::tensor::{read_zten, Tensor};
use zebra::zebra::bandwidth::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let model = ReferenceBackend::new(RefSpec::from_key("rn18-c10-t0.1")?)?;
    let hw = model.image_hw();
    let per = 3 * hw * hw;

    // One normalized test image — exported if available (and the right
    // resolution for this model), synthetic otherwise.
    let art = zebra::artifacts_dir();
    let x = match read_zten(art.join("testset_images.zten")) {
        Ok(images) if testset_matches(&images, hw) => {
            Tensor::from_vec(&[1, 3, hw, hw], images.data()[..per].to_vec())
        }
        _ => {
            println!("(no {hw}px test set — classifying a synthetic image)");
            synth_images(hw, 1, 7)
        }
    };

    // The fused serving path: every layer's conv skips the previous
    // layer's zero blocks, and each pruned spill streams straight into
    // the zero-block codec (conv -> ReLU -> prune -> encode, no dense
    // round-trip) — so the bytes below are the ACTUAL encoded spills,
    // not a mask-derived estimate.
    let mut spill_frames = Vec::new();
    let out = model.run_capture_encoded(&x, &mut spill_frames)?;
    let pred = out
        .logits
        .data()
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    println!("backend {} predicted class {pred}", model.name());

    // Eq. 2-3 accounting straight off the encoded spills.
    let (mut dense, mut stored, mut index) = (0f64, 0f64, 0f64);
    for buf in &spill_frames {
        let volume: usize = buf.shape().iter().product();
        dense += volume as f64 * 4.0;
        stored += buf.payload().len() as f64;
        index += buf.index().len() as f64;
    }
    println!(
        "activation spills: dense {} -> stored {} + index {}  ({:.1}% \
         bandwidth saved)",
        fmt_bytes(dense),
        fmt_bytes(stored),
        fmt_bytes(index),
        100.0 * (1.0 - (stored + index) / dense)
    );
    Ok(())
}
