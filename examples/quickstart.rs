//! Quickstart: the whole stack in ~40 lines.
//!
//! Loads one AOT-compiled Zebra model (ResNet-18 trained with
//! T_obj = 0.1), classifies one image from the exported test set, and
//! prints the paper's headline quantity for that single inference: how
//! many activation bytes the accelerator would NOT have to move.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use zebra::runtime::Runtime;
use zebra::tensor::{read_zten, read_zten_i32, Tensor};
use zebra::zebra::bandwidth::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let art = zebra::artifacts_dir();
    let rt = Runtime::new(&art)?;
    println!("PJRT platform: {}", rt.platform());

    // One normalized test image.
    let images = read_zten(art.join("testset_images.zten"))?;
    let (_, labels) = read_zten_i32(art.join("testset_labels.zten"))?;
    let hw = images.shape()[2];
    let per = 3 * hw * hw;
    let x = Tensor::from_vec(&[1, 3, hw, hw], images.data()[..per].to_vec());

    // The Zebra model, batch-1 artifact.
    let model = rt.model_for_batch("rn18-c10-t0.1", 1)?;
    let out = model.run(&x)?;
    let pred = out
        .logits
        .data()
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    println!("predicted class {pred} (label {})", labels[0]);

    // Eq. 2-3 accounting from the model's own mask outputs.
    let (mut dense, mut stored, mut index) = (0f64, 0f64, 0f64);
    for (m, be) in out.masks.iter().zip(&out.block_elems) {
        let blocks = m.len() as f64;
        let kept = m.data().iter().filter(|&&v| v != 0.0).count() as f64;
        dense += blocks * (*be as f64) * 4.0;
        stored += kept * (*be as f64) * 4.0;
        index += blocks / 8.0;
    }
    println!(
        "activation spills: dense {} -> stored {} + index {}  ({:.1}% \
         bandwidth saved)",
        fmt_bytes(dense),
        fmt_bytes(stored),
        fmt_bytes(index),
        100.0 * (1.0 - (stored + index) / dense)
    );
    Ok(())
}
