//! Accelerator co-design study: replay a trained model's real
//! activation traces through the transaction-level accelerator model
//! under every codec, and sweep the DRAM bandwidth to find where Zebra
//! turns memory-bound layers into compute-bound ones.
//!
//! This is the experiment a hardware architect would run with this
//! repo: "how much slower DRAM can I tolerate if activations are
//! Zebra-compressed?"
//!
//! Run: `make artifacts && cargo run --release --example accelerator_sim`

use zebra::accel::{simulate_trace, AccelConfig, LayerDesc};
use zebra::bench::Table;
use zebra::compress::{all_codecs, ZeroBlockCodec};
use zebra::tensor::Tensor;
use zebra::zebra::bandwidth::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let art = zebra::artifacts_dir();
    let tr = zebra::trace::load(art.join("traces/rn18-c10-t0.2"))?;
    println!(
        "trace: {} on {} ({} images, T_obj = {})",
        tr.model,
        tr.dataset,
        tr.batch(),
        tr.t_obj
    );
    let plan = tr.plan();
    let layers = LayerDesc::from_plan(&plan);
    let tensors: Vec<Tensor> =
        tr.spills.iter().map(|s| s.tensor.clone()).collect();
    let block = plan.iter().map(|s| s.block).max().unwrap_or(4);

    // 1. Codec comparison at the default configuration.
    let cfg = AccelConfig::default();
    let mut t = Table::new(&[
        "codec", "act traffic/img", "bus eff", "cycles", "latency ms",
        "energy uJ", "mem-bound layers",
    ]);
    for codec in all_codecs(block) {
        let r = simulate_trace(&cfg, &layers, &tensors, codec.as_ref())?;
        let membound =
            r.layers.iter().filter(|l| l.memory_bound).count();
        t.row(&[
            r.codec.clone(),
            fmt_bytes(r.activation_bytes() as f64 / tr.batch() as f64),
            format!("{:.2}", r.dram.efficiency()),
            r.total_cycles.to_string(),
            format!("{:.3}", r.latency_ms(&cfg)),
            format!("{:.1}", r.total_energy_pj / 1e6),
            format!("{membound}/{}", r.layers.len()),
        ]);
    }
    t.print("Codec comparison (default accel: 16x16 PEs @1GHz, 12.8 B/cyc DRAM)");

    // 2. DRAM bandwidth sweep: dense vs zero-block end-to-end latency.
    let zb = ZeroBlockCodec::new(block);
    let dense = zebra::compress::DenseCodec;
    let mut sweep = Table::new(&[
        "DRAM B/cyc", "dense ms", "zebra ms", "speedup",
    ]);
    for bpc in [1.6, 3.2, 6.4, 12.8, 25.6, 51.2] {
        let c = AccelConfig { dram_bytes_per_cycle: bpc, ..AccelConfig::default() };
        let rd = simulate_trace(&c, &layers, &tensors, &dense)?;
        let rz = simulate_trace(&c, &layers, &tensors, &zb)?;
        sweep.row(&[
            format!("{bpc:.1}"),
            format!("{:.3}", rd.latency_ms(&c)),
            format!("{:.3}", rz.latency_ms(&c)),
            format!(
                "{:.2}x",
                rd.total_cycles as f64 / rz.total_cycles.max(1) as f64
            ),
        ]);
    }
    sweep.print("DRAM bandwidth sweep — where activation compression buys latency");
    println!(
        "Reading: at low DRAM bandwidth every layer is memory-bound and \
         Zebra's byte savings translate ~1:1 into speedup; at high \
         bandwidth layers go compute-bound and the advantage tapers — \
         the paper's motivation inverted into a provisioning rule."
    );
    Ok(())
}
