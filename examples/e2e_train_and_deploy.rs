//! END-TO-END driver: the full system on a real small workload.
//!
//! Covers every layer of the stack in one run (EXPERIMENTS.md §E2E):
//!
//! 1. **Train** (build time, `make artifacts`): the Python pipeline
//!    trained ResNet-18 with Zebra (T_obj = 0.1) on the synthetic
//!    CIFAR-10 stand-in; this driver replays its loss curve and the
//!    learned-threshold convergence (the paper's Fig. 3 claim) from
//!    metrics.json.
//! 2. **Deploy**: the AOT HLO artifacts (Pallas-lowered kernels inside)
//!    are loaded by the PJRT runtime; the coordinator serves the whole
//!    exported test set through the dynamic batcher.
//! 3. **Measure**: top-1 accuracy, serving throughput, and the paper's
//!    headline metric — % of activation DRAM traffic eliminated — both
//!    from the serving masks and from the accelerator simulation of
//!    the traced spills, vs the no-Zebra baseline model.
//!
//! Needs trained artifacts and the PJRT runtime: build with
//! `--features pjrt` (a default build prints a pointer to
//! `zebra serve --backend reference` instead).
//!
//! Run: `make e2e` (or
//! `cargo run --release --features pjrt --example e2e_train_and_deploy`)

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "e2e_train_and_deploy exercises the PJRT runtime over AOT \
         artifacts; rebuild with `cargo run --release --features pjrt \
         --example e2e_train_and_deploy`. For the zero-dependency path, \
         try `zebra serve --backend reference` or the quickstart example."
    );
}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use zebra::accel::{simulate_trace, AccelConfig, LayerDesc};
    use zebra::bench::paper::PaperMetrics;
    use zebra::bench::Table;
    use zebra::compress::{DenseCodec, ZeroBlockCodec};
    use zebra::coordinator::{pjrt_executor, Server, ServerConfig};
    use zebra::tensor::{read_zten, read_zten_i32, Tensor};

    let art = zebra::artifacts_dir();
    println!("=== Phase 1: training evidence (from `make artifacts`) ===");
    let metrics = PaperMetrics::load(&art)?;
    let run = metrics
        .run("rn18-c10-t0.1")
        .ok_or_else(|| anyhow::anyhow!("rn18-c10-t0.1 missing — run make artifacts"))?;
    let loss = &run.loss_history;
    anyhow::ensure!(loss.len() >= 4, "loss history too short");
    let (first, last) = (loss[0], *loss.last().unwrap());
    println!(
        "loss curve ({} logged points): {:.3} -> {:.3} ({:.0}% drop)",
        loss.len(),
        first,
        last,
        100.0 * (1.0 - last / first)
    );
    sparkline("loss", loss);
    anyhow::ensure!(last < 0.7 * first, "training must reduce the loss");
    let ts = &run.mean_t_history;
    if !ts.is_empty() {
        sparkline("mean T_{l,c}", ts);
        let final_t = *ts.last().unwrap();
        println!(
            "learned thresholds converged to {:.4} (T_obj = {:.2}) — the \
             paper's Fig. 3 observation, enabling threshold-net removal at \
             inference.",
            final_t, run.t_obj
        );
        anyhow::ensure!(
            (final_t - run.t_obj).abs() < 0.05,
            "thresholds must converge to T_obj"
        );
    }

    println!("\n=== Phase 2: deploy — serve the full test set ===");
    let exec = Arc::new(pjrt_executor(art.clone(), "rn18-c10-t0.1")?);
    let server = Server::start(
        exec,
        ServerConfig {
            max_wait: Duration::from_millis(3),
            workers: 1,
            max_queue: 1024,
            ship_spills: None,
        },
    );
    let images = read_zten(art.join("testset_images.zten"))?;
    let (_, labels) = read_zten_i32(art.join("testset_labels.zten"))?;
    let hw = images.shape()[2];
    let per = 3 * hw * hw;
    let n = images.shape()[0];
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let x = Tensor::from_vec(
                &[3, hw, hw],
                images.data()[i * per..(i + 1) * per].to_vec(),
            );
            server.submit(x).unwrap()
        })
        .collect();
    let mut correct = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv()?;
        if r.predicted as i32 == labels[i] {
            correct += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let top1 = 100.0 * correct as f64 / n as f64;
    println!(
        "served {n} images in {wall:.2}s ({:.1} img/s) | top-1 {top1:.1}% \
         (python eval: {:.1}%)",
        n as f64 / wall,
        run.top1
    );
    println!("coordinator: {}", server.metrics.summary());
    let serving_reduction = server.metrics.reduction_pct();
    server.shutdown();

    println!("\n=== Phase 3: accelerator-level measurement ===");
    let mut t = Table::new(&["model", "codec", "act bytes/img", "latency ms",
                             "reduction %"]);
    let cfg = AccelConfig::default();
    let mut zebra_red = 0.0;
    for (name, trace_dir) in
        [("baseline (no Zebra)", "rn18-c10-off"), ("Zebra T=0.2", "rn18-c10-t0.2")]
    {
        let tr = zebra::trace::load(art.join("traces").join(trace_dir))?;
        let plan = tr.plan();
        let layers = LayerDesc::from_plan(&plan);
        let tensors: Vec<Tensor> =
            tr.spills.iter().map(|s| s.tensor.clone()).collect();
        let block = plan.iter().map(|s| s.block).max().unwrap_or(4);
        let dense = simulate_trace(&cfg, &layers, &tensors, &DenseCodec)?;
        let zb =
            simulate_trace(&cfg, &layers, &tensors, &ZeroBlockCodec::new(block))?;
        let red = zb.reduction_vs(&dense);
        for (codec, r) in [("dense", &dense), ("zero-block", &zb)] {
            t.row(&[
                name.into(),
                codec.into(),
                (r.activation_bytes() / tr.batch() as u64).to_string(),
                format!("{:.3}", r.latency_ms(&cfg)),
                format!("{:.1}", r.reduction_vs(&dense)),
            ]);
        }
        if trace_dir == "rn18-c10-t0.2" {
            zebra_red = red;
        }
    }
    t.print("Accelerator simulation — traced spills through the DRAM model");

    println!("=== Headline ===");
    println!(
        "Zebra eliminated {serving_reduction:.1}% of activation DRAM \
         traffic at serving time (masks) and {zebra_red:.1}% in the \
         accelerator simulation (real traced spills, burst-quantized), \
         at top-1 {top1:.1}% — the paper's Table II/III trade-off, \
         reproduced end to end: JAX+Pallas training -> HLO AOT -> Rust \
         PJRT serving -> accelerator co-simulation."
    );
    anyhow::ensure!(serving_reduction > 10.0, "Zebra must save bandwidth");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn sparkline(label: &str, v: &[f64]) {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let (lo, hi) = v.iter().fold((f64::MAX, f64::MIN), |(l, h), &x| {
        (l.min(x), h.max(x))
    });
    let s: String = v
        .iter()
        .map(|&x| {
            let t = if hi > lo { (x - lo) / (hi - lo) } else { 0.5 };
            RAMP[(t * (RAMP.len() - 1) as f64).round() as usize] as char
        })
        .collect();
    println!("  {label:>12}: [{s}]  ({lo:.3} .. {hi:.3})");
}
