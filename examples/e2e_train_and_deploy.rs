//! END-TO-END driver: the full system on a real small workload, with
//! zero Python, zero artifacts, zero native dependencies.
//!
//! Covers every layer of the stack in one run:
//!
//! 1. **Train** (`zebra::train`): two identical runs of the reference
//!    tiny CNN on a synthetic labeled dataset — one with the Zebra
//!    objective `CE + lambda * sum ||block||_2` (straight-through
//!    estimator through the block gate), one control at lambda = 0.
//! 2. **Deploy**: the Zebra run's weights are written as `w%05d.zten`
//!    leaves and served through the coordinator (continuous batch manager,
//!    per-request Eq. 2–3 accounting) on the reference backend — the
//!    same artifact path `zebra serve --backend reference --weights`
//!    uses.
//! 3. **Measure**: held-out accuracy, zero-block ratio and bandwidth
//!    reduction for both runs, plus the accelerator simulation
//!    (burst-quantized DRAM traffic) of their captured spills — the
//!    paper's headline: learned zero-block regularization cuts
//!    activation memory traffic.
//!
//! Run: `cargo run --release --example e2e_train_and_deploy`
//! (`ZEBRA_E2E_STEPS=N` overrides the training budget.)

use std::sync::Arc;
use std::time::{Duration, Instant};

use zebra::accel::{simulate_trace, AccelConfig, LayerDesc};
use zebra::backend::reference::ReferenceBackend;
use zebra::bench::Table;
use zebra::compress::{DenseCodec, ZeroBlockCodec};
use zebra::coordinator::{
    reference_executor, Server, ServerConfig, SubmitOutcome, SubmitRequest,
};
use zebra::tensor::Tensor;
use zebra::train::{train_on, Dataset, TrainConfig};

fn main() -> anyhow::Result<()> {
    let steps = std::env::var("ZEBRA_E2E_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    let base = TrainConfig {
        model: "ref-tiny".into(),
        lambda: 2e-3,
        steps,
        batch: 16,
        seed: 7,
        quiet: true,
        ..TrainConfig::default()
    };

    println!("=== Phase 1: train (pure Rust, Zebra objective) ===");
    let ds = Dataset::synthetic(8, 10, 320, base.seed);
    let (train_ds, holdout) = ds.split(64);
    let t0 = Instant::now();
    let zebra_run = train_on(&base, &train_ds, &holdout)?;
    let control = train_on(
        &TrainConfig { lambda: 0.0, ..base.clone() },
        &train_ds,
        &holdout,
    )?;
    println!(
        "two {steps}-step runs (lambda {} vs 0) in {:.1}s",
        base.lambda,
        t0.elapsed().as_secs_f64()
    );
    for (label, run) in [("zebra", &zebra_run), ("control", &control)] {
        let hist: Vec<f64> =
            run.loss_history.iter().map(|&v| v as f64).collect();
        sparkline(&format!("{label} loss"), &hist);
        let (first, last) = (hist[0], *hist.last().unwrap());
        anyhow::ensure!(last < first, "{label}: training must reduce loss");
    }

    println!("\n=== Phase 2: deploy — .zten artifact into the coordinator ===");
    let dir = std::env::temp_dir()
        .join(format!("zebra-e2e-{}", std::process::id()));
    zebra_run.write_leaves(&dir)?;
    println!("checkpointed {} leaves to {dir:?}", zebra_run.params.conv_w.len() + 1);
    let mut spec = zebra_run.spec.clone();
    spec.weights_dir = Some(dir.clone());
    let exec = Arc::new(reference_executor(spec)?);
    let server = Server::start(
        exec,
        ServerConfig {
            max_wait: Duration::from_millis(2),
            workers: 1,
            max_queue: 1024,
            max_batch: 0,
            ship_spills: None,
            spill_sink: None,
        },
    );
    let hw = 8usize;
    let per = 3 * hw * hw;
    let n = holdout.len();
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let x = Tensor::from_vec(
                &[3, hw, hw],
                holdout.images.data()[i * per..(i + 1) * per].to_vec(),
            );
            let (tx, rx) = std::sync::mpsc::channel();
            match server.submit(SubmitRequest::new(x), tx) {
                SubmitOutcome::Enqueued { .. } => rx,
                other => panic!("expected admission, got {other:?}"),
            }
        })
        .collect();
    let mut correct = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv()?;
        if r.predicted as i32 == holdout.labels[i] {
            correct += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let top1 = 100.0 * correct as f64 / n as f64;
    println!(
        "served {n} held-out images in {wall:.2}s ({:.0} img/s) | \
         top-1 {top1:.1}% (chance would be 10%)",
        n as f64 / wall
    );
    println!("coordinator: {}", server.metrics.summary());
    let serving_reduction = server.metrics.reduction_pct();
    server.shutdown();
    // The artifact has served its purpose; clean up before the
    // assertions below so a failing run does not leak temp dirs.
    std::fs::remove_dir_all(&dir).ok();

    println!("\n=== Phase 3: accelerator-level measurement, lambda vs 0 ===");
    let mut t = Table::new(&[
        "run", "codec", "act bytes/img", "latency ms", "reduction %",
    ]);
    let cfg = AccelConfig::default();
    let probe = Tensor::from_vec(
        &[8, 3, hw, hw],
        holdout.images.data()[..8 * per].to_vec(),
    );
    for (name, run) in
        [("Zebra lambda=2e-3", &zebra_run), ("control lambda=0", &control)]
    {
        let be = ReferenceBackend::from_params(
            run.spec.clone(),
            run.params.clone(),
        )?;
        let (_, spills) = be.run_capture(&probe)?;
        let layers = LayerDesc::from_plan(&be.spec().spills);
        let block = be.spec().spills.iter().map(|s| s.block).min().unwrap();
        let dense = simulate_trace(&cfg, &layers, &spills, &DenseCodec)?;
        let zb = simulate_trace(
            &cfg,
            &layers,
            &spills,
            &ZeroBlockCodec::new(block),
        )?;
        for (codec, r) in [("dense", &dense), ("zero-block", &zb)] {
            t.row(&[
                name.into(),
                codec.into(),
                (r.activation_bytes() / 8).to_string(),
                format!("{:.3}", r.latency_ms(&cfg)),
                format!("{:.1}", r.reduction_vs(&dense)),
            ]);
        }
    }
    t.print("Accelerator simulation — trained spills through the DRAM model");

    let (z, c) = (zebra_run.final_stat(), control.final_stat());
    println!("=== Headline ===");
    println!(
        "Zero-block regularization raised the pruned-block ratio from \
         {:.1}% (lambda=0) to {:.1}% and the Eq. 2-3 bandwidth reduction \
         from {:.1}% to {:.1}%, at held-out top-1 {:.1}% vs {:.1}% — the \
         paper's accuracy/bandwidth trade-off, reproduced with training, \
         artifact export, serving and accelerator co-simulation all in \
         one Rust binary.",
        c.zero_block_pct,
        z.zero_block_pct,
        c.reduced_pct,
        z.reduced_pct,
        100.0 * z.holdout_acc,
        100.0 * c.holdout_acc,
    );
    anyhow::ensure!(
        z.zero_block_pct > c.zero_block_pct,
        "the regularizer must raise the zero-block ratio"
    );
    anyhow::ensure!(serving_reduction > 0.0, "Zebra must save bandwidth");
    Ok(())
}

fn sparkline(label: &str, v: &[f64]) {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let (lo, hi) = v
        .iter()
        .fold((f64::MAX, f64::MIN), |(l, h), &x| (l.min(x), h.max(x)));
    // Downsample to at most 64 columns so long runs stay readable.
    let cols = v.len().min(64);
    let s: String = (0..cols)
        .map(|i| {
            let x = v[i * v.len() / cols];
            let t = if hi > lo { (x - lo) / (hi - lo) } else { 0.5 };
            RAMP[(t * (RAMP.len() - 1) as f64).round() as usize] as char
        })
        .collect();
    println!("  {label:>12}: [{s}]  ({lo:.3} .. {hi:.3})");
}
