# Repo-level convenience targets.

.PHONY: check
check:
	./rust/check.sh
