# Repo-level convenience targets.

.PHONY: check ci bench-smoke

# Full gate: build + tests + fmt + clippy in both feature configs
# (the pjrt config auto-skips when no XLA toolchain is present).
check:
	./rust/check.sh

# Everything the CI workflow runs: the gate plus the bench smoke pass.
ci: check bench-smoke

# Run every table*/fig* bench regenerator in fast smoke mode:
# ZEBRA_BENCH_SMOKE=1 caps measuring budgets at ~1 ms and lets
# artifact-dependent benches skip cleanly, so the whole suite finishes
# in seconds and CI catches bench bit-rot without trained artifacts.
bench-smoke:
	cd rust && ZEBRA_BENCH_SMOKE=1 cargo bench --no-default-features
