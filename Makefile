# Repo-level convenience targets.

.PHONY: check ci bench-smoke train-smoke cluster-smoke loadgen-smoke \
	perf-smoke simulate-smoke obs-smoke chaos-smoke

# Full gate: build + tests + fmt + clippy in both feature configs
# (the pjrt config auto-skips when no XLA toolchain is present),
# closed by the train smoke below.
check:
	./rust/check.sh

# Everything the CI workflow runs: the gate (train smoke included)
# plus the bench smoke pass.
ci: check bench-smoke

# Run every table*/fig* bench regenerator in fast smoke mode:
# ZEBRA_BENCH_SMOKE=1 caps measuring budgets at ~1 ms and lets
# artifact-dependent benches skip cleanly, so the whole suite finishes
# in seconds and CI catches bench bit-rot without trained artifacts.
bench-smoke:
	cd rust && ZEBRA_BENCH_SMOKE=1 cargo bench --no-default-features

# Few-step synthetic `zebra train` + artifact reload on the reference
# backend: proves the train -> .zten -> serve loop end to end in
# seconds. ZEBRA_BENCH_SMOKE=1 caps the training budget the same way
# it caps bench measuring time. This recipe is the single source of
# truth — rust/check.sh invokes this target rather than duplicating it.
# Loopback cluster smoke: 2 cluster-workers + a cluster-router (all
# on ephemeral ports, addresses harvested from their "listening on"
# lines) driven by `zebra loadgen --fail-on-error`. Proves the
# multi-node serving path — sharding, wire protocol, metrics
# aggregation — end to end in seconds. rust/check.sh invokes this
# target rather than duplicating the recipe.
cluster-smoke:
	cd rust && ./cluster_smoke.sh

# Admission-control smoke: one worker behind a router with a tiny
# outstanding budget, flooded by mixed-priority loadgen connections.
# Passes only with nonzero sheds, zero faults, and loadgen's built-in
# ok+shed+failed == submitted conservation check (no silent drops).
# rust/check.sh and ci.yml invoke this target rather than duplicating
# the recipe.
loadgen-smoke:
	cd rust && ./loadgen_smoke.sh

# Observability smoke: loopback cluster with tracing sampled 1-in-4
# at the loadgen edge, a forced-shed admission budget, and flight
# recorders on both nodes. Gates the flight dump (valid JSON-lines,
# rendered by `zebra obs replay`), the unified `zebra obs` scrape
# (Prometheus + --json), and the BENCH_PR8.json emission. rust/check.sh
# and ci.yml invoke this target rather than duplicating the recipe.
obs-smoke:
	cd rust && ./obs_smoke.sh

# Chaos + self-healing smoke: a seeded fault plan (wire drops +
# corruption at the router, one worker crashing mid-load) against the
# breaker/redial/request-timeout machinery. Passes only when loadgen's
# conservation check holds under chaos, the breaker's full
# Open -> Half-Open -> Closed cycle lands in the flight dump, and the
# breaker/brownout families export on the live scrape. rust/check.sh
# and ci.yml invoke this target rather than duplicating the recipe.
chaos-smoke:
	cd rust && ./chaos_smoke.sh

# Block-sparse kernel never-regress gate: run the perf_hotpath bench
# in smoke mode with the guard armed — the masked conv must be faster
# than the dense kernel at 70% zero blocks (coarse, smoke-sized
# shapes; emits BENCH_PR5.json at the repo root). rust/check.sh and
# ci.yml invoke this target rather than duplicating the recipe.
perf-smoke:
	cd rust && ZEBRA_BENCH_SMOKE=1 ZEBRA_PERF_GUARD=1 \
		cargo bench --bench perf_hotpath --no-default-features

# Target-manifest smoke: resolve a committed .target file from disk
# for one simulation, then sweep every builtin hardware profile with
# `zebra targets` (--json exercises the machine-readable path).
# ref-tiny + 2 synthetic images keeps it to seconds. rust/check.sh
# and ci.yml invoke this target rather than duplicating the recipe.
simulate-smoke:
	cd rust && ZEBRA_BENCH_SMOKE=1 cargo run --release \
		--no-default-features -- \
		simulate --backend reference --model ref-tiny --images 2 \
		--target targets/edge-npu.target \
	&& ZEBRA_BENCH_SMOKE=1 cargo run --release \
		--no-default-features -- \
		targets --backend reference --model ref-tiny --images 2 --json

train-smoke:
	cd rust && tmp=$$(mktemp -d) && \
	( ZEBRA_BENCH_SMOKE=1 cargo run --release --no-default-features -- \
	    train --model ref-tiny --lambda 0.001 --steps 25 \
	    --out "$$tmp/leaves" \
	  && cargo run --release --no-default-features -- \
	    serve --backend reference --model ref-tiny \
	    --weights "$$tmp/leaves" --requests 8 --seed 7 ); \
	rc=$$?; rm -rf "$$tmp"; exit $$rc
