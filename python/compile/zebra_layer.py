"""The Zebra layer (paper Sec. II) in its two modes.

Training mode (Fig. 2): a tiny threshold network — GAP over the incoming
activation map followed by one FC layer and a sigmoid — produces a
per-(sample, channel) threshold ``T_{l,c} in [0, 1]``. Blocks whose max
is below the threshold are zeroed through the fused L1 ``relu_zebra``
kernel. The hard mask uses a straight-through estimator on the
activations; the threshold net receives gradient ONLY from the Eq. 1
regularizer ``||T_obj - T_{l,c}||^2`` (the kernel's VJP returns zero
cotangent for the threshold input), which is exactly why the learned
thresholds converge to ``T_obj`` (Fig. 3).

Inference mode (Fig. 3): the threshold net is deleted and the scalar
``T_obj`` is used directly — zero parameters, one max per element of
run-time overhead (Eq. 5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .kernels import ref as zref
from .kernels import zebra as zk

# Which implementation executes the block-prune op:
#   "pallas" — the L1 kernel (AOT export + equivalence tests);
#   "jnp"    — the vectorized oracle from kernels/ref.py. Identical math
#              (tests assert it), including the straight-through gradient:
#              comparisons have zero cotangent in JAX, so `x * mask(x)`
#              backpropagates exactly the kept-block mask. The training
#              grid uses this path because interpret-mode pallas inside
#              jit lowers to a sequential XLA loop over the grid
#              (DESIGN.md §7).
def _prune(x, t, block, backend: str, relu: bool):
    if backend == "pallas":
        fn = zk.relu_zebra if relu else zk.zebra_prune
        return fn(x, t, block)
    if backend == "jnp":
        fn = zref.relu_zebra_ref if relu else zref.zebra_prune_ref
        return fn(x, t, block)
    raise ValueError(f"unknown zebra backend {backend!r}")


def init_threshold_net(key, c: int, t_obj: float) -> dict:
    """Threshold net params: FC (C -> C) + bias.

    The bias starts at ``logit(T_obj)`` and the weight at ~0 so the layer
    begins with T ~= T_obj: training starts from the regularizer's fixed
    point instead of fighting it.
    """
    t = min(max(t_obj, 1e-3), 1 - 1e-3)
    logit = float(jnp.log(t / (1 - t)))
    w = jax.random.normal(key, (c, c)) * 0.01
    return {"w": w, "b": jnp.full((c,), logit)}


def thresholds(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """(N, C, H, W) -> per-(sample, channel) thresholds in [0, 1]."""
    pooled = layers.gap(x)  # (N, C)
    return jax.nn.sigmoid(pooled @ params["w"] + params["b"])


def apply_train(params: dict, x: jnp.ndarray, block: int,
                backend: str = "pallas"):
    """Training mode: fused ReLU+prune with learned thresholds.

    Returns (pruned, mask, t) where ``t`` feeds the Eq. 1 regularizer.
    """
    t = thresholds(params, x)
    # stop_gradient is belt-and-braces: the kernel VJP already returns a
    # zero cotangent for the threshold operand.
    pruned, mask = _prune(x, jax.lax.stop_gradient(t), block, backend,
                          relu=True)
    return pruned, mask, t


def apply_infer(x: jnp.ndarray, t_obj: float, block: int,
                backend: str = "pallas"):
    """Inference mode: fixed scalar threshold, no parameters (Fig. 3)."""
    pruned, mask = _prune(x, jnp.float32(t_obj), block, backend, relu=True)
    return pruned, mask


def regularizer(ts: list[jnp.ndarray], t_obj: float) -> jnp.ndarray:
    """Eq. 1's second term: sum_{l,c} ||T_obj - T_{l,c}||^2.

    ``ts`` carries one (N, C) array per Zebra layer; the sum over the
    batch dimension is averaged so the term is batch-size invariant.
    """
    if not ts:
        return jnp.float32(0.0)
    return sum(jnp.mean(jnp.sum((t_obj - t) ** 2, axis=1)) for t in ts)
