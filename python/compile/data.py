"""Synthetic vision datasets standing in for CIFAR-10 / Tiny-ImageNet.

This image has no network access and neither dataset on disk, so we
substitute a procedural dataset that preserves the property Zebra
exploits (DESIGN.md §7): images have an explicit foreground /
background split — class-defining geometric foregrounds composited on
low-information, weakly-textured backgrounds — so "learn that background
blocks are prunable" is exactly the signal available, as in the paper's
Fig. 4 visualizations.

Classes are combinations of shape x texture:
  shape   in {disk, square, triangle, ring, cross}
  texture in {solid, stripes, checker, gradient}  (as many as needed)

``synth_cifar``  : 32x32, 10 classes  (CIFAR-10 stand-in)
``synth_tiny``   : 64x64, 20 classes  (Tiny-ImageNet stand-in; the real
                   one has 200 classes — 20 keeps CPU training sane while
                   preserving the higher-resolution / more-classes
                   relationship to the 32x32 set)

Everything is generated with numpy from an integer seed: deterministic,
no files. Images are float32, channel-normalized roughly to zero mean /
unit variance like the standard CIFAR pipeline.
"""

from __future__ import annotations

import numpy as np

SHAPES = ("disk", "square", "triangle", "ring", "cross")
TEXTURES = ("solid", "stripes", "checker", "gradient")


def _shape_mask(shape: str, hw: int, cx, cy, r, rng) -> np.ndarray:
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float32)
    dx, dy = xx - cx, yy - cy
    if shape == "disk":
        return (dx**2 + dy**2) <= r**2
    if shape == "square":
        return (np.abs(dx) <= r) & (np.abs(dy) <= r)
    if shape == "triangle":
        return (dy >= -r) & (dy + 2 * np.abs(dx) <= r)
    if shape == "ring":
        d2 = dx**2 + dy**2
        return (d2 <= r**2) & (d2 >= (0.55 * r) ** 2)
    if shape == "cross":
        t = max(1.0, r * 0.45)
        return ((np.abs(dx) <= t) & (np.abs(dy) <= r)) | (
            (np.abs(dy) <= t) & (np.abs(dx) <= r)
        )
    raise ValueError(shape)


def _texture(tex: str, hw: int, base: np.ndarray, rng) -> np.ndarray:
    """Per-class foreground coloring, (3, H, W)."""
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float32)
    if tex == "solid":
        mod = np.ones((hw, hw), np.float32)
    elif tex == "stripes":
        mod = 0.55 + 0.45 * np.sign(np.sin(xx * np.pi / 2.5))
    elif tex == "checker":
        mod = 0.55 + 0.45 * np.sign(
            np.sin(xx * np.pi / 3) * np.sin(yy * np.pi / 3)
        )
    elif tex == "gradient":
        mod = 0.3 + 0.7 * (xx + yy) / (2 * hw)
    else:
        raise ValueError(tex)
    return base[:, None, None] * mod[None]


def _render(label: int, hw: int, rng: np.random.Generator) -> np.ndarray:
    """One (3, hw, hw) float image in [0, 1]."""
    shape = SHAPES[label % len(SHAPES)]
    tex = TEXTURES[(label // len(SHAPES)) % len(TEXTURES)]
    # Low-information background: dim solid color + faint noise.
    bg = rng.uniform(0.05, 0.25, size=3).astype(np.float32)
    img = np.broadcast_to(bg[:, None, None], (3, hw, hw)).copy()
    img += rng.normal(0, 0.02, size=img.shape).astype(np.float32)
    # Foreground: bright class shape, randomly placed/scaled/colored hue.
    r = rng.uniform(0.16, 0.3) * hw
    cx = rng.uniform(0.3 * hw, 0.7 * hw)
    cy = rng.uniform(0.3 * hw, 0.7 * hw)
    mask = _shape_mask(shape, hw, cx, cy, r, rng)
    base = rng.uniform(0.6, 1.0, size=3).astype(np.float32)
    fg = _texture(tex, hw, base, rng)
    img = np.where(mask[None], fg, img)
    # A couple of small distractors so background is not trivially flat.
    for _ in range(rng.integers(0, 3)):
        dr = rng.uniform(0.03, 0.07) * hw
        dx = rng.uniform(0, hw)
        dy = rng.uniform(0, hw)
        dmask = _shape_mask("disk", hw, dx, dy, dr, rng)
        img = np.where(
            dmask[None],
            rng.uniform(0.2, 0.45, size=3).astype(np.float32)[:, None, None],
            img,
        )
    return np.clip(img, 0.0, 1.0)


# Channel statistics of the generator (fixed constants so train/test and
# python/rust all normalize identically).
MEAN = np.array([0.32, 0.32, 0.32], np.float32)
STD = np.array([0.27, 0.27, 0.27], np.float32)


def normalize(img: np.ndarray) -> np.ndarray:
    return (img - MEAN[:, None, None]) / STD[:, None, None]


def make_split(
    n: int, hw: int, num_classes: int, seed: int
) -> tuple[np.ndarray, np.ndarray]:
    """Generate a dataset split: (images (N,3,hw,hw) f32, labels (N,) i32).

    Labels cycle deterministically so every class is equally represented.
    """
    rng = np.random.default_rng(seed)
    xs = np.empty((n, 3, hw, hw), np.float32)
    ys = np.empty((n,), np.int32)
    for i in range(n):
        label = i % num_classes
        xs[i] = normalize(_render(label, hw, rng))
        ys[i] = label
    perm = rng.permutation(n)
    return xs[perm], ys[perm]


def synth_cifar(n_train: int = 2000, n_test: int = 512, seed: int = 7):
    """32x32 / 10-class CIFAR-10 stand-in."""
    tr = make_split(n_train, 32, 10, seed)
    te = make_split(n_test, 32, 10, seed + 1)
    return tr, te


def synth_tiny(n_train: int = 2000, n_test: int = 512, seed: int = 17):
    """64x64 / 20-class Tiny-ImageNet stand-in."""
    tr = make_split(n_train, 64, 20, seed)
    te = make_split(n_test, 64, 20, seed + 1)
    return tr, te


DATASETS = {
    "cifar10": {"hw": 32, "classes": 10, "make": synth_cifar, "block": 4},
    "tiny": {"hw": 64, "classes": 20, "make": synth_tiny, "block": 8},
}
