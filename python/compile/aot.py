"""AOT export: JAX inference graphs -> HLO text for the Rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 (behind the published ``xla`` 0.1.6 crate) rejects;
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py.

Exports use the **pallas** backends (conv-as-GEMM through the L1 matmul
kernel, the fused relu+zebra kernel): interpret-mode pallas lowers to
plain HLO, so the artifact the Rust coordinator executes contains the
Pallas lowering of the paper's op on its hot path.

Each exported model returns ``(logits, mask_0, ..., mask_{K-1})`` — the
per-Zebra-layer {0,1} block masks ride along so the coordinator can do
per-request bandwidth accounting without re-deriving blocks.

**Weights are parameters, not constants.** HLO *text* elides large
constant tensors (``{ ... }``), so baking trained weights into the
graph silently corrupts them across the text round-trip. Models are
therefore lowered as ``fwd(w_0, ..., w_{P-1}, x)`` with every parameter
leaf an explicit argument; the leaves are written (in
``jax.tree_util.tree_flatten`` order) to ``weights_<key>/w*.zten`` and
the Rust runtime uploads them once as device-resident PJRT buffers.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import models, trace
from .kernels import zebra as zk


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_weights(params: dict, outdir: str) -> int:
    """Write every parameter leaf (tree_flatten order) as w%05d.zten.

    Returns the leaf count. The order is the exported HLO's argument
    order, so the Rust runtime feeds buffers by index.
    """
    leaves, _ = jax.tree_util.tree_flatten(params)
    os.makedirs(outdir, exist_ok=True)
    import numpy as np

    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf, np.float32)
        if arr.ndim == 0:
            arr = arr.reshape(1)
        trace.write_zten(os.path.join(outdir, f"w{i:05d}.zten"), arr)
    return len(leaves)


def export_model(
    params: dict,
    spec: list[dict],
    *,
    batch: int,
    hw: int,
    t_obj: float,
    default_block: int,
    zebra: bool,
    out_path: str,
    weights_dir: str | None = None,
    backend: str = "pallas",
) -> dict:
    """Lower one inference configuration to HLO text.

    Returns manifest metadata: input shape, #outputs, spill plan of the
    mask outputs, and the weights directory (see module docstring for
    why weights travel out-of-band).
    """
    mode = "infer" if zebra else "off"
    leaves, treedef = jax.tree_util.tree_flatten(params)

    def fwd(*args):
        flat, x = list(args[:-1]), args[-1]
        p = jax.tree_util.tree_unflatten(treedef, flat)
        logits, _, aux = models.apply(
            p, spec, x, train=False, zebra_mode=mode, t_obj=t_obj,
            default_block=default_block, backend=backend,
            zebra_backend=backend if backend == "pallas" else "jnp")
        return (logits, *aux["masks"])

    w_specs = [jax.ShapeDtypeStruct(l.shape, jnp.float32) for l in leaves]
    x_spec = jax.ShapeDtypeStruct((batch, 3, hw, hw), jnp.float32)
    # keep_unused: inference drops the threshold nets, but the
    # weight files are indexed by flattened position — keep the
    # argument list aligned.
    lowered = jax.jit(fwd, keep_unused=True).lower(*w_specs, x_spec)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    n_weights = len(leaves)
    if weights_dir is not None:
        n_weights = export_weights(params, weights_dir)
    plan = models.spill_plan(spec, hw, default_block)
    return {
        "path": out_path.split("/")[-1],
        "batch": batch,
        "input": [batch, 3, hw, hw],
        "zebra": zebra,
        "t_obj": t_obj,
        "n_outputs": 1 + (len(plan) if zebra else 0),
        "n_weights": n_weights,
        "weights_dir": (weights_dir or "").split("/")[-1],
        "masks": [
            {"name": s.name, "c": s.c, "h": s.h // s.block,
             "w": s.w // s.block, "block": s.block}
            for s in plan
        ] if zebra else [],
    }


def export_zebra_kernel(
    out_path: str, shape=(1, 16, 32, 32), block: int = 4, t_obj: float = 0.1
) -> dict:
    """Standalone fused relu+zebra kernel HLO — the runtime microbench
    target (perf_hotpath bench, EXPERIMENTS.md §Perf)."""

    def fn(x):
        pruned, mask = zk.relu_zebra(x, jnp.float32(t_obj), block)
        return (pruned, mask)

    x_spec = jax.ShapeDtypeStruct(shape, jnp.float32)
    lowered = jax.jit(fn).lower(x_spec)
    with open(out_path, "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "path": out_path.split("/")[-1],
        "input": list(shape),
        "block": block,
        "t_obj": t_obj,
        "n_outputs": 2,
    }
