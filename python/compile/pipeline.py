"""The artifact pipeline: trains the paper's experiment grid, exports
AOT HLO models, dumps activation traces, and writes the manifests the
Rust side consumes.

Run via ``make artifacts`` (``python -m compile.pipeline``). Incremental:
results are flushed to ``artifacts/metrics.json`` after every run, and
finished runs are skipped on re-entry, so a partial grid is still usable
by the Rust benches (they report whatever is present).

Every experiment row of the paper's Tables II/III/IV lives in
``EXPERIMENTS`` with the paper's reported numbers attached; the Rust
bench binaries print paper-vs-measured side by side from this file
(DESIGN.md §4).

Budget: this image has ONE CPU. The default ("small") budget uses
width-scaled models and hundreds of SGD steps — enough for the *shape*
of every table (ordering, rough factors); ``--full 1`` raises widths and
steps for closer numbers (DESIGN.md §7).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from . import aot, data, models, trace
from .kernels import ref as kref
from .train import TrainConfig, train

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


# ------------------------------------------------------------------ grid
#
# Experiment key -> (overrides, paper numbers). "bw" = paper's reduced
# bandwidth %, "acc" = paper top-1 (CIFAR) or (top1, top5) (Tiny).

def _e(arch, ds, t, ns=0.0, wp=0.0, zebra=True, **paper):
    return {
        "arch": arch, "dataset": ds, "t_obj": t, "ns_ratio": ns,
        "wp_ratio": wp, "zebra": zebra, "paper": paper,
    }


EXPERIMENTS: dict[str, dict] = {
    # ---------------- Table II: CIFAR-10 ----------------
    "vgg16-c10-t0":        _e("vgg16", "cifar10", 0.0, bw=16.7, acc=92.58),
    "vgg16-c10-t0.05":     _e("vgg16", "cifar10", 0.05, bw=36.4, acc=92.35),
    "vgg16-c10-t0.05-ns50": _e("vgg16", "cifar10", 0.05, ns=0.5,
                               bw=51.4, acc=92.40),
    "vgg16-c10-t0.05-ns20": _e("vgg16", "cifar10", 0.05, ns=0.2,
                               bw=41.1, acc=92.69),
    "vgg16-c10-t0.05-wp20": _e("vgg16", "cifar10", 0.05, wp=0.2,
                               bw=42.3, acc=93.27),
    "vgg16-c10-t0.1":      _e("vgg16", "cifar10", 0.1, bw=45.0, acc=92.15),
    "vgg16-c10-t0.1-ns50": _e("vgg16", "cifar10", 0.1, ns=0.5,
                              bw=73.8, acc=89.20),
    "vgg16-c10-t0.1-ns20": _e("vgg16", "cifar10", 0.1, ns=0.2,
                              bw=71.1, acc=87.81),
    "vgg16-c10-t0.1-wp20": _e("vgg16", "cifar10", 0.1, wp=0.2,
                              bw=73.7, acc=90.65),
    "vgg16-c10-t0.15":     _e("vgg16", "cifar10", 0.15, bw=54.3, acc=91.72),
    "rn18-c10-t0":         _e("resnet18", "cifar10", 0.0, bw=2.8, acc=91.33),
    "rn18-c10-t0.1":       _e("resnet18", "cifar10", 0.1, bw=33.5,
                              acc=90.41),
    "rn18-c10-t0.2":       _e("resnet18", "cifar10", 0.2, bw=40.5,
                              acc=89.76),
    "rn18-c10-t0.2-ns20":  _e("resnet18", "cifar10", 0.2, ns=0.2,
                              bw=41.4, acc=91.55),
    "rn18-c10-t0.2-wp20":  _e("resnet18", "cifar10", 0.2, wp=0.2,
                              bw=49.2, acc=88.62),
    "rn56-c10-t0":         _e("resnet56", "cifar10", 0.0, bw=7.8, acc=92.27),
    "rn56-c10-t0.05":      _e("resnet56", "cifar10", 0.05, bw=31.8,
                              acc=93.22),
    "rn56-c10-t0.15":      _e("resnet56", "cifar10", 0.15, bw=46.4,
                              acc=91.33),
    "mobile-c10-t0":       _e("mobilenet", "cifar10", 0.0, bw=14.4,
                              acc=90.66),
    "mobile-c10-t0.1":     _e("mobilenet", "cifar10", 0.1, bw=35.6,
                              acc=90.00),
    "mobile-c10-t0.15":    _e("mobilenet", "cifar10", 0.15, bw=78.8,
                              acc=87.92),
    # ---------------- Table III: Tiny-ImageNet ----------------
    "rn18-tiny-t0":        _e("resnet18", "tiny", 0.0, bw=3.0,
                              acc=(55.18, 77.56)),
    "rn18-tiny-t0.1":      _e("resnet18", "tiny", 0.1, bw=15.9,
                              acc=(61.46, 82.50)),
    "rn18-tiny-t0.15":     _e("resnet18", "tiny", 0.15, bw=33.9,
                              acc=(57.00, 79.64)),
    "rn18-tiny-t0.2":      _e("resnet18", "tiny", 0.2, bw=47.2,
                              acc=(56.50, 78.92)),
    "rn18-tiny-t0.2-ns40": _e("resnet18", "tiny", 0.2, ns=0.4,
                              bw=69.7, acc=(58.36, 79.36)),
    "rn18-tiny-t0.2-ns20": _e("resnet18", "tiny", 0.2, ns=0.2,
                              bw=44.5, acc=(60.30, 82.58)),
    "rn18-tiny-t0.2-wp40": _e("resnet18", "tiny", 0.2, wp=0.4,
                              bw=41.8, acc=(59.64, 81.24)),
    "rn18-tiny-t0.2-wp20": _e("resnet18", "tiny", 0.2, wp=0.2,
                              bw=42.8, acc=(58.66, 80.78)),
    "rn18-tiny-t0.4":      _e("resnet18", "tiny", 0.4, bw=69.5,
                              acc=(54.20, 76.70)),
    # ---------------- Table IV extras (ablation) ----------------
    "vgg16-c10-ns20-only": _e("vgg16", "cifar10", 0.0, ns=0.2, zebra=False,
                              bw=21.9, acc=92.84),
    "vgg16-c10-ns50-only": _e("vgg16", "cifar10", 0.0, ns=0.5, zebra=False,
                              bw=58.5, acc=90.15),
    "rn18-c10-ns20-only":  _e("resnet18", "cifar10", 0.0, ns=0.2,
                              zebra=False, bw=22.5, acc=90.75),
    "rn18-c10-ns40-only":  _e("resnet18", "cifar10", 0.0, ns=0.4,
                              zebra=False, bw=29.8, acc=89.42),
    "rn18-c10-t0.1-ns20":  _e("resnet18", "cifar10", 0.1, ns=0.2,
                              bw=41.4, acc=90.96),
    "rn18-c10-t0.2-ns40":  _e("resnet18", "cifar10", 0.2, ns=0.4,
                              bw=50.4, acc=89.55),
    # ---------------- substrate runs (not a paper row) ----------------
    "rn18-c10-off":        _e("resnet18", "cifar10", 0.0, zebra=False),
    "rn18-tiny-off":       _e("resnet18", "tiny", 0.0, zebra=False),
}

# Table name -> list of (row label, experiment key). The Rust benches
# join these with metrics.json to print paper-vs-measured tables.
TABLES = {
    "table2": [
        (k.replace("-c10", ""), k) for k in EXPERIMENTS
        if "-c10" in k and "only" not in k and "off" not in k
        and k not in ("rn18-c10-t0.1-ns20", "rn18-c10-t0.2-ns40")
    ],
    "table3": [(k, k) for k in EXPERIMENTS if "-tiny-" in k
               and "off" not in k],
    "table4": [
        ("vgg16 NS(20)", "vgg16-c10-ns20-only"),
        ("vgg16 Zebra(0.05)", "vgg16-c10-t0.05"),
        ("vgg16 Zebra+NS(20)", "vgg16-c10-t0.05-ns20"),
        ("vgg16 NS(50)", "vgg16-c10-ns50-only"),
        ("vgg16 Zebra(0.1)", "vgg16-c10-t0.1"),
        ("vgg16 Zebra+NS(50)", "vgg16-c10-t0.1-ns50"),
        ("rn18 NS(20)", "rn18-c10-ns20-only"),
        ("rn18 Zebra(0.1)", "rn18-c10-t0.1"),
        ("rn18 Zebra+NS(20)", "rn18-c10-t0.1-ns20"),
        ("rn18 NS(40)", "rn18-c10-ns40-only"),
        ("rn18 Zebra(0.2)", "rn18-c10-t0.2"),
        ("rn18 Zebra+NS(40)", "rn18-c10-t0.2-ns40"),
    ],
}

# Paper Table IV reference rows (bw, acc) keyed by row label above.
TABLE4_PAPER = {
    "vgg16 NS(20)": (21.9, 92.84), "vgg16 Zebra(0.05)": (40.2, 92.8),
    "vgg16 Zebra+NS(20)": (48.5, 92.89), "vgg16 NS(50)": (58.5, 90.15),
    "vgg16 Zebra(0.1)": (60.4, 90.23), "vgg16 Zebra+NS(50)": (68.8, 90.25),
    "rn18 NS(20)": (22.5, 90.75), "rn18 Zebra(0.1)": (30.4, 90.81),
    "rn18 Zebra+NS(20)": (41.4, 90.96), "rn18 NS(40)": (29.8, 89.42),
    "rn18 Zebra(0.2)": (40.5, 89.50), "rn18 Zebra+NS(40)": (50.4, 89.55),
}

WIDTHS = {"vgg16": 0.2, "resnet18": 0.25, "resnet56": 0.5,
          "mobilenet": 0.25}


def budget(full: bool) -> dict:
    if full:
        return {"steps_c10": 600, "steps_tiny": 400, "n_train": 4000,
                "n_test": 512, "batch_c10": 48, "batch_tiny": 24,
                "wmul": 2.0}
    return {"steps_c10": 130, "steps_tiny": 90, "n_train": 1280,
            "n_test": 256, "batch_c10": 32, "batch_tiny": 16,
            "wmul": 1.0}


def make_config(key: str, full: bool) -> TrainConfig:
    e = EXPERIMENTS[key]
    b = budget(full)
    tiny = e["dataset"] == "tiny"
    return TrainConfig(
        arch=e["arch"], dataset=e["dataset"],
        width=min(1.0, WIDTHS[e["arch"]] * b["wmul"]),
        t_obj=e["t_obj"], zebra=e["zebra"],
        ns_ratio=e["ns_ratio"], wp_ratio=e["wp_ratio"],
        steps=b["steps_tiny"] if tiny else b["steps_c10"],
        batch=b["batch_tiny"] if tiny else b["batch_c10"],
        n_train=b["n_train"] // (2 if tiny else 1),
        n_test=b["n_test"],
        seed=hash(key) % (2**31),
    )


# --------------------------------------------------------------- helpers


def flatten_params(tree, prefix="") -> dict[str, np.ndarray]:
    out = {}
    for k, v in tree.items():
        p = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(flatten_params(v, p))
        else:
            out[p] = np.asarray(v)
    return out


def unflatten_params(flat: dict) -> dict:
    tree: dict = {}
    for path, v in flat.items():
        segs = path.split("/")
        node = tree
        for s in segs[:-1]:
            node = node.setdefault(s, {})
        node[segs[-1]] = jnp.asarray(v)
    return tree


def _metrics_path() -> str:
    return os.path.join(ART, "metrics.json")


def load_metrics() -> dict:
    try:
        with open(_metrics_path()) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def save_metrics(m: dict) -> None:
    tmp = _metrics_path() + ".tmp"
    with open(tmp, "w") as f:
        json.dump(m, f, indent=1)
    os.replace(tmp, _metrics_path())


# Runs whose parameters are needed downstream (AOT export / traces).
SAVE_PARAMS = {"rn18-c10-t0.1", "rn18-c10-t0.2", "rn18-c10-off",
               "rn18-tiny-t0.2"}


def run_experiment(key: str, full: bool, metrics: dict) -> None:
    if key in metrics.get("runs", {}):
        print(f"[skip] {key} (already in metrics.json)", flush=True)
        return
    cfg = make_config(key, full)
    print(f"[run ] {key}: {cfg.arch}/{cfg.dataset} w={cfg.width} "
          f"T={cfg.t_obj} ns={cfg.ns_ratio} wp={cfg.wp_ratio} "
          f"zebra={cfg.zebra} steps={cfg.steps}", flush=True)
    res = train(cfg, log=False)
    entry = {
        "config": res["config"],
        "eval": res["eval"],
        "paper": EXPERIMENTS[key]["paper"],
        "history": {k: v[:: max(1, len(v) // 60)]
                    for k, v in res["history"].items()},
        "train_seconds": res["train_seconds"],
    }
    metrics.setdefault("runs", {})[key] = entry
    save_metrics(metrics)
    ev = res["eval"]
    print(f"      -> top1={ev['top1']:.2f} top5={ev['top5']:.2f} "
          f"bw={ev.get('reduced_pct', 0):.1f}% "
          f"[{res['train_seconds']:.0f}s]", flush=True)
    if key in SAVE_PARAMS:
        np.savez(os.path.join(ART, f"params_{key}.npz"),
                 **flatten_params(res["params"]))


# ------------------------------------------------------- traces + tableI


def dump_traces_for(key: str, full: bool, n_images: int = 8) -> dict | None:
    """Replay a saved model on test images and dump its DRAM spills."""
    path = os.path.join(ART, f"params_{key}.npz")
    if not os.path.exists(path):
        return None
    cfg = make_config(key, full)
    ds = data.DATASETS[cfg.dataset]
    params = unflatten_params(dict(np.load(path)))
    spec = models.make_spec(cfg.arch, ds["classes"], cfg.width)
    _, (xte, yte) = ds["make"](64, n_images, seed=cfg.seed + 7)
    x = jnp.asarray(xte[:n_images])
    mode = "infer" if cfg.zebra else "off"
    _, _, aux = models.apply(
        params, spec, x, train=False, zebra_mode=mode, t_obj=cfg.t_obj,
        default_block=ds["block"], keep_spills=True)
    plan = models.spill_plan(spec, ds["hw"], ds["block"])
    outdir = os.path.join(ART, "traces", key)
    raw = np.clip(
        (np.asarray(xte[:n_images]) * data.STD[:, None, None]
         + data.MEAN[:, None, None]) * 255.0, 0, 255).astype(np.uint8)
    trace.dump_trace(
        outdir,
        [s.name for s in plan],
        [np.asarray(sp) for sp in aux["spills"]],
        [s.block for s in plan],
        extra_meta={
            "model": key, "arch": cfg.arch, "dataset": cfg.dataset,
            "t_obj": cfg.t_obj, "zebra": cfg.zebra,
            "labels": [int(v) for v in yte[:n_images]],
        },
    )
    trace.write_zten(os.path.join(outdir, "raw_images.zten"), raw)
    print(f"[trce] {key} -> {outdir} ({len(plan)} spills)", flush=True)
    return {"dir": f"traces/{key}", "n_images": n_images}


def compute_table1(full: bool, metrics: dict) -> None:
    """Table I: natural zero-block % of (baseline) ResNet-18 on CIFAR
    for block sizes 2x2 / 4x4 / whole-map."""
    path = os.path.join(ART, "params_rn18-c10-off.npz")
    if not os.path.exists(path):
        return
    cfg = make_config("rn18-c10-off", full)
    ds = data.DATASETS["cifar10"]
    params = unflatten_params(dict(np.load(path)))
    spec = models.make_spec(cfg.arch, ds["classes"], cfg.width)
    _, (xte, _) = ds["make"](64, 64, seed=cfg.seed + 7)
    _, _, aux = models.apply(
        params, spec, jnp.asarray(xte), train=False, zebra_mode="off",
        t_obj=0.0, default_block=ds["block"], keep_spills=True)
    rows = {}
    for label, blk in [("2x2", 2), ("4x4", 4), ("whole", 0)]:
        num = 0.0
        den = 0.0
        for sp in aux["spills"]:
            b = blk if blk else sp.shape[2]  # whole map = one block
            b = min(b, sp.shape[2])
            frac = float(kref.zero_block_fraction_ref(sp, b))
            nblocks = sp.shape[0] * sp.shape[1] * (sp.shape[2] // b) * (
                sp.shape[3] // b)
            num += frac * nblocks
            den += nblocks
        rows[label] = 100.0 * num / max(den, 1)
    metrics["table1"] = {
        "measured": rows,
        "paper": {"2x2": 24.7, "4x4": 7.9, "whole": 1.1},
    }
    save_metrics(metrics)
    print(f"[tbl1] natural zero blocks: {rows}", flush=True)


# ------------------------------------------------------------ AOT export


def export_artifacts(full: bool, metrics: dict) -> None:
    manifest: dict = {"models": [], "datasets": {}, "specs": {}}
    b = budget(full)

    # Dataset descriptions + a shared test set for the Rust examples.
    for name, ds in data.DATASETS.items():
        manifest["datasets"][name] = {
            "hw": ds["hw"], "classes": ds["classes"], "block": ds["block"],
            "mean": [float(v) for v in data.MEAN],
            "std": [float(v) for v in data.STD],
        }
    _, (xte, yte) = data.synth_cifar(64, 128, seed=1007)
    trace.write_zten(os.path.join(ART, "testset_images.zten"),
                     xte.astype(np.float32))
    trace.write_zten(os.path.join(ART, "testset_labels.zten"),
                     yte.astype(np.int32))

    # Spill plans: trained width (for the simulator) and width=1.0 (the
    # paper's architecture — Table V arithmetic).
    for arch in ("vgg16", "resnet18", "resnet56", "mobilenet"):
        for dsname, ds in data.DATASETS.items():
            for tag, width in [
                ("trained", min(1.0, WIDTHS[arch] * b["wmul"])),
                ("paper", 1.0),
            ]:
                spec = models.make_spec(arch, ds["classes"], width)
                plan = models.spill_plan(spec, ds["hw"], ds["block"])
                manifest["specs"][f"{arch}-{dsname}-{tag}"] = [
                    {"name": s.name, "c": s.c, "h": s.h, "w": s.w,
                     "block": s.block} for s in plan
                ]

    # AOT models: the serving configuration (ResNet-18 + Zebra) at a few
    # batch sizes, the no-Zebra baseline, and the standalone kernel.
    jobs = [
        ("rn18-c10-t0.1", True, [1, 4, 8]),
        ("rn18-c10-off", False, [1, 8]),
    ]
    for key, zebra_on, batches in jobs:
        ppath = os.path.join(ART, f"params_{key}.npz")
        if not os.path.exists(ppath):
            continue
        cfg = make_config(key, full)
        ds = data.DATASETS[cfg.dataset]
        params = unflatten_params(dict(np.load(ppath)))
        spec = models.make_spec(cfg.arch, ds["classes"], cfg.width)
        wdir = os.path.join(ART, f"weights_{key}")
        for i, bs in enumerate(batches):
            out = os.path.join(ART, f"model_{key}_b{bs}.hlo.txt")
            t0 = time.time()
            meta = aot.export_model(
                params, spec, batch=bs, hw=ds["hw"], t_obj=cfg.t_obj,
                default_block=ds["block"], zebra=zebra_on, out_path=out,
                weights_dir=wdir if i == 0 else None)
            meta["key"] = key
            meta["weights_dir"] = f"weights_{key}"
            manifest["models"].append(meta)
            print(f"[aot ] {out} ({time.time() - t0:.0f}s)", flush=True)
    kmeta = aot.export_zebra_kernel(
        os.path.join(ART, "kernel_zebra.hlo.txt"))
    manifest["kernel"] = kmeta

    manifest["traces"] = {}
    for key in ("rn18-c10-off", "rn18-c10-t0.2", "rn18-tiny-t0.2"):
        t = dump_traces_for(key, full)
        if t:
            manifest["traces"][key] = t

    with open(os.path.join(ART, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    metrics["exported"] = True
    save_metrics(metrics)
    print("[done] manifest.json written", flush=True)


# ------------------------------------------------------------------ main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", type=int, default=0)
    ap.add_argument("--only", type=str, default="",
                    help="comma-separated experiment keys (debug)")
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()
    full = bool(args.full)
    os.makedirs(ART, exist_ok=True)
    metrics = load_metrics()
    metrics["tables"] = {
        name: [{"label": lbl, "key": key} for lbl, key in rows]
        for name, rows in TABLES.items()
    }
    metrics["table4_paper"] = TABLE4_PAPER
    save_metrics(metrics)

    keys = (args.only.split(",") if args.only else list(EXPERIMENTS))
    # Group by (arch, dataset, zebra) so the jit cache is hit in order,
    # and run the substrate models first (they gate traces/AOT).
    prio = {"rn18-c10-off": 0, "rn18-c10-t0.1": 1, "rn18-c10-t0.2": 2,
            "rn18-tiny-t0.2": 3}
    keys.sort(key=lambda k: (
        prio.get(k, 10),
        EXPERIMENTS[k]["arch"], EXPERIMENTS[k]["dataset"],
        not EXPERIMENTS[k]["zebra"]))
    t0 = time.time()
    if not args.skip_train:
        for key in keys:
            run_experiment(key, full, metrics)
            # Export early once the substrate runs are done so the Rust
            # side can start even while the grid is still training.
            if key == "rn18-tiny-t0.2" and not metrics.get("exported"):
                compute_table1(full, metrics)
                export_artifacts(full, metrics)
    compute_table1(full, metrics)
    export_artifacts(full, metrics)
    print(f"[done] pipeline in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
