"""Activation trace + tensor interchange with the Rust side (.zten).

Format (little-endian), shared with ``rust/src/tensor/io.rs``:

    magic   b"ZTEN"
    u32     version (1)
    u32     dtype   (0 = f32, 1 = u8, 2 = i32)
    u32     ndim
    u32[nd] dims
    payload row-major

A *trace directory* holds one ``.zten`` per DRAM spill of one batch of
images plus ``trace.json`` describing spill names, shapes and Zebra
block sizes — the accelerator simulator replays these to measure real
bytes-on-the-wire (DESIGN.md §9).
"""

from __future__ import annotations

import json
import os
import struct

import numpy as np

MAGIC = b"ZTEN"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.uint8): 1,
          np.dtype(np.int32): 2}
DTYPES_INV = {0: np.float32, 1: np.uint8, 2: np.int32}


def write_zten(path: str, arr: np.ndarray) -> None:
    arr = np.ascontiguousarray(arr)
    code = DTYPES[arr.dtype]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<III", 1, code, arr.ndim))
        f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
        f.write(arr.tobytes())


def read_zten(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        ver, code, nd = struct.unpack("<III", f.read(12))
        if ver != 1:
            raise ValueError(f"{path}: unsupported version {ver}")
        dims = struct.unpack(f"<{nd}I", f.read(4 * nd))
        return np.frombuffer(f.read(), DTYPES_INV[code]).reshape(dims).copy()


def dump_trace(
    outdir: str,
    spill_names: list[str],
    spills: list[np.ndarray],
    blocks: list[int],
    extra_meta: dict | None = None,
) -> None:
    """Write one batch's spills + metadata as a trace directory."""
    os.makedirs(outdir, exist_ok=True)
    entries = []
    for name, arr, block in zip(spill_names, spills, blocks):
        fname = name.replace(".", "_") + ".zten"
        write_zten(os.path.join(outdir, fname), np.asarray(arr, np.float32))
        entries.append({
            "name": name,
            "file": fname,
            "shape": list(arr.shape),
            "block": int(block),
        })
    meta = {"spills": entries}
    meta.update(extra_meta or {})
    with open(os.path.join(outdir, "trace.json"), "w") as f:
        json.dump(meta, f, indent=1)
