"""Training: Eq. 1 loss, SGD + momentum, Network Slimming, Weight Pruning.

Implements the paper's full training recipe:

- ``L = lambda * CE + sum_{l,c} ||T_obj - T_{l,c}||^2`` (Eq. 1 verbatim;
  the regularizer is the only gradient source for the threshold nets).
- Standard SGD with momentum and step-decayed learning rate
  ("0.1 -> 0.001" in the paper; scaled to our step budget).
- **Weight Pruning** (ref [3]): global magnitude pruning of conv/FC
  weights on a trained model, mask frozen, then retrain with Zebra.
- **Network Slimming** (ref [4]): L1 sparsity on BN gamma, then the
  smallest-|gamma| fraction of channels is *masked out*
  (gamma = beta = 0 -> the channel's post-ReLU map is identically zero),
  then retrain with Zebra. Masking rather than physically shrinking
  tensors keeps one spec shared across all runs; the effect Zebra sees —
  redundant activation maps become all-zero and block-prunable — is the
  mechanism the paper credits for the NS+Zebra synergy (Table IV).

Bandwidth accounting follows Eq. 2–3: a pruned block costs 0 bytes, a
kept block ``B^2 * 4`` bytes, plus 1 index bit per block; reduction % is
measured on the test set in inference mode (fixed T_obj, Fig. 3).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import models, zebra_layer


@dataclasses.dataclass
class TrainConfig:
    arch: str = "resnet18"
    dataset: str = "cifar10"
    width: float = 0.25
    t_obj: float = 0.1
    lam: float = 1.0            # lambda on the CE term (Eq. 1)
    zebra: bool = True          # False -> plain baseline model
    steps: int = 400
    batch: int = 64
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 5e-4
    ns_ratio: float = 0.0       # Network-Slimming channel fraction
    ns_l1: float = 1e-4         # L1 strength on BN gamma during NS pretrain
    wp_ratio: float = 0.0       # Weight-Pruning fraction
    pretrain_steps: int = 0     # steps before NS/WP act (0 -> steps // 2)
    n_train: int = 2000
    n_test: int = 512
    seed: int = 0
    backend: str = "xla"        # conv backend for the training grid


# ------------------------------------------------------------- utilities


def _lr_at(cfg: TrainConfig, step: int) -> float:
    """Step decay 0.1 -> 0.01 -> 0.001 at 50% / 80% of the budget."""
    frac = step / max(1, cfg.steps)
    if frac < 0.5:
        return cfg.lr
    if frac < 0.8:
        return cfg.lr * 0.1
    return cfg.lr * 0.01


def _is_weight(path: tuple) -> bool:
    """True for conv/FC weight leaves (targets of decay + WP)."""
    return any(seg in ("conv", "conv1", "conv2", "proj", "dw", "pw", "fc")
               for seg in path) and path[-1] == "w"


def _is_bn_gamma(path: tuple) -> bool:
    return path[-1] == "gamma"


def _is_bn_stat(path: tuple) -> bool:
    return path[-1] in ("mean", "var")


def _tree_paths(tree, prefix=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _tree_paths(v, prefix + (k,))
    else:
        yield prefix, tree


def tree_map_with_path(fn, tree, prefix=()):
    if isinstance(tree, dict):
        return {k: tree_map_with_path(fn, v, prefix + (k,))
                for k, v in tree.items()}
    return fn(prefix, tree)


# --------------------------------------------------------------- pruning


def weight_prune_masks(params: dict, ratio: float) -> dict:
    """Global magnitude masks over all conv/FC weights (ref [3])."""
    mags = [
        np.abs(np.asarray(leaf)).ravel()
        for path, leaf in _tree_paths(params)
        if _is_weight(path)
    ]
    if not mags or ratio <= 0.0:
        return tree_map_with_path(lambda p, v: jnp.ones_like(v)
                                  if _is_weight(p) else None, params)
    allm = np.concatenate(mags)
    thresh = np.quantile(allm, ratio)

    def mk(path, leaf):
        if not _is_weight(path):
            return None
        return (jnp.abs(leaf) > thresh).astype(leaf.dtype)

    return tree_map_with_path(mk, params)


def apply_weight_masks(params: dict, masks: dict) -> dict:
    def ap(path, leaf):
        m = masks
        for seg in path:
            m = m[seg]
        return leaf * m if m is not None else leaf

    return tree_map_with_path(ap, params)


def slim_masks(params: dict, ratio: float) -> dict:
    """Network Slimming: globally mask the smallest-|gamma| channel
    fraction (per ref [4]'s global threshold over all BN gammas)."""
    gammas = [
        np.abs(np.asarray(leaf)).ravel()
        for path, leaf in _tree_paths(params)
        if _is_bn_gamma(path)
    ]
    if not gammas or ratio <= 0.0:
        return tree_map_with_path(lambda p, v: None, params)
    thresh = np.quantile(np.concatenate(gammas), ratio)

    def mk(path, leaf):
        if _is_bn_gamma(path):
            return (jnp.abs(leaf) > thresh).astype(leaf.dtype)
        return None

    return tree_map_with_path(mk, params)


def apply_slim_masks(params: dict, masks: dict) -> dict:
    """gamma *= m ; beta *= m  — masked channels emit exactly zero."""
    def ap(path, leaf):
        if path[-1] in ("gamma", "beta"):
            m = masks
            for seg in path[:-1]:
                m = m[seg]
            m = m.get("gamma") if isinstance(m, dict) else None
            if m is not None:
                return leaf * m
        return leaf

    return tree_map_with_path(ap, params)


# ------------------------------------------------------------- bandwidth


def bandwidth_stats(masks: list[jnp.ndarray], blocks: list[int]) -> dict:
    """Eq. 2–3 accounting over one batch's Zebra masks.

    Returns totals in *bytes per image* (f32 activations, 1 bit / block
    of index) plus the reduction percentage net of index overhead.
    """
    total = 0.0
    kept = 0.0
    index_bits = 0.0
    for mask, b in zip(masks, blocks):
        n = mask.shape[0]
        nblocks = float(np.prod(mask.shape)) / n
        elems = nblocks * b * b
        total += elems * 4.0
        kept += float(np.asarray(mask).mean()) * elems * 4.0
        index_bits += nblocks
    overhead = index_bits / 8.0
    reduced = 100.0 * (1.0 - (kept + overhead) / max(total, 1e-9))
    return {
        "required_bytes": total,
        "kept_bytes": kept,
        "overhead_bytes": overhead,
        "reduced_pct": reduced,
    }


# ---------------------------------------------------------------- losses


def _split_params(params):
    """Separate BN running stats (non-trainable) from trainables."""
    train = tree_map_with_path(
        lambda p, v: None if _is_bn_stat(p) else v, params)
    stats = tree_map_with_path(
        lambda p, v: v if _is_bn_stat(p) else None, params)
    return train, stats


def _merge_params(train, stats):
    def mg(a, b):
        if isinstance(a, dict):
            return {k: mg(a[k], b[k]) for k in a}
        return a if a is not None else b

    return mg(train, stats)


# jit cache: one compiled step per (arch, width, classes, dataset-block,
# zebra on/off, backend, batch) — the T_obj / lambda / NS-L1 sweep reuses
# the same executable because those enter as traced scalars. On this
# 1-CPU host, recompiling per grid point would dominate the whole
# pipeline (DESIGN.md §7).
_STEP_CACHE: dict[tuple, Any] = {}
_EVAL_CACHE: dict[tuple, Any] = {}


def make_train_step(cfg: TrainConfig, spec, default_block):
    key = (cfg.arch, cfg.width, cfg.dataset, cfg.zebra, cfg.backend,
           cfg.momentum, cfg.weight_decay)
    if key in _STEP_CACHE:
        return _STEP_CACHE[key]
    zebra_mode = "train" if cfg.zebra else "off"
    momentum, weight_decay = cfg.momentum, cfg.weight_decay

    def loss_fn(trainable, stats, x, y, t_obj, lam, ns_l1):
        params = _merge_params(trainable, stats)
        logits, new_params, aux = models.apply(
            params, spec, x, train=True, zebra_mode=zebra_mode,
            t_obj=t_obj, default_block=default_block,
            backend=cfg.backend)
        onehot = jax.nn.one_hot(y, logits.shape[-1])
        ce = -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=1))
        reg = zebra_layer.regularizer(aux["ts"], t_obj)
        # L1 on BN gamma is active only for Network-Slimming pretraining
        # (ns_l1 is passed as 0 otherwise).
        l1 = sum(jnp.abs(leaf).sum()
                 for path, leaf in _tree_paths(trainable)
                 if leaf is not None and _is_bn_gamma(path))
        loss = lam * ce + reg + ns_l1 * l1
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        _, new_stats = _split_params(new_params)
        mean_t = (sum(jnp.mean(t) for t in aux["ts"]) / len(aux["ts"])
                  if aux["ts"] else jnp.float32(0.0))
        return loss, (ce, reg, acc, new_stats, mean_t)

    @jax.jit
    def step(trainable, stats, velocity, x, y, lr, t_obj, lam, ns_l1):
        (loss, (ce, reg, acc, new_stats, mean_t)), grads = (
            jax.value_and_grad(loss_fn, has_aux=True)(
                trainable, stats, x, y, t_obj, lam, ns_l1))

        def upd(path, v):
            if v is None:
                return None
            g = grads
            vel = velocity
            for seg in path:
                g, vel = g[seg], vel[seg]
            if _is_weight(path):
                g = g + weight_decay * v
            newvel = momentum * vel - lr * g
            return newvel

        new_velocity = tree_map_with_path(upd, trainable)

        def apply_v(path, v):
            if v is None:
                return None
            nv = new_velocity
            for seg in path:
                nv = nv[seg]
            return v + nv

        new_trainable = tree_map_with_path(apply_v, trainable)
        metrics = {"loss": loss, "ce": ce, "reg": reg, "acc": acc,
                   "mean_t": mean_t}
        return new_trainable, new_stats, new_velocity, metrics

    _STEP_CACHE[key] = step
    return step


# ------------------------------------------------------------ evaluation


def evaluate(params, spec, cfg: TrainConfig, xs, ys, default_block,
             batch: int = 128) -> dict:
    """Test accuracy + Eq. 2–3 bandwidth stats in inference mode.

    Models trained without Zebra are still *evaluated* through the
    inference op at T = 0: post-ReLU that is the identity on values, and
    the masks then count the natural / NS-induced zero blocks — the
    bandwidth the paper credits to its baselines (e.g. Table IV's
    NS-only rows, Table II's T_obj = 0 rows).
    """
    zebra_mode = "infer"
    eval_t = cfg.t_obj if cfg.zebra else 0.0
    n = xs.shape[0]
    correct = 0
    top5 = 0
    all_masks: list[list[np.ndarray]] = []
    blocks: list[int] = []

    ekey = (cfg.arch, cfg.width, cfg.dataset, cfg.zebra, cfg.backend, batch)
    if ekey in _EVAL_CACHE:
        fwd = _EVAL_CACHE[ekey]
    else:
        @jax.jit
        def fwd(params, x, t_obj):
            logits, _, aux = models.apply(
                params, spec, x, train=False, zebra_mode=zebra_mode,
                t_obj=t_obj, default_block=default_block,
                backend=cfg.backend)
            return logits, aux["masks"]

        _EVAL_CACHE[ekey] = fwd

    for i in range(0, n, batch):
        x, y = xs[i:i + batch], ys[i:i + batch]
        orig = x.shape[0]
        if orig != batch:  # pad the ragged tail to keep one jit key
            pad = np.zeros((batch - orig,) + x.shape[1:], x.dtype)
            x = np.concatenate([x, pad])
        logits, masks = fwd(params, jnp.asarray(x), jnp.float32(eval_t))
        logits = logits[:orig]
        masks = [m[:orig] for m in masks]
        pred = np.asarray(jnp.argmax(logits, -1))
        correct += int((pred == y).sum())
        k = min(5, logits.shape[-1])
        topk = np.asarray(jnp.argsort(logits, -1)[:, -k:])
        top5 += int(sum(y[j] in topk[j] for j in range(len(y))))
        if masks:
            all_masks.append([np.asarray(m) for m in masks])

    out = {"top1": 100.0 * correct / n, "top5": 100.0 * top5 / n}
    if all_masks:
        merged = [np.concatenate([bm[i] for bm in all_masks])
                  for i in range(len(all_masks[0]))]
        hw = data_mod.DATASETS[cfg.dataset]["hw"]
        plan = models.spill_plan(spec, hw,
                                 data_mod.DATASETS[cfg.dataset]["block"])
        blocks = [s.block for s in plan]
        out.update(bandwidth_stats([jnp.asarray(m) for m in merged], blocks))
    else:
        # Baseline model: only natural zero blocks reduce traffic. Measure
        # them by running inference with T = 0 semantics (strict compare).
        out.update({"reduced_pct": 0.0})
    return out


# -------------------------------------------------------------- training


def train(cfg: TrainConfig, log: bool = True) -> dict[str, Any]:
    """Full recipe: [NS/WP pretrain ->] train [+ Zebra] -> evaluate.

    Returns a results dict (accuracies, bandwidth stats, histories,
    final params) consumed by the pipeline and the AOT exporter.
    """
    t0 = time.time()
    ds = data_mod.DATASETS[cfg.dataset]
    (xtr, ytr), (xte, yte) = ds["make"](cfg.n_train, cfg.n_test,
                                        seed=cfg.seed + 7)
    spec = models.make_spec(cfg.arch, ds["classes"], cfg.width)
    default_block = ds["block"]
    key = jax.random.PRNGKey(cfg.seed)
    params = models.init(key, spec, ds["hw"], default_block, cfg.t_obj)

    trainable, stats = _split_params(params)
    velocity = tree_map_with_path(
        lambda p, v: None if v is None else jnp.zeros_like(v), trainable)
    step_fn = make_train_step(cfg, spec, default_block)

    wp_masks = None
    ns_masks = None
    pretrain = cfg.pretrain_steps or (
        cfg.steps // 2 if (cfg.ns_ratio > 0 or cfg.wp_ratio > 0) else 0)

    rng = np.random.default_rng(cfg.seed)
    history = {"loss": [], "acc": [], "mean_t": [], "reg": []}
    for it in range(cfg.steps):
        idx = rng.integers(0, xtr.shape[0], cfg.batch)
        x, y = jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx])
        lr = _lr_at(cfg, it)
        ns_l1_eff = cfg.ns_l1 if (cfg.ns_ratio > 0 and it < pretrain) else 0.0
        trainable, stats, velocity, m = step_fn(
            trainable, stats, velocity, x, y,
            jnp.float32(lr), jnp.float32(cfg.t_obj),
            jnp.float32(cfg.lam), jnp.float32(ns_l1_eff))

        # Static pruning acts once, mid-budget: prune, freeze mask,
        # keep training (the paper's "prune then retrain with Zebra").
        if it + 1 == pretrain:
            merged = _merge_params(trainable, stats)
            if cfg.wp_ratio > 0:
                wp_masks = weight_prune_masks(merged, cfg.wp_ratio)
            if cfg.ns_ratio > 0:
                ns_masks = slim_masks(merged, cfg.ns_ratio)
        if wp_masks is not None:
            trainable = apply_weight_masks(trainable, wp_masks)
        if ns_masks is not None:
            trainable = apply_slim_masks(trainable, ns_masks)

        for k in history:
            if k in m:
                history[k].append(float(m[k]))
        if log and (it % max(1, cfg.steps // 10) == 0 or it == cfg.steps - 1):
            print(f"  step {it:4d} loss={float(m['loss']):.4f} "
                  f"acc={float(m['acc']):.3f} reg={float(m['reg']):.4f} "
                  f"mean_T={float(m['mean_t']):.4f} lr={lr:.4f}",
                  flush=True)

    params = _merge_params(trainable, stats)
    ev = evaluate(params, spec, cfg, xte, yte, default_block)
    result = {
        "config": dataclasses.asdict(cfg),
        "spec": spec,
        "params": params,
        "history": history,
        "eval": ev,
        "train_seconds": time.time() - t0,
    }
    if log:
        print(f"  -> top1={ev['top1']:.2f}% "
              f"reduced_bw={ev.get('reduced_pct', 0.0):.1f}% "
              f"({result['train_seconds']:.0f}s)", flush=True)
    return result
