"""Layer-2 building blocks: functional NN layers over the L1 kernels.

Everything is NCHW and purely functional: ``init_*`` returns a parameter
pytree, ``apply`` functions take (params, x) and return outputs plus any
updated state (BN running statistics).

Two compute backends exist for convolution:

- ``"pallas"`` — im2col + the L1 MXU-tiled GEMM kernel
  (`kernels/matmul.py`). This is the backend used by the AOT export path
  (so the shipped HLO contains the Pallas lowering) and by the
  equivalence tests.
- ``"xla"`` — `jax.lax.conv_general_dilated`. Numerically equivalent
  (tests assert allclose); used by the CPU-budget training grid because
  XLA's native conv is several times faster on this host
  (DESIGN.md §7). The paper's technique is agnostic to which one runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import matmul as mm_kernel

# Global default; the trainer overrides per-run.
DEFAULT_BACKEND = "xla"


# ------------------------------------------------------------------ init

def _fan_in_init(key, shape, fan_in, dtype=jnp.float32):
    """He-normal initialization (ReLU networks, as the paper's baselines)."""
    std = (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, shape, dtype) * std


def init_conv(key, cin: int, cout: int, ksize: int) -> dict:
    """3x3/1x1 conv weights, (O, I, Kh, Kw), no bias (BN follows)."""
    w = _fan_in_init(key, (cout, cin, ksize, ksize), cin * ksize * ksize)
    return {"w": w}


def init_dwconv(key, c: int, ksize: int) -> dict:
    """Depthwise conv weights, (C, 1, Kh, Kw) (MobileNet)."""
    w = _fan_in_init(key, (c, 1, ksize, ksize), ksize * ksize)
    return {"w": w}


def init_bn(c: int) -> dict:
    """BatchNorm params + running stats. gamma is the Network-Slimming
    channel-importance handle (paper Sec. I, ref [4])."""
    return {
        "gamma": jnp.ones((c,)),
        "beta": jnp.zeros((c,)),
        "mean": jnp.zeros((c,)),
        "var": jnp.ones((c,)),
    }


def init_fc(key, cin: int, cout: int) -> dict:
    w = _fan_in_init(key, (cin, cout), cin)
    return {"w": w, "b": jnp.zeros((cout,))}


# ------------------------------------------------------------------ conv

def _im2col(x: jnp.ndarray, ksize: int, stride: int, pad: int):
    """NCHW -> (N*Ho*Wo, C*K*K) patches for conv-as-GEMM."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    ho = (h + 2 * pad - ksize) // stride + 1
    wo = (w + 2 * pad - ksize) // stride + 1
    # Extract K*K shifted strided views; cheap under XLA (fused gathers).
    cols = []
    for i in range(ksize):
        for j in range(ksize):
            cols.append(
                xp[
                    :,
                    :,
                    i : i + stride * ho : stride,
                    j : j + stride * wo : stride,
                ]
            )
    # (K*K, N, C, Ho, Wo) -> (N, Ho, Wo, C, K*K) -> (N*Ho*Wo, C*K*K)
    patches = jnp.stack(cols, axis=0)
    patches = patches.transpose(1, 3, 4, 2, 0)
    return patches.reshape(n * ho * wo, c * ksize * ksize), ho, wo


def conv2d(
    params: dict,
    x: jnp.ndarray,
    stride: int = 1,
    pad: int | None = None,
    backend: str | None = None,
) -> jnp.ndarray:
    """2-D convolution, NCHW x (O,I,Kh,Kw) -> NCHW."""
    w = params["w"]
    cout, cin, k, _ = w.shape
    if pad is None:
        pad = k // 2
    backend = backend or DEFAULT_BACKEND
    if backend == "xla":
        return jax.lax.conv_general_dilated(
            x,
            w,
            window_strides=(stride, stride),
            padding=[(pad, pad), (pad, pad)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
    if backend != "pallas":
        raise ValueError(f"unknown conv backend {backend!r}")
    n = x.shape[0]
    patches, ho, wo = _im2col(x, k, stride, pad)
    out = mm_kernel.matmul(patches, w.reshape(cout, cin * k * k).T)
    return out.reshape(n, ho, wo, cout).transpose(0, 3, 1, 2)


def dwconv2d(
    params: dict,
    x: jnp.ndarray,
    stride: int = 1,
    backend: str | None = None,
) -> jnp.ndarray:
    """Depthwise 3x3 conv (MobileNet). Always lowered via XLA's grouped
    conv — it is bandwidth-bound, not MXU-shaped, so there is nothing for
    the GEMM kernel to win (DESIGN.md §8)."""
    del backend
    w = params["w"]  # (C, 1, K, K)
    c, _, k, _ = w.shape
    pad = k // 2
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c,
    )


# -------------------------------------------------------------------- bn

BN_MOMENTUM = 0.9
BN_EPS = 1e-5


def batchnorm(params: dict, x: jnp.ndarray, train: bool):
    """BatchNorm2d. Returns (y, updated_params) — running stats advance
    only in training mode."""
    gamma = params["gamma"][None, :, None, None]
    beta = params["beta"][None, :, None, None]
    if train:
        mean = x.mean(axis=(0, 2, 3))
        var = x.var(axis=(0, 2, 3))
        new = dict(params)
        new["mean"] = BN_MOMENTUM * params["mean"] + (1 - BN_MOMENTUM) * mean
        new["var"] = BN_MOMENTUM * params["var"] + (1 - BN_MOMENTUM) * var
    else:
        mean, var = params["mean"], params["var"]
        new = params
    xn = (x - mean[None, :, None, None]) * jax.lax.rsqrt(
        var[None, :, None, None] + BN_EPS
    )
    return gamma * xn + beta, new


# ------------------------------------------------------------------ misc

def relu(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(x, 0.0)


def gap(x: jnp.ndarray) -> jnp.ndarray:
    """Global average pool, (N, C, H, W) -> (N, C)."""
    return x.mean(axis=(2, 3))


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2/2 max pool (VGG)."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2),
        padding="VALID",
    )


def avgpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2/2 average pool."""
    s = jax.lax.reduce_window(
        x,
        0.0,
        jax.lax.add,
        window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2),
        padding="VALID",
    )
    return s / 4.0


def fc(params: dict, x: jnp.ndarray, backend: str | None = None):
    """Fully connected layer over the GEMM kernel (classifier head)."""
    backend = backend or DEFAULT_BACKEND
    if backend == "pallas":
        return mm_kernel.matmul(x, params["w"]) + params["b"]
    return x @ params["w"] + params["b"]
