"""Model zoo: VGG16, ResNet-18, ResNet-56, MobileNetV1 (paper Sec. III).

Models are described by a small layer-spec IR (list of stage dicts) and
executed by one generic ``apply``. The same IR is exported to JSON by
``aot.py`` so the Rust side (bandwidth math, accelerator simulator,
Table V) consumes exactly the architecture Python trained — no
double-maintenance.

Conventions:
- NCHW, CIFAR-style stems (3x3/1) for both 32x32 and 64x64 inputs.
- A "spill" is an activation tensor the paper's layer-by-layer
  accelerator would write to DRAM: the output of every ReLU stage. Each
  spill carries its Zebra block size, following the paper's rule
  (block 4 on CIFAR, 2 once maps shrink to 2x2; block 8 on
  Tiny-ImageNet).
- ``width`` scales every channel count (CPU-budget knob, DESIGN.md §7);
  width=1.0 is the paper's architecture.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers, zebra_layer

# --------------------------------------------------------------- spec IR


def _ch(c: int, width: float) -> int:
    """Scale a channel count, keeping it a multiple of 4 and >= 4."""
    return max(4, int(round(c * width / 4)) * 4)


def vgg16_spec(num_classes: int, width: float = 1.0) -> list[dict]:
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    spec: list[dict] = []
    for v in cfg:
        if v == "M":
            spec.append({"kind": "pool", "op": "max"})
        else:
            spec.append({"kind": "conv", "cout": _ch(v, width), "k": 3,
                         "stride": 1})
    spec += [{"kind": "gap"}, {"kind": "fc", "cout": num_classes}]
    return spec


def resnet18_spec(num_classes: int, width: float = 1.0) -> list[dict]:
    spec: list[dict] = [{"kind": "conv", "cout": _ch(64, width), "k": 3,
                         "stride": 1}]
    for cout, stride, blocks in [(64, 1, 2), (128, 2, 2), (256, 2, 2),
                                 (512, 2, 2)]:
        for i in range(blocks):
            spec.append({"kind": "res", "cout": _ch(cout, width),
                         "stride": stride if i == 0 else 1})
    spec += [{"kind": "gap"}, {"kind": "fc", "cout": num_classes}]
    return spec


def resnet56_spec(num_classes: int, width: float = 1.0) -> list[dict]:
    spec: list[dict] = [{"kind": "conv", "cout": _ch(16, width), "k": 3,
                         "stride": 1}]
    for cout, stride in [(16, 1), (32, 2), (64, 2)]:
        for i in range(9):
            spec.append({"kind": "res", "cout": _ch(cout, width),
                         "stride": stride if i == 0 else 1})
    spec += [{"kind": "gap"}, {"kind": "fc", "cout": num_classes}]
    return spec


def mobilenet_spec(num_classes: int, width: float = 1.0) -> list[dict]:
    spec: list[dict] = [{"kind": "conv", "cout": _ch(32, width), "k": 3,
                         "stride": 1}]
    chain = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2)]
    chain += [(512, 1)] * 5
    chain += [(1024, 2), (1024, 1)]
    for cout, stride in chain:
        spec.append({"kind": "dwsep", "cout": _ch(cout, width),
                     "stride": stride})
    spec += [{"kind": "gap"}, {"kind": "fc", "cout": num_classes}]
    return spec


SPECS = {
    "vgg16": vgg16_spec,
    "resnet18": resnet18_spec,
    "resnet56": resnet56_spec,
    "mobilenet": mobilenet_spec,
}


def make_spec(arch: str, num_classes: int, width: float = 1.0) -> list[dict]:
    return SPECS[arch](num_classes, width)


def zebra_block_for(hw: int, default_block: int) -> int:
    """The paper's block-size rule: the configured block, shrunk when the
    map itself gets smaller ("we set block size as 2 when the size of
    activation maps in deeper layers goes to 2x2")."""
    return max(1, min(default_block, hw))


# ----------------------------------------------------------------- shapes


@dataclasses.dataclass
class SpillInfo:
    """Static description of one DRAM activation spill (for Rust)."""

    name: str
    c: int
    h: int
    w: int
    block: int


def spill_plan(
    spec: list[dict], in_hw: int, default_block: int, in_ch: int = 3
) -> list[SpillInfo]:
    """Walk the spec symbolically, listing every DRAM spill with its
    shape and Zebra block size. Mirrors ``apply`` exactly (tested)."""
    spills: list[SpillInfo] = []
    c, hw = in_ch, in_hw
    for i, st in enumerate(spec):
        k = st["kind"]
        if k == "conv":
            hw = hw // st["stride"]
            c = st["cout"]
            spills.append(SpillInfo(f"s{i}.conv", c, hw, hw,
                                    zebra_block_for(hw, default_block)))
        elif k == "res":
            hw = hw // st["stride"]
            c = st["cout"]
            b = zebra_block_for(hw, default_block)
            spills.append(SpillInfo(f"s{i}.res.a", c, hw, hw, b))
            spills.append(SpillInfo(f"s{i}.res.out", c, hw, hw, b))
        elif k == "dwsep":
            hwd = hw // st["stride"]
            b = zebra_block_for(hwd, default_block)
            spills.append(SpillInfo(f"s{i}.dw", c, hwd, hwd, b))
            c = st["cout"]
            spills.append(SpillInfo(f"s{i}.pw", c, hwd, hwd, b))
            hw = hwd
        elif k == "pool":
            hw //= 2
        elif k in ("gap", "fc"):
            pass
        else:
            raise ValueError(f"unknown stage kind {k!r}")
    return spills


# ------------------------------------------------------------------ init


def init(key, spec: list[dict], in_hw: int, default_block: int,
         t_obj: float, in_ch: int = 3) -> dict:
    """Initialize all parameters for a spec, including per-Zebra-layer
    threshold nets (training mode)."""
    params: dict = {}
    c, hw = in_ch, in_hw
    for i, st in enumerate(spec):
        k = st["kind"]
        key, *sub = jax.random.split(key, 4)
        name = f"s{i}"
        if k == "conv":
            hw = hw // st["stride"]
            params[name] = {
                "conv": layers.init_conv(sub[0], c, st["cout"], st["k"]),
                "bn": layers.init_bn(st["cout"]),
                "tnet": zebra_layer.init_threshold_net(sub[1], st["cout"],
                                                       t_obj),
            }
            c = st["cout"]
        elif k == "res":
            cout = st["cout"]
            hw = hw // st["stride"]
            p = {
                "conv1": layers.init_conv(sub[0], c, cout, 3),
                "bn1": layers.init_bn(cout),
                "conv2": layers.init_conv(sub[1], cout, cout, 3),
                "bn2": layers.init_bn(cout),
                "tnet1": zebra_layer.init_threshold_net(
                    jax.random.fold_in(sub[2], 1), cout, t_obj),
                "tnet2": zebra_layer.init_threshold_net(
                    jax.random.fold_in(sub[2], 2), cout, t_obj),
            }
            if st["stride"] != 1 or c != cout:
                p["proj"] = layers.init_conv(
                    jax.random.fold_in(sub[2], 3), c, cout, 1)
                p["bnp"] = layers.init_bn(cout)
            params[name] = p
            c = cout
        elif k == "dwsep":
            cout = st["cout"]
            params[name] = {
                "dw": layers.init_dwconv(sub[0], c, 3),
                "bnd": layers.init_bn(c),
                "tnetd": zebra_layer.init_threshold_net(
                    jax.random.fold_in(sub[2], 1), c, t_obj),
                "pw": layers.init_conv(sub[1], c, cout, 1),
                "bnp": layers.init_bn(cout),
                "tnetp": zebra_layer.init_threshold_net(
                    jax.random.fold_in(sub[2], 2), cout, t_obj),
            }
            c = cout
            hw = hw // st["stride"]
        elif k == "pool":
            hw //= 2
        elif k == "fc":
            params[name] = {"fc": layers.init_fc(sub[0], c, st["cout"])}
    return params


# ----------------------------------------------------------------- apply


def _zebra_stage(x, stage_params, tnet_key, zebra_mode, t_obj, block, aux,
                 zb):
    """Shared ReLU(+Zebra) tail of every conv stage. Appends the spill,
    mask and threshold records to ``aux`` and returns the spilled
    tensor."""
    if zebra_mode == "train":
        out, mask, t = zebra_layer.apply_train(
            stage_params[tnet_key], x, block, backend=zb)
        aux["ts"].append(t)
        aux["masks"].append(mask)
    elif zebra_mode == "infer":
        out, mask = zebra_layer.apply_infer(x, t_obj, block, backend=zb)
        aux["masks"].append(mask)
    elif zebra_mode == "off":
        out = layers.relu(x)
    else:
        raise ValueError(f"unknown zebra mode {zebra_mode!r}")
    aux["spills"].append(out)
    return out


def apply(
    params: dict,
    spec: list[dict],
    x: jnp.ndarray,
    *,
    train: bool,
    zebra_mode: str,
    t_obj: float,
    default_block: int,
    backend: str | None = None,
    zebra_backend: str = "jnp",
    keep_spills: bool = False,
):
    """Run a spec. Returns (logits, new_params, aux).

    aux: "masks" — per-Zebra-layer {0,1} block masks; "ts" — per-layer
    learned thresholds (train mode); "spills" — the DRAM activation
    tensors (cleared unless ``keep_spills`` to save memory).
    """
    aux = {"masks": [], "ts": [], "spills": []}
    new_params = dict(params)
    hw = x.shape[2]
    for i, st in enumerate(spec):
        k = st["kind"]
        name = f"s{i}"
        if k == "conv":
            p = dict(params[name])
            hw = hw // st["stride"]
            block = zebra_block_for(hw, default_block)
            y = layers.conv2d(p["conv"], x, st["stride"], backend=backend)
            y, p["bn"] = layers.batchnorm(p["bn"], y, train)
            x = _zebra_stage(y, p, "tnet", zebra_mode, t_obj, block, aux, zebra_backend)
            new_params[name] = p
        elif k == "res":
            p = dict(params[name])
            hw = hw // st["stride"]
            block = zebra_block_for(hw, default_block)
            y = layers.conv2d(p["conv1"], x, st["stride"], backend=backend)
            y, p["bn1"] = layers.batchnorm(p["bn1"], y, train)
            y = _zebra_stage(y, p, "tnet1", zebra_mode, t_obj, block, aux, zebra_backend)
            y2 = layers.conv2d(p["conv2"], y, 1, backend=backend)
            y2, p["bn2"] = layers.batchnorm(p["bn2"], y2, train)
            if "proj" in p:
                sc = layers.conv2d(p["proj"], x, st["stride"], pad=0,
                                   backend=backend)
                sc, p["bnp"] = layers.batchnorm(p["bnp"], sc, train)
            else:
                sc = x
            x = _zebra_stage(y2 + sc, p, "tnet2", zebra_mode, t_obj, block,
                             aux, zebra_backend)
            new_params[name] = p
        elif k == "dwsep":
            p = dict(params[name])
            hw = hw // st["stride"]
            block = zebra_block_for(hw, default_block)
            y = layers.dwconv2d(p["dw"], x, st["stride"])
            y, p["bnd"] = layers.batchnorm(p["bnd"], y, train)
            y = _zebra_stage(y, p, "tnetd", zebra_mode, t_obj, block, aux, zebra_backend)
            y = layers.conv2d(p["pw"], y, 1, pad=0, backend=backend)
            y, p["bnp"] = layers.batchnorm(p["bnp"], y, train)
            x = _zebra_stage(y, p, "tnetp", zebra_mode, t_obj, block, aux, zebra_backend)
            new_params[name] = p
        elif k == "pool":
            x = layers.maxpool2(x)
            hw //= 2
        elif k == "gap":
            x = layers.gap(x)
        elif k == "fc":
            x = layers.fc(params[name]["fc"], x, backend=backend)
    if not keep_spills:
        aux["spills"] = []
    return x, new_params, aux
