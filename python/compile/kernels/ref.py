"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth for correctness: ``python/tests`` sweeps the
Pallas kernels (interpret=True) against these functions with hypothesis
over shapes, dtypes and block sizes, asserting ``allclose``.

Shapes follow the paper's convention: activation maps are NCHW, a map is
partitioned into non-overlapping ``block x block`` spatial tiles (Fig. 1),
and a tile is a *zero block* iff its maximum is below the per-channel
threshold ``T_{l,c}`` (Sec. II-A).
"""

from __future__ import annotations

import jax.numpy as jnp


def block_max_ref(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """Per-block maxima of NCHW activations.

    Args:
      x: (N, C, H, W) activation maps; H and W must be divisible by block.
      block: spatial block side B.

    Returns:
      (N, C, H // B, W // B) array of per-block maxima.
    """
    n, c, h, w = x.shape
    if h % block or w % block:
        raise ValueError(f"H={h}, W={w} not divisible by block={block}")
    xb = x.reshape(n, c, h // block, block, w // block, block)
    return xb.max(axis=(3, 5))


def zebra_prune_ref(
    x: jnp.ndarray, thresholds: jnp.ndarray, block: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference Zebra block pruning (paper Sec. II, inference rule).

    A block survives iff ``max(block) > T_c`` for its channel's threshold;
    otherwise every element in the block is forced to zero. The comparison
    is strict so that at ``T_obj = 0`` the *naturally* zero blocks ReLU
    produces are flagged in the mask — that is exactly the paper's
    ``T_obj = 0`` rows in Tables II/III (16.7% reduction for VGG16 with no
    learned sparsity at all).

    Args:
      x: (N, C, H, W) activations.
      thresholds: broadcastable to (N, C) — scalar, (C,), or (N, C).
      block: block side B.

    Returns:
      (pruned, mask) where pruned has x's shape and mask is
      (N, C, H//B, W//B) float32 in {0, 1} (1 = block kept).
    """
    n, c, h, w = x.shape
    bmax = block_max_ref(x, block)  # (N, C, H/B, W/B)
    t = jnp.broadcast_to(jnp.asarray(thresholds, x.dtype), (n, c))
    mask = (bmax > t[:, :, None, None]).astype(x.dtype)
    up = jnp.repeat(jnp.repeat(mask, block, axis=2), block, axis=3)
    return x * up, mask.astype(jnp.float32)


def relu_zebra_ref(
    x: jnp.ndarray, thresholds: jnp.ndarray, block: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ReLU + Zebra pruning reference ("after activation functions")."""
    return zebra_prune_ref(jnp.maximum(x, 0.0), thresholds, block)


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """f32-accumulating GEMM reference for the MXU-tiled Pallas kernel."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def zero_block_fraction_ref(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """Fraction of all-zero blocks (Table I statistic) for NCHW maps.

    Note this is the *natural* zero-block rate: a block counts as zero iff
    every element is exactly zero (what ReLU alone produces), independent
    of any threshold.
    """
    bmax = block_max_ref(jnp.abs(x), block)
    return jnp.mean((bmax == 0.0).astype(jnp.float32))


def gap_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Global average pooling, (N, C, H, W) -> (N, C)."""
    return x.mean(axis=(2, 3))
