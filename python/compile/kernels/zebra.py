"""Pallas kernels for the Zebra zero-block pruning op (paper Sec. II).

The op sits on the accelerator's activation write-back path: given an
NCHW activation map and a per-channel threshold, zero every non-
overlapping ``B x B`` spatial block whose maximum is below the threshold
and emit a {0,1} block mask (the 1-bit-per-block DRAM index of Eq. 3).

TPU mapping (DESIGN.md §8): the grid walks (flattened N*C maps,
block-rows); each step holds one ``(B, W)`` stripe in VMEM, reduces it to
``W/B`` block maxima with a VPU max over a reshaped view, applies the
mask in-register, and writes the pruned stripe back — i.e. pruning
happens *before* the HBM write, the TPU analogue of pruning before the
paper's DRAM spill. No MXU involvement; the op is bandwidth-bound by
construction (Eq. 5: one max per element).

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot
run Mosaic custom-calls, and correctness is what we validate here. Real-
TPU performance is estimated from the VMEM footprint in DESIGN.md §11.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _zebra_kernel(x_ref, t_ref, o_ref, m_ref, *, block: int, relu: bool):
    """One grid step: prune one (B, W) stripe of one (n, c) map.

    x_ref: (1, B, W) activation stripe.
    t_ref: (1, 1) this map's channel threshold.
    o_ref: (1, B, W) pruned stripe.
    m_ref: (1, 1, W // B) block mask for this stripe (f32 {0, 1}).
    """
    x = x_ref[...]  # (1, B, W)
    if relu:
        x = jnp.maximum(x, 0.0)
    _, b, w = x.shape
    nblk = w // block
    # (1, B, W) -> (1, B, W/B, B) -> per-block max over the B x B window.
    xb = x.reshape(1, b, nblk, block)
    bmax = xb.max(axis=(1, 3))  # (1, W/B)
    # Strict compare: a block dies iff max <= T, so T=0 flags the natural
    # zero blocks ReLU produces (paper's T_obj=0 rows in Tables II/III).
    keep = (bmax > t_ref[0, 0]).astype(x.dtype)  # (1, W/B)
    m_ref[...] = keep[:, None, :].astype(jnp.float32)
    # Upsample the mask across the stripe and apply while resident in VMEM.
    up = jnp.repeat(keep, block, axis=1)  # (1, W)
    o_ref[...] = x * up[:, None, :]


def _call_zebra(
    x: jnp.ndarray, thresholds: jnp.ndarray, block: int, relu: bool
) -> tuple[jnp.ndarray, jnp.ndarray]:
    n, c, h, w = x.shape
    if h % block or w % block:
        raise ValueError(f"H={h}, W={w} not divisible by block={block}")
    nc = n * c
    xf = x.reshape(nc, h, w)
    t = jnp.broadcast_to(jnp.asarray(thresholds, x.dtype), (n, c))
    tf = t.reshape(nc, 1)
    hb, wb = h // block, w // block

    kern = functools.partial(_zebra_kernel, block=block, relu=relu)
    pruned, mask = pl.pallas_call(
        kern,
        grid=(nc, hb),
        in_specs=[
            pl.BlockSpec((1, block, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, wb), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nc, h, w), x.dtype),
            jax.ShapeDtypeStruct((nc, hb, wb), jnp.float32),
        ],
        interpret=True,
    )(xf, tf)
    return pruned.reshape(n, c, h, w), mask.reshape(n, c, hb, wb)


def _upsample_mask(mask: jnp.ndarray, block: int, dtype) -> jnp.ndarray:
    return jnp.repeat(
        jnp.repeat(mask.astype(dtype), block, axis=2), block, axis=3
    )


# ``pallas_call`` has no reverse-mode rule, so the public ops carry a
# custom VJP — the standard way production kernels (e.g. flash attention)
# ship. The backward pass is the straight-through estimator the paper's
# training needs: gradient flows unchanged through surviving blocks and
# is zero elsewhere; the threshold receives NO gradient from the mask
# (it is trained purely by the Eq. 1 regularizer, which is why it
# converges to T_obj — paper Fig. 3).

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def zebra_prune(
    x: jnp.ndarray, thresholds: jnp.ndarray, block: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Zero-block pruning of NCHW activations (inference rule, Fig. 3).

    Args:
      x: (N, C, H, W) activations; H, W divisible by ``block``.
      thresholds: broadcastable to (N, C); typically the scalar ``T_obj``.
      block: block side B (paper uses 2/4 on CIFAR, 8 on Tiny-ImageNet).

    Returns:
      (pruned, mask): pruned activations (same shape) and the
      (N, C, H/B, W/B) f32 {0,1} keep-mask (Eq. 3's 1-bit index).
    """
    return _call_zebra(x, thresholds, block, relu=False)


def _zebra_prune_fwd(x, thresholds, block):
    pruned, mask = _call_zebra(x, thresholds, block, relu=False)
    return (pruned, mask), (mask, jnp.zeros_like(thresholds))


def _zebra_prune_bwd(block, res, cts):
    mask, zero_t = res
    g_pruned, _ = cts  # the {0,1} mask output is piecewise constant
    gx = g_pruned * _upsample_mask(mask, block, g_pruned.dtype)
    return gx, zero_t


zebra_prune.defvjp(_zebra_prune_fwd, _zebra_prune_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def relu_zebra(
    x: jnp.ndarray, thresholds: jnp.ndarray, block: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ReLU + Zebra prune — the op as deployed after activations."""
    return _call_zebra(x, thresholds, block, relu=True)


def _relu_zebra_fwd(x, thresholds, block):
    pruned, mask = _call_zebra(x, thresholds, block, relu=True)
    return (pruned, mask), (mask, x > 0, jnp.zeros_like(thresholds))


def _relu_zebra_bwd(block, res, cts):
    mask, pos, zero_t = res
    g_pruned, _ = cts
    d = g_pruned.dtype
    gx = g_pruned * _upsample_mask(mask, block, d) * pos.astype(d)
    return gx, zero_t


relu_zebra.defvjp(_relu_zebra_fwd, _relu_zebra_bwd)


def _block_max_kernel(x_ref, o_ref, *, block: int):
    x = x_ref[...]  # (1, B, W)
    _, b, w = x.shape
    xb = x.reshape(1, b, w // block, block)
    o_ref[...] = xb.max(axis=(1, 3))[:, None, :]


def block_max(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """Per-block maxima, (N, C, H, W) -> (N, C, H/B, W/B).

    The training-mode Zebra layer uses this (through L2) to compare block
    importance against the learned threshold; it is also the entire
    run-time computation overhead of Eq. 5.
    """
    n, c, h, w = x.shape
    if h % block or w % block:
        raise ValueError(f"H={h}, W={w} not divisible by block={block}")
    nc = n * c
    hb, wb = h // block, w // block
    out = pl.pallas_call(
        functools.partial(_block_max_kernel, block=block),
        grid=(nc, hb),
        in_specs=[pl.BlockSpec((1, block, w), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((1, 1, wb), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, hb, wb), x.dtype),
        interpret=True,
    )(x.reshape(nc, h, w))
    return out.reshape(n, c, hb, wb)
