"""MXU-tiled GEMM Pallas kernel — the CNN compute hot-spot.

L2 lowers every convolution to im2col + this GEMM so the model's FLOPs
run through one Pallas kernel. Tiles default to the MXU-native
``128 x 128`` with f32 accumulation (`preferred_element_type`), the TPU
analogue of the paper's PE-array matmul.

The grid is (M/bm, N/bn); the full K panel of each operand is resident
per step, which keeps the kernel scratch-free (interpret mode has no
VMEM scratch) while still expressing the HBM->VMEM schedule via
BlockSpec. DESIGN.md §11 records the VMEM footprint per tile choice.

interpret=True throughout — see kernels/zebra.py for why.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def matmul(a: jnp.ndarray, b: jnp.ndarray, bm: int = 128, bn: int = 128):
    """Tiled GEMM: (M, K) @ (K, N) -> (M, N) with f32 accumulation.

    Operands are zero-padded up to tile multiples and the result cropped,
    so arbitrary shapes are accepted (conv im2col rarely lands on 128s).

    ``pallas_call`` has no reverse-mode rule, so this op carries a custom
    VJP whose backward GEMMs also run through this kernel — the whole
    training step's FLOPs stay on the MXU path.
    """
    return _matmul_impl(a, b, bm, bn)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def _matmul_impl(
    a: jnp.ndarray, b: jnp.ndarray, bm: int = 128, bn: int = 128
) -> jnp.ndarray:
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    bm_ = min(bm, _ceil_mult(m, 8))
    bn_ = min(bn, _ceil_mult(n, 8))
    mp, np_ = _ceil_to(m, bm_), _ceil_to(n, bn_)
    ap = _pad_to(a, mp, k)
    bp = _pad_to(b, k, np_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm_, np_ // bn_),
        in_specs=[
            pl.BlockSpec((bm_, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn_), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def _matmul_fwd(a, b, bm, bn):
    return _matmul_impl(a, b, bm, bn), (a, b)


def _matmul_bwd(bm, bn, res, g):
    a, b = res
    ga = _matmul_impl(g, b.T, bm, bn).astype(a.dtype)
    gb = _matmul_impl(a.T, g, bm, bn).astype(b.dtype)
    return ga, gb


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _ceil_mult(x: int, m: int) -> int:
    """Smallest multiple of m >= x (used to shrink tiles for tiny GEMMs)."""
    return _ceil_to(max(x, 1), m)
