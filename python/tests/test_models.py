"""L2 model zoo: shapes, spill plans, backend equivalence, zebra modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, models

jax.config.update("jax_platform_name", "cpu")

ARCHS = ["vgg16", "resnet18", "resnet56", "mobilenet"]


def tiny_setup(arch, hw=32, classes=4, width=0.1, block=4, t_obj=0.1):
    spec = models.make_spec(arch, classes, width)
    params = models.init(jax.random.PRNGKey(0), spec, hw, block, t_obj)
    return spec, params


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    spec, params = tiny_setup(arch)
    x = jnp.zeros((2, 3, 32, 32))
    logits, _, aux = models.apply(
        params, spec, x, train=False, zebra_mode="infer", t_obj=0.1,
        default_block=4)
    assert logits.shape == (2, 4)
    assert len(aux["masks"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_spill_plan_matches_apply(arch):
    spec, params = tiny_setup(arch)
    x = jnp.zeros((1, 3, 32, 32))
    _, _, aux = models.apply(
        params, spec, x, train=False, zebra_mode="infer", t_obj=0.1,
        default_block=4, keep_spills=True)
    plan = models.spill_plan(spec, 32, 4)
    assert len(plan) == len(aux["spills"])
    for info, spill in zip(plan, aux["spills"]):
        assert spill.shape[1:] == (info.c, info.h, info.w), info.name
    # Mask shapes match the plan's block grid.
    for info, mask in zip(plan, aux["masks"]):
        assert mask.shape[1:] == (
            info.c, info.h // info.block, info.w // info.block), info.name


def test_block_size_rule():
    assert models.zebra_block_for(32, 4) == 4
    assert models.zebra_block_for(2, 4) == 2  # paper: shrink on 2x2 maps
    assert models.zebra_block_for(1, 8) == 1
    assert models.zebra_block_for(64, 8) == 8


def test_width_scaling():
    wide = models.make_spec("resnet18", 10, 1.0)
    thin = models.make_spec("resnet18", 10, 0.25)
    w = [s["cout"] for s in wide if "cout" in s and s["kind"] != "fc"]
    t = [s["cout"] for s in thin if "cout" in s and s["kind"] != "fc"]
    assert all(a == 4 * b for a, b in zip(w, t))


def test_backends_agree():
    spec, params = tiny_setup("resnet18", width=0.1)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 3, 32, 32))
    lx, _, _ = models.apply(params, spec, x, train=False,
                            zebra_mode="infer", t_obj=0.1, default_block=4,
                            backend="xla")
    lp, _, _ = models.apply(params, spec, x, train=False,
                            zebra_mode="infer", t_obj=0.1, default_block=4,
                            backend="pallas", zebra_backend="pallas")
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=1e-4, atol=1e-4)


def test_zebra_modes_differ_only_by_pruning():
    spec, params = tiny_setup("resnet18", width=0.1)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 3, 32, 32))
    l_off, _, aux_off = models.apply(
        params, spec, x, train=False, zebra_mode="off", t_obj=0.0,
        default_block=4, keep_spills=True)
    l_inf, _, aux_inf = models.apply(
        params, spec, x, train=False, zebra_mode="infer", t_obj=1e9,
        default_block=4, keep_spills=True)
    # A huge threshold prunes everything -> all spills zero.
    for sp in aux_inf["spills"]:
        assert float(jnp.abs(sp).sum()) == 0.0
    # T=0 equals plain ReLU output on every spill.
    _, _, aux0 = models.apply(
        params, spec, x, train=False, zebra_mode="infer", t_obj=0.0,
        default_block=4, keep_spills=True)
    for a, b in zip(aux_off["spills"], aux0["spills"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    assert not np.allclose(np.asarray(l_off), np.asarray(l_inf))


def test_train_mode_emits_thresholds():
    spec, params = tiny_setup("resnet18", width=0.1)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 32, 32))
    _, _, aux = models.apply(
        params, spec, x, train=True, zebra_mode="train", t_obj=0.1,
        default_block=4)
    assert len(aux["ts"]) == len(aux["masks"])
    for t in aux["ts"]:
        assert t.shape[0] == 2
        assert float(t.min()) >= 0.0 and float(t.max()) <= 1.0
        # Initialized near T_obj (threshold net starts at the fixed point).
        np.testing.assert_allclose(np.asarray(t), 0.1, atol=0.05)


def test_bn_stats_update_only_in_training():
    spec, params = tiny_setup("resnet18", width=0.1)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 3, 32, 32))
    _, p_train, _ = models.apply(params, spec, x, train=True,
                                 zebra_mode="off", t_obj=0.0,
                                 default_block=4)
    _, p_eval, _ = models.apply(params, spec, x, train=False,
                                zebra_mode="off", t_obj=0.0,
                                default_block=4)
    moved = np.abs(np.asarray(p_train["s0"]["bn"]["mean"])
                   - np.asarray(params["s0"]["bn"]["mean"])).max()
    frozen = np.abs(np.asarray(p_eval["s0"]["bn"]["mean"])
                    - np.asarray(params["s0"]["bn"]["mean"])).max()
    assert moved > 0.0
    assert frozen == 0.0


def test_dataset_generator_properties():
    (xtr, ytr), (xte, yte) = data.synth_cifar(64, 32, seed=3)
    assert xtr.shape == (64, 3, 32, 32)
    assert set(np.unique(ytr)) <= set(range(10))
    # Deterministic per seed.
    (xtr2, ytr2), _ = data.synth_cifar(64, 32, seed=3)
    np.testing.assert_array_equal(xtr, xtr2)
    np.testing.assert_array_equal(ytr, ytr2)
    # Different seeds differ.
    (xtr3, _), _ = data.synth_cifar(64, 32, seed=4)
    assert np.abs(xtr - xtr3).max() > 0.1
    # Tiny variant: higher res, 20 classes.
    (xt, yt), _ = data.synth_tiny(20, 10, seed=5)
    assert xt.shape == (20, 3, 64, 64)
    assert yt.max() < 20
