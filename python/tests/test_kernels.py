"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

This is the CORE correctness signal for the compile path: hypothesis
sweeps shapes, dtypes, block sizes and threshold layouts, asserting
allclose against ``kernels/ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref, zebra

jax.config.update("jax_platform_name", "cpu")

F32 = jnp.float32
BF16 = jnp.bfloat16


def rand(key, shape, dtype=F32, scale=1.0):
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


# ---------------------------------------------------------------- zebra

nchw_cases = st.tuples(
    st.integers(1, 3),                      # N
    st.integers(1, 6),                      # C
    st.sampled_from([2, 4, 8, 16, 32]),     # H
    st.sampled_from([2, 4, 8, 16, 32]),     # W
    st.sampled_from([2, 4, 8]),             # block
    st.integers(0, 2**31 - 1),              # seed
).filter(lambda t: t[2] % t[4] == 0 and t[3] % t[4] == 0)


@settings(max_examples=40, deadline=None)
@given(nchw_cases)
def test_zebra_prune_matches_ref(case):
    n, c, h, w, b, seed = case
    key = jax.random.PRNGKey(seed)
    x = rand(key, (n, c, h, w))
    t = jax.random.uniform(jax.random.fold_in(key, 1), (c,))
    got_x, got_m = zebra.zebra_prune(x, t, b)
    ref_x, ref_m = ref.zebra_prune_ref(x, t, b)
    np.testing.assert_allclose(got_x, ref_x, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(ref_m))


@settings(max_examples=40, deadline=None)
@given(nchw_cases)
def test_relu_zebra_matches_ref(case):
    n, c, h, w, b, seed = case
    key = jax.random.PRNGKey(seed)
    x = rand(key, (n, c, h, w))
    t = jax.random.uniform(jax.random.fold_in(key, 1), (n, c))
    got_x, got_m = zebra.relu_zebra(x, t, b)
    ref_x, ref_m = ref.relu_zebra_ref(x, t, b)
    np.testing.assert_allclose(got_x, ref_x, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(ref_m))


@settings(max_examples=30, deadline=None)
@given(nchw_cases)
def test_block_max_matches_ref(case):
    n, c, h, w, b, seed = case
    x = rand(jax.random.PRNGKey(seed), (n, c, h, w))
    np.testing.assert_allclose(
        zebra.block_max(x, b), ref.block_max_ref(x, b), rtol=1e-6
    )


def test_zebra_scalar_threshold_broadcasts():
    x = rand(jax.random.PRNGKey(0), (2, 4, 8, 8))
    got_x, got_m = zebra.zebra_prune(x, 0.25, 4)
    ref_x, ref_m = ref.zebra_prune_ref(x, 0.25, 4)
    np.testing.assert_allclose(got_x, ref_x, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(ref_m))


def test_zebra_zero_threshold_keeps_positive_blocks():
    # T=0: after ReLU a block dies only if it is entirely <= 0.
    x = jnp.full((1, 1, 4, 4), -1.0, F32).at[0, 0, 0, 0].set(2.0)
    pruned, mask = zebra.relu_zebra(x, 0.0, 2)
    m = np.asarray(mask)[0, 0]
    assert m[0, 0] == 1.0 and m.sum() == 1.0
    assert float(jnp.sum(pruned)) == 2.0


def test_zebra_huge_threshold_prunes_everything():
    x = rand(jax.random.PRNGKey(1), (1, 2, 8, 8), scale=0.1)
    pruned, mask = zebra.relu_zebra(x, 1e9, 4)
    assert float(jnp.abs(pruned).sum()) == 0.0
    assert float(mask.sum()) == 0.0


def test_zebra_idempotent():
    x = rand(jax.random.PRNGKey(2), (1, 3, 16, 16))
    p1, m1 = zebra.relu_zebra(x, 0.4, 4)
    p2, m2 = zebra.zebra_prune(p1, 0.4, 4)
    np.testing.assert_allclose(p1, p2, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_zebra_mask_monotone_in_threshold():
    x = rand(jax.random.PRNGKey(3), (2, 4, 16, 16))
    masks = [
        np.asarray(zebra.relu_zebra(x, t, 4)[1]) for t in (0.0, 0.2, 0.5, 1.0)
    ]
    for lo, hi in zip(masks[1:], masks[:-1]):
        assert np.all(lo <= hi), "higher threshold must prune a superset"


def test_zebra_rejects_indivisible_shapes():
    x = jnp.zeros((1, 1, 6, 8), F32)
    with pytest.raises(ValueError):
        zebra.zebra_prune(x, 0.1, 4)


def test_zebra_bfloat16():
    x = rand(jax.random.PRNGKey(4), (1, 2, 8, 8), BF16)
    got_x, got_m = zebra.zebra_prune(x, 0.3, 2)
    ref_x, ref_m = ref.zebra_prune_ref(x, 0.3, 2)
    np.testing.assert_allclose(
        np.asarray(got_x, np.float32), np.asarray(ref_x, np.float32), rtol=1e-2
    )
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(ref_m))


def test_zebra_grad_is_straight_through_on_kept_blocks():
    # d/dx sum(prune(x)) == upsampled mask: 1 on kept blocks, 0 on pruned.
    x = rand(jax.random.PRNGKey(5), (1, 2, 8, 8))
    g = jax.grad(lambda v: zebra.zebra_prune(v, 0.5, 4)[0].sum())(x)
    _, mask = zebra.zebra_prune(x, 0.5, 4)
    up = np.repeat(np.repeat(np.asarray(mask), 4, axis=2), 4, axis=3)
    np.testing.assert_allclose(np.asarray(g), up, rtol=1e-6)


# --------------------------------------------------------------- matmul

mm_cases = st.tuples(
    st.integers(1, 200),        # M
    st.integers(1, 64),         # K
    st.integers(1, 200),        # N
    st.integers(0, 2**31 - 1),  # seed
)


@settings(max_examples=30, deadline=None)
@given(mm_cases)
def test_matmul_matches_ref(case):
    m, k, n, seed = case
    key = jax.random.PRNGKey(seed)
    a = rand(key, (m, k))
    b = rand(jax.random.fold_in(key, 1), (k, n))
    np.testing.assert_allclose(
        matmul.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
    )


def test_matmul_mxu_aligned_tiles():
    key = jax.random.PRNGKey(7)
    a = rand(key, (256, 128))
    b = rand(jax.random.fold_in(key, 1), (128, 256))
    np.testing.assert_allclose(
        matmul.matmul(a, b, bm=128, bn=128),
        ref.matmul_ref(a, b),
        rtol=1e-4,
        atol=1e-4,
    )


def test_matmul_bf16_accumulates_in_f32():
    key = jax.random.PRNGKey(8)
    a = rand(key, (64, 512), BF16)
    b = rand(jax.random.fold_in(key, 1), (512, 64), BF16)
    got = np.asarray(matmul.matmul(a, b), np.float32)
    want = np.asarray(ref.matmul_ref(a, b), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_matmul_rejects_mismatched_inner():
    with pytest.raises(ValueError):
        matmul.matmul(jnp.zeros((4, 5)), jnp.zeros((6, 4)))


def test_matmul_is_differentiable():
    key = jax.random.PRNGKey(9)
    a = rand(key, (16, 8))
    b = rand(jax.random.fold_in(key, 1), (8, 16))
    ga = jax.grad(lambda u: matmul.matmul(u, b).sum())(a)
    np.testing.assert_allclose(
        np.asarray(ga), np.asarray(jnp.ones((16, 16)) @ b.T), rtol=1e-4
    )


# ----------------------------------------------------- table-I statistic

def test_zero_block_fraction_orders_with_block_size():
    # Smaller blocks always have >= the zero-block fraction of larger
    # blocks on the same map (a zero 4x4 block is four zero 2x2 blocks,
    # but not vice versa) — the ordering behind paper Table I.
    x = np.asarray(
        jax.random.normal(jax.random.PRNGKey(11), (4, 8, 16, 16))
    )
    x = np.maximum(x, 0.0)  # ReLU sparsity
    f2 = float(ref.zero_block_fraction_ref(jnp.asarray(x), 2))
    f4 = float(ref.zero_block_fraction_ref(jnp.asarray(x), 4))
    f8 = float(ref.zero_block_fraction_ref(jnp.asarray(x), 8))
    assert f2 >= f4 >= f8
