"""Trainer: Eq. 1 dynamics, NS/WP masking, bandwidth accounting, AOT."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, data, models, trace, zebra_layer
from compile import train as T

jax.config.update("jax_platform_name", "cpu")


def tiny_cfg(**kw):
    base = dict(arch="resnet18", dataset="cifar10", width=0.1, t_obj=0.1,
                steps=8, batch=8, n_train=32, n_test=16, seed=1)
    base.update(kw)
    return T.TrainConfig(**base)


def test_loss_decreases_and_thresholds_converge():
    res = T.train(tiny_cfg(steps=30, n_train=64), log=False)
    hist = res["history"]
    assert hist["loss"][-1] < hist["loss"][0]
    # Thresholds stay pinned to T_obj (Eq. 1 regularizer, Fig. 3).
    assert abs(hist["mean_t"][-1] - 0.1) < 0.03
    assert "reduced_pct" in res["eval"]


def test_zebra_off_baseline_trains():
    res = T.train(tiny_cfg(zebra=False), log=False)
    assert res["eval"]["top1"] >= 0.0
    # Baselines are evaluated at T=0: natural zero blocks only.
    assert res["eval"]["reduced_pct"] >= 0.0


def test_regularizer_pulls_threshold_to_tobj():
    t1 = zebra_layer.regularizer([jnp.full((2, 3), 0.5)], 0.5)
    t2 = zebra_layer.regularizer([jnp.full((2, 3), 0.9)], 0.5)
    assert float(t1) == 0.0
    assert float(t2) > 0.0
    assert float(zebra_layer.regularizer([], 0.5)) == 0.0


def test_weight_pruning_masks_are_global_magnitude():
    spec = models.make_spec("resnet18", 4, 0.1)
    params = models.init(jax.random.PRNGKey(0), spec, 32, 4, 0.1)
    masks = T.weight_prune_masks(params, 0.5)
    zeros = kept = 0
    for path, leaf in T._tree_paths(masks):
        if leaf is None:
            continue
        arr = np.asarray(leaf)
        zeros += (arr == 0).sum()
        kept += (arr == 1).sum()
    frac = zeros / (zeros + kept)
    assert 0.45 < frac < 0.55, f"pruned fraction {frac}"
    pruned = T.apply_weight_masks(params, masks)
    w0 = np.asarray(pruned["s0"]["conv"]["w"])
    m0 = np.asarray(masks["s0"]["conv"]["w"])
    assert np.all((w0 == 0) | (m0 == 1))


def test_network_slimming_zeroes_channels():
    spec = models.make_spec("resnet18", 4, 0.1)
    params = models.init(jax.random.PRNGKey(0), spec, 32, 4, 0.1)
    # Make one channel's gamma clearly the smallest everywhere.
    params["s0"]["bn"]["gamma"] = params["s0"]["bn"]["gamma"].at[0].set(1e-6)
    masks = T.slim_masks(params, 0.3)
    slimmed = T.apply_slim_masks(params, masks)
    assert float(slimmed["s0"]["bn"]["gamma"][0]) == 0.0
    assert float(slimmed["s0"]["bn"]["beta"][0]) == 0.0
    # A zeroed BN channel emits exactly zero post-ReLU -> prunable maps.
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 3, 32, 32))
    _, _, aux = models.apply(slimmed, spec, x, train=False,
                             zebra_mode="infer", t_obj=0.0,
                             default_block=4, keep_spills=True)
    ch0 = np.asarray(aux["spills"][0])[:, 0]
    assert np.abs(ch0).max() == 0.0


def test_bandwidth_stats_match_formula():
    mask = jnp.ones((2, 4, 8, 8)).at[:, :2].set(0.0)  # half the blocks
    stats = T.bandwidth_stats([mask], [4])
    nblocks = 4 * 8 * 8
    assert stats["required_bytes"] == nblocks * 16 * 4
    assert stats["kept_bytes"] == stats["required_bytes"] / 2
    assert stats["overhead_bytes"] == nblocks / 8
    assert 0 < stats["reduced_pct"] < 50


def test_aot_export_roundtrip(tmp_path):
    spec = models.make_spec("resnet18", 4, 0.1)
    params = models.init(jax.random.PRNGKey(0), spec, 32, 4, 0.1)
    out = tmp_path / "m.hlo.txt"
    wdir = tmp_path / "weights"
    meta = aot.export_model(
        params, spec, batch=1, hw=32, t_obj=0.1, default_block=4,
        zebra=True, out_path=str(out), weights_dir=str(wdir))
    text = out.read_text()
    assert text.startswith("HloModule")
    assert meta["n_outputs"] == 1 + len(meta["masks"])
    # Weight files cover every leaf, in flatten order, with no elision.
    leaves = jax.tree_util.tree_flatten(params)[0]
    assert meta["n_weights"] == len(leaves)
    files = sorted(os.listdir(wdir))
    assert len(files) == len(leaves)
    w0 = trace.read_zten(str(wdir / "w00000.zten"))
    assert w0.size == np.asarray(leaves[0]).size


def test_zten_roundtrip(tmp_path):
    arr = np.random.default_rng(0).normal(size=(2, 3, 4, 4)).astype(np.float32)
    p = str(tmp_path / "t.zten")
    trace.write_zten(p, arr)
    np.testing.assert_array_equal(trace.read_zten(p), arr)
    u8 = (np.abs(arr[0]) * 50).astype(np.uint8)
    trace.write_zten(p, u8)
    np.testing.assert_array_equal(trace.read_zten(p), u8)


def test_eval_pads_ragged_tail():
    cfg = tiny_cfg(n_test=10)  # not a multiple of eval batch
    ds = data.DATASETS["cifar10"]
    spec = models.make_spec(cfg.arch, ds["classes"], cfg.width)
    params = models.init(jax.random.PRNGKey(0), spec, 32, 4, cfg.t_obj)
    _, (xte, yte) = ds["make"](16, 10, seed=9)
    out = T.evaluate(params, spec, cfg, xte, yte, 4, batch=8)
    assert 0.0 <= out["top1"] <= 100.0
    assert out["required_bytes"] > 0
