#!/usr/bin/env bash
# rust/obs_smoke.sh — observability smoke gate: a loopback cluster
# (worker + router, ephemeral ports) with distributed tracing sampled
# 1-in-4 at the loadgen edge, a deliberately tiny admission budget so
# overload sheds are certain, and flight recorders on both nodes.
# Passes only when the whole observability plane holds together:
#
#   - loadgen's traced run completes with sheds and zero faults;
#   - the router's terminal shed events dumped a flight ring to
#     --flight-dir, and `zebra obs replay` parses it strictly
#     (JSON-lines) and renders shed events + trace waterfalls;
#   - `zebra obs --addr ROUTER` serves the unified report as both
#     Prometheus text and JSON, with the bandwidth-ledger families in
#     the Prometheus scrape;
#   - `zebra top --json` once-mode succeeds against the loopback
#     cluster;
#   - `--bench-json` (via ZEBRA_BENCH_OUT) emitted BENCH_PR9.json
#     with the ledger and SLO sections.
#
# `make obs-smoke` runs this; rust/check.sh and
# .github/workflows/ci.yml invoke that target.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release --no-default-features
BIN=target/release/zebra

tmp=$(mktemp -d)
pids=()
cleanup() {
  for p in ${pids[@]+"${pids[@]}"}; do kill "$p" 2>/dev/null || true; done
  rm -rf "$tmp"
}
trap cleanup EXIT

# Harvest the "... listening on HOST:PORT" line a node prints.
wait_addr() {
  local log="$1" i addr
  for i in $(seq 1 100); do
    addr=$(sed -n 's/.*listening on \([0-9.]*:[0-9]*\).*/\1/p' "$log" | head -n1)
    if [ -n "$addr" ]; then
      echo "$addr"
      return 0
    fi
    sleep 0.1
  done
  echo "timed out waiting for an address in $log" >&2
  cat "$log" >&2
  return 1
}

# --run-s bounds every node's lifetime so a wedged smoke run cannot
# outlive CI even if the cleanup trap is skipped.
"$BIN" cluster-worker --model ref-tiny --flush-us 2000 --max-batch 4 \
  --flight-dir "$tmp/fl" --port 0 --run-s 120 >"$tmp/w1.log" 2>&1 &
pids+=($!)
W1=$(wait_addr "$tmp/w1.log")

# --max-outstanding 2 --max-attempts 1 makes sheds certain (same
# recipe as loadgen_smoke.sh), and every shed is a terminal event that
# dumps the router's flight ring to --flight-dir.
"$BIN" cluster-router --workers "$W1" --max-outstanding 2 \
  --max-attempts 1 --flight-dir "$tmp/fl" --port 0 --run-s 120 \
  >"$tmp/r.log" 2>&1 &
pids+=($!)
R=$(wait_addr "$tmp/r.log")

# The loadgen edge assigns trace ids, samples 1-in-4, polls the live
# report every 25 ms, and writes the machine-readable run summary.
ZEBRA_BENCH_SMOKE=1 ZEBRA_BENCH_OUT="$tmp/BENCH_PR9.json" \
  "$BIN" loadgen --addr "$R" --requests 240 --conns 8 \
  --priority mixed --keys 4 --hw 8 --trace-sample 4 --scrape-ms 25 \
  --expect-sheds --fail-on-error

# BENCH_PR9.json: emitted where ZEBRA_BENCH_OUT pointed, with the
# run summary + the scraped time series + the cluster report + the
# per-layer bandwidth ledger and SLO breach counts.
test -s "$tmp/BENCH_PR9.json"
grep -q '"bench"' "$tmp/BENCH_PR9.json"
grep -q '"trace"' "$tmp/BENCH_PR9.json"
grep -q '"scrape"' "$tmp/BENCH_PR9.json"
grep -q '"ledger"' "$tmp/BENCH_PR9.json"
grep -q '"slo"' "$tmp/BENCH_PR9.json"

# Flight dump: the sheds above are terminal events, so the router must
# have dumped its ring. `zebra obs replay` parses the JSON-lines
# strictly (any malformed line is a hard error) and renders it.
FLIGHT="$tmp/fl/flight-router.jsonl"
test -s "$FLIGHT"
"$BIN" obs replay "$FLIGHT" >"$tmp/replay.txt"
grep -q 'shed_' "$tmp/replay.txt"
grep -q 'terminal events' "$tmp/replay.txt"

# Unified export plane, both renderings, against the live router.
"$BIN" obs --addr "$R" >"$tmp/obs.prom"
grep -q '^zebra_responses_total' "$tmp/obs.prom"
grep -q '^zebra_stage_nanos_total{stage="router.dispatch"}' "$tmp/obs.prom"
# The bandwidth ledger rides the same scrape as its own families
# (the worker's per-layer cells, merged through the router).
grep -q '^zebra_ledger_dense_bytes_total' "$tmp/obs.prom"
grep -q '^zebra_ledger_savings_pct' "$tmp/obs.prom"
"$BIN" obs --addr "$R" --json >"$tmp/obs.json"
grep -q '"counters"' "$tmp/obs.json"
grep -q '"telemetry"' "$tmp/obs.json"
grep -q '"ledger"' "$tmp/obs.json"

# zebra top once-mode: one scrape, the full JSON report, no redraw
# loop — the same path the live dashboard polls.
"$BIN" top --addr "$R" --json >"$tmp/top.json"
grep -q '"ledger"' "$tmp/top.json"
grep -q '"slo"' "$tmp/top.json"

echo "obs smoke OK (router $R, worker $W1: traces sampled, sheds in the flight dump, ledger + top on the obs scrape)"
