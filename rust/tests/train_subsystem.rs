//! Integration: the native train subsystem end to end —
//! train -> `.zten` artifact -> serve on the reference backend — plus
//! the optimization-sanity gates (loss decrease, lambda's effect on
//! the zero-block ratio) the CLI acceptance run relies on.

use zebra::backend::reference::{RefSpec, ReferenceBackend};
use zebra::backend::InferenceBackend;
use zebra::train::{train, train_on, Dataset, TrainConfig};

fn base_cfg() -> TrainConfig {
    TrainConfig {
        model: "ref-tiny".into(),
        seed: 11,
        quiet: true,
        ..TrainConfig::default()
    }
}

#[test]
fn loss_strictly_decreases_on_a_fixed_batch() {
    // 20 steps of exact full-batch gradient descent: lambda 0 and
    // T = 0 make the pruned forward identical to plain ReLU (pruning
    // at T=0 only zeroes already-zero blocks) and the STE equal to the
    // true ReLU subgradient, so each small step must strictly reduce
    // the smooth CE loss. The dataset fits in one batch, which the
    // loop runs in fixed index order.
    let cfg = TrainConfig {
        lambda: 0.0,
        t_obj: Some(0.0),
        steps: 20,
        batch: 16,
        lr: 0.01,
        momentum: 0.0,
        weight_decay: 0.0,
        ..base_cfg()
    };
    let ds = Dataset::synthetic(8, 10, 20, 11);
    let (train_ds, holdout) = ds.split(4);
    assert_eq!(train_ds.len(), 16, "one fixed full batch");
    let out = train_on(&cfg, &train_ds, &holdout).unwrap();
    assert_eq!(out.loss_history.len(), 20);
    for w in out.loss_history.windows(2) {
        assert!(
            w[1] < w[0],
            "loss must strictly decrease on a fixed batch: {:?}",
            out.loss_history
        );
    }
}

#[test]
fn lambda_raises_the_zero_block_ratio() {
    // Same data, same seeds, same budget — the only difference is the
    // zero-block regularizer. The lambda run must prune strictly more
    // blocks at the deployment threshold; that is the paper's core
    // claim and the `zebra train` acceptance gate.
    let mk = |lambda: f32| TrainConfig {
        lambda,
        steps: 40,
        batch: 8,
        n_train: 64,
        n_holdout: 32,
        ..base_cfg()
    };
    let baseline = train(&mk(0.0)).unwrap();
    let zebra_run = train(&mk(0.02)).unwrap();
    let (b, z) = (baseline.final_stat(), zebra_run.final_stat());
    assert!(
        z.zero_block_pct > b.zero_block_pct,
        "lambda=0.02 must prune more blocks: {:.1}% vs {:.1}% at lambda=0",
        z.zero_block_pct,
        b.zero_block_pct
    );
    assert!(
        z.reduced_pct > b.reduced_pct,
        "Eq.2-3 reduction must improve: {:.1}% vs {:.1}%",
        z.reduced_pct,
        b.reduced_pct
    );
    // The regularizer actually contributed to the objective.
    assert!(z.penalty > 0.0);
    assert_eq!(b.penalty, 0.0);
}

#[test]
fn trained_leaves_roundtrip_into_the_serving_backend() {
    let cfg = TrainConfig {
        lambda: 1e-3,
        steps: 12,
        batch: 8,
        n_train: 32,
        n_holdout: 8,
        ..base_cfg()
    };
    let out = train(&cfg).unwrap();
    let dir = std::env::temp_dir()
        .join(format!("zebra-train-rt-{}", std::process::id()));
    out.write_leaves(&dir).unwrap();

    // The artifact loads through the exact weights_dir path `zebra
    // serve --weights DIR` uses, and reproduces the trained model
    // bit-for-bit (f32 .zten leaves are lossless).
    let mut spec = RefSpec::from_key("ref-tiny").unwrap();
    spec.seed = cfg.seed;
    spec.weights_dir = Some(dir.clone());
    let served = ReferenceBackend::new(spec.clone()).unwrap();
    let trained =
        ReferenceBackend::from_params(out.spec.clone(), out.params.clone())
            .unwrap();
    let probe = Dataset::synthetic(8, 10, 4, 99).images;
    let a = served.execute(&probe).unwrap();
    let b = trained.execute(&probe).unwrap();
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.masks, b.masks);

    // And it differs from the untrained deterministic weights.
    let mut fresh_spec = spec;
    fresh_spec.weights_dir = None;
    let fresh = ReferenceBackend::new(fresh_spec).unwrap();
    assert_ne!(fresh.execute(&probe).unwrap().logits, a.logits);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_train_checkpoints_servable_leaves() {
    let dir = std::env::temp_dir()
        .join(format!("zebra-train-cli-{}", std::process::id()));
    let argv: Vec<String> = [
        "train",
        "--model",
        "ref-tiny",
        "--lambda",
        "0.001",
        "--steps",
        "10",
        "--batch",
        "8",
        "--train-n",
        "24",
        "--holdout",
        "8",
        "--out",
        dir.to_str().unwrap(),
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    zebra::cli::run(&argv).unwrap();
    // ref-tiny: 2 conv layers + classifier = 3 leaves.
    for i in 0..3 {
        assert!(
            dir.join(format!("w{i:05}.zten")).exists(),
            "missing leaf {i}"
        );
    }
    let mut spec = RefSpec::from_key("ref-tiny").unwrap();
    spec.weights_dir = Some(dir.clone());
    let be = ReferenceBackend::new(spec).unwrap();
    let out = be
        .execute(&Dataset::synthetic(8, 10, 2, 1).images)
        .unwrap();
    assert_eq!(out.logits.shape(), &[2, 10]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_cli_loads_trained_weights_and_honors_seed() {
    // The acceptance loop: train -> --out DIR -> serve --weights DIR,
    // with --seed steering the synthetic test set.
    let cfg = TrainConfig {
        lambda: 1e-3,
        steps: 8,
        batch: 8,
        n_train: 24,
        n_holdout: 8,
        ..base_cfg()
    };
    let out = train(&cfg).unwrap();
    let dir = std::env::temp_dir()
        .join(format!("zebra-train-serve-{}", std::process::id()));
    out.write_leaves(&dir).unwrap();
    let argv: Vec<String> = [
        "serve",
        "--backend",
        "reference",
        "--model",
        "ref-tiny",
        "--weights",
        dir.to_str().unwrap(),
        "--requests",
        "3",
        "--wait-ms",
        "0",
        "--seed",
        "123",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let args = zebra::cli::Args::parse(&argv).unwrap();
    let empty = std::env::temp_dir()
        .join(format!("zebra-train-serve-art-{}", std::process::id()));
    zebra::cli::serve::run_with(&args, empty.clone()).unwrap();
    // A missing weights dir is a loud CLI error, not a fallback.
    let mut bad = argv.clone();
    let wpos = bad.iter().position(|a| a == "--weights").unwrap();
    bad[wpos + 1] = "/nonexistent/zebra-weights".into();
    let bad_args = zebra::cli::Args::parse(&bad).unwrap();
    assert!(zebra::cli::serve::run_with(&bad_args, empty.clone()).is_err());
    // So is a PARTIAL checkpoint: delete one leaf and the explicit
    // --weights path must refuse to mix trained and generated weights.
    std::fs::remove_file(dir.join("w00001.zten")).unwrap();
    let args = zebra::cli::Args::parse(&argv).unwrap();
    let err = zebra::cli::serve::run_with(&args, empty)
        .unwrap_err()
        .to_string();
    assert!(err.contains("w00001"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn block_override_flows_through_training_and_eval() {
    // ref-tiny's layers are 8px and 4px; --block 4 is valid for both
    // and must show up in the evaluation masks' geometry.
    let cfg = TrainConfig {
        block: Some(4),
        steps: 6,
        batch: 8,
        n_train: 16,
        n_holdout: 8,
        ..base_cfg()
    };
    let out = train(&cfg).unwrap();
    assert!(out.spec.spills.iter().all(|s| s.block == 4));
    let be =
        ReferenceBackend::from_params(out.spec.clone(), out.params.clone())
            .unwrap();
    let r = be
        .execute(&Dataset::synthetic(8, 10, 1, 3).images)
        .unwrap();
    assert_eq!(r.masks[0].shape(), &[1, 8, 2, 2], "8px map / block 4");
    assert_eq!(r.masks[1].shape(), &[1, 16, 1, 1], "4px map / block 4");
    assert_eq!(r.block_elems, vec![16, 16]);
    // A non-dividing block errors instead of training garbage.
    let bad = TrainConfig { block: Some(3), ..cfg };
    assert!(train(&bad).is_err());
}
