//! Registry-wide codec coverage over degenerate shapes: every
//! registered codec must round-trip empty maps, 1x1x1 maps, all-zero
//! and fully-dense tensors through the streaming
//! `encode_into`/`decode_into` paths and the `.zspill` wire format.
//! The property/fuzz tests in `compress` drive *random realistic*
//! spills; these pin the boundary shapes they rarely generate.

use zebra::compress::{
    all_codecs, Codec, DenseCodec, EncodedView, RleZeroCodec, SpillBuf,
};
use zebra::tensor::Tensor;

/// Round-trip `x` through every registered codec at `block`, via the
/// buffer-reusing streaming API and again through `.zspill` bytes.
fn roundtrip_all(x: &Tensor, block: usize) {
    let mut buf = SpillBuf::new();
    let mut out = Tensor::zeros(&[0]);
    for codec in all_codecs(block) {
        codec.encode_into(x, &mut buf);
        codec.decode_into(buf.view(), &mut out);
        assert_eq!(
            &out,
            x,
            "codec {} (block {block}) streaming roundtrip on {:?}",
            codec.name(),
            x.shape()
        );
        let bytes = buf.view().to_bytes();
        let view = EncodedView::parse(&bytes).unwrap_or_else(|e| {
            panic!(
                "codec {} frame for {:?} must parse: {e}",
                codec.name(),
                x.shape()
            )
        });
        let mut out2 = Tensor::zeros(&[0]);
        codec.decode_into(view, &mut out2);
        assert_eq!(
            &out2,
            x,
            "codec {} (block {block}) wire roundtrip on {:?}",
            codec.name(),
            x.shape()
        );
    }
}

#[test]
fn one_by_one_by_one_maps() {
    // The smallest legal NCHW map, live and zero, at block 1.
    roundtrip_all(&Tensor::from_vec(&[1, 1, 1, 1], vec![2.5]), 1);
    roundtrip_all(&Tensor::zeros(&[1, 1, 1, 1]), 1);
    // A single pixel per map across several channels.
    let x = Tensor::from_vec(&[2, 3, 1, 1], vec![0.0, 1.0, 0.0, 3.5, 0.0, 0.0]);
    roundtrip_all(&x, 1);
}

#[test]
fn empty_maps() {
    // Zero batch, zero channels, zero spatial extent: every section
    // (payload, index, shape) degenerates without panicking.
    roundtrip_all(&Tensor::zeros(&[0, 3, 4, 4]), 2);
    roundtrip_all(&Tensor::zeros(&[1, 0, 4, 4]), 2);
    roundtrip_all(&Tensor::zeros(&[2, 2, 0, 0]), 2);
}

#[test]
fn all_zero_tensors() {
    // Fully pruned activations: zero-block and whole-map must emit
    // index-only frames; rle an empty stream.
    let x = Tensor::zeros(&[2, 3, 8, 8]);
    roundtrip_all(&x, 4);
    roundtrip_all(&x, 2);
    for codec in all_codecs(4) {
        let e = codec.encode(&x);
        if codec.name() != "dense" {
            assert!(
                e.payload.is_empty(),
                "codec {} should store nothing for all-zero input",
                codec.name()
            );
        }
    }
}

#[test]
fn fully_dense_tensors() {
    // No zeros anywhere: nothing to prune, nothing to lose.
    let n = 2 * 4 * 4;
    let x = Tensor::from_vec(
        &[1, 2, 4, 4],
        (0..n).map(|i| 0.5 + i as f32).collect(),
    );
    roundtrip_all(&x, 2);
    roundtrip_all(&x, 4);
    // Dense payload is the floor: no codec stores less than zero and
    // zero-block stores exactly dense + 1 bit per block here.
    let dense = DenseCodec.encode(&x).payload.len();
    for codec in all_codecs(2) {
        let e = codec.encode(&x);
        assert!(
            e.payload.len() >= dense || codec.name() == "rle-zero",
            "codec {} payload {} vs dense {dense}",
            codec.name(),
            e.payload.len()
        );
    }
}

#[test]
fn rankless_codecs_take_any_shape() {
    // dense and rle-zero are shape-agnostic; the block codecs require
    // NCHW and are exercised above. Empty and 1-D tensors included.
    let shapes: Vec<Tensor> = vec![
        Tensor::zeros(&[0]),
        Tensor::from_vec(&[5], vec![0.0, 1.0, 0.0, 2.0, 0.0]),
        Tensor::from_vec(&[1], vec![-7.25]),
    ];
    let mut buf = SpillBuf::new();
    let mut out = Tensor::zeros(&[0]);
    for x in &shapes {
        for codec in [&DenseCodec as &dyn Codec, &RleZeroCodec as &dyn Codec]
        {
            codec.encode_into(x, &mut buf);
            codec.decode_into(buf.view(), &mut out);
            assert_eq!(&out, x, "codec {} on {:?}", codec.name(), x.shape());
            let bytes = buf.view().to_bytes();
            let view = EncodedView::parse(&bytes).unwrap();
            let mut out2 = Tensor::zeros(&[0]);
            codec.decode_into(view, &mut out2);
            assert_eq!(&out2, x);
        }
    }
}
