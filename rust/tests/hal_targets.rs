//! HAL integration: the committed `rust/targets/` manifests on disk,
//! the embedded builtin registry, file-loading edge cases, and the
//! `zebra simulate --target` / `zebra targets` CLI paths end to end.

use std::path::PathBuf;

use zebra::hal::{
    builtin_names, builtin_targets, resolve_target, TargetManifest,
    MAX_TARGET_FILE_BYTES,
};

fn targets_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("targets")
}

/// A scratch file that cleans up after itself (tests must not litter
/// the repo checkout or temp dir).
struct ScratchFile(PathBuf);

impl ScratchFile {
    fn new(name: &str, bytes: &[u8]) -> ScratchFile {
        let p = std::env::temp_dir()
            .join(format!("zebra-hal-{}-{name}", std::process::id()));
        std::fs::write(&p, bytes).unwrap();
        ScratchFile(p)
    }
}

impl Drop for ScratchFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

#[test]
fn every_committed_manifest_loads_and_matches_its_builtin() {
    let builtins = builtin_targets().unwrap();
    let mut seen = 0;
    for entry in std::fs::read_dir(targets_dir()).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("target") {
            continue;
        }
        seen += 1;
        // Disk -> parse -> canonical text -> parse is the identity.
        let m = TargetManifest::from_file(&path)
            .unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        assert_eq!(TargetManifest::parse(&m.to_text()).unwrap(), m);
        // The embedded copy is byte-equivalent to the file on disk.
        let builtin = builtins
            .iter()
            .find(|b| b.name == m.name)
            .unwrap_or_else(|| panic!("{} is not embedded", m.name));
        assert_eq!(builtin, &m, "disk and builtin disagree for {}", m.name);
    }
    assert_eq!(
        seen,
        builtins.len(),
        "every builtin must come from a committed .target file"
    );
    assert!(seen >= 5, "expected 5+ committed profiles, found {seen}");
}

#[test]
fn resolve_accepts_disk_paths_and_builtin_names() {
    let by_name = resolve_target("edge-npu").unwrap();
    let path = targets_dir().join("edge-npu.target");
    let by_path = resolve_target(path.to_str().unwrap()).unwrap();
    assert_eq!(by_name, by_path);
    let e = resolve_target("holodeck").unwrap_err().to_string();
    for name in builtin_names() {
        assert!(e.contains(name), "error must list {name}: {e}");
    }
}

#[test]
fn oversize_manifest_is_rejected_before_reading() {
    let big = ScratchFile::new(
        "oversize.target",
        &vec![b'#'; MAX_TARGET_FILE_BYTES as usize + 1],
    );
    let e = format!("{:#}", TargetManifest::from_file(&big.0).unwrap_err());
    assert!(e.contains("large") || e.contains("bytes"), "{e}");
}

#[test]
fn non_utf8_manifest_errors_cleanly() {
    let junk = ScratchFile::new("junk.target", &[0xff, 0xfe, 0x00, 0x80]);
    let e = format!("{:#}", TargetManifest::from_file(&junk.0).unwrap_err());
    assert!(e.to_lowercase().contains("utf-8"), "{e}");
}

#[test]
fn truncated_file_on_disk_errors_not_panics() {
    let full = TargetManifest::default().to_text();
    let cut = &full[..full.len() / 3];
    let f = ScratchFile::new("truncated.target", cut.as_bytes());
    assert!(TargetManifest::from_file(&f.0).is_err());
}

fn cli(args: &[&str]) -> anyhow::Result<()> {
    let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
    zebra::cli::run(&argv)
}

#[test]
fn simulate_runs_against_a_named_target_and_a_target_file() {
    cli(&[
        "simulate", "--backend", "reference", "--model", "ref-tiny",
        "--images", "2", "--target", "edge-npu",
    ])
    .unwrap();
    let path = targets_dir().join("datacenter-hbm.target");
    cli(&[
        "simulate", "--backend", "reference", "--model", "ref-tiny",
        "--images", "2", "--target", path.to_str().unwrap(), "--json",
    ])
    .unwrap();
}

#[test]
fn targets_sweep_covers_every_builtin() {
    cli(&[
        "targets", "--backend", "reference", "--model", "ref-tiny",
        "--images", "2",
    ])
    .unwrap();
    cli(&[
        "targets", "--backend", "reference", "--model", "ref-tiny",
        "--images", "2", "--json",
    ])
    .unwrap();
}
