//! Integration: PJRT runtime over real AOT artifacts.
//!
//! Requires `make artifacts` AND `--features pjrt` (the whole file is
//! compiled out otherwise — the default build has no XLA toolchain).
//! The standalone zebra-kernel HLO is cross-validated against the Rust
//! pruner — the two implementations of the paper's op (Pallas-lowered
//! HLO vs native Rust) must agree bit for bit.
#![cfg(feature = "pjrt")]

use zebra::runtime::Runtime;
use zebra::tensor::Tensor;
use zebra::util::prng::Rng;
use zebra::zebra::prune::{relu_prune, Thresholds};

fn artifacts() -> std::path::PathBuf {
    let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        p.join("manifest.json").exists(),
        "run `make artifacts` before integration tests"
    );
    p
}

#[test]
fn zebra_kernel_hlo_matches_rust_pruner() {
    let art = artifacts();
    let rt = Runtime::new(&art).unwrap();
    let exe = rt.compile_file(&art.join("kernel_zebra.hlo.txt")).unwrap();
    // Kernel was exported for (1, 16, 32, 32), block 4, T=0.1.
    let mut rng = Rng::new(99);
    let data: Vec<f32> = (0..16 * 32 * 32).map(|_| rng.normal()).collect();
    let x = Tensor::from_vec(&[1, 16, 32, 32], data);
    let out = rt.run_kernel(&exe, &[&x]).unwrap();
    assert_eq!(out.len(), 2, "kernel returns (pruned, mask)");
    let (pruned_hlo, mask_hlo) = (&out[0], &out[1]);
    let (pruned_rs, mask_rs) = relu_prune(&x, &Thresholds::Scalar(0.1), 4);
    assert_eq!(pruned_hlo.shape(), pruned_rs.shape());
    let mut diffs = 0;
    for (a, b) in pruned_hlo.data().iter().zip(pruned_rs.data()) {
        if a != b {
            diffs += 1;
        }
    }
    assert_eq!(diffs, 0, "pruned tensors disagree in {diffs} elements");
    // Mask: HLO emits f32 {0,1} (N, C, H/4, W/4).
    assert_eq!(mask_hlo.shape(), &[1, 16, 8, 8]);
    let g = mask_rs.grid;
    for n in 0..1 {
        for c in 0..16 {
            for by in 0..8 {
                for bx in 0..8 {
                    let want = mask_rs.get(g.block_id(n, c, by, bx));
                    let got = mask_hlo.at4(n, c, by, bx) != 0.0;
                    assert_eq!(got, want, "mask mismatch at {n},{c},{by},{bx}");
                }
            }
        }
    }
}
