//! Chaos acceptance: the deterministic fault engine + self-healing
//! loop end to end (`rust/docs/robustness.md`) —
//!
//! * conservation under wire drops/corruption and a worker crash over
//!   loopback TCP: every submitted request resolves as ok, shed, or
//!   failed (never hangs, never vanishes),
//! * corrupt-spill downgrade: a post-checksum bit flip in a shipped
//!   `.zspill` frame is caught by the decode self-check, re-shipped
//!   dense, and the request's logits stay bitwise-correct,
//! * replay-by-seed: the same `--chaos` spec over the same workload
//!   journals the identical fault schedule,
//! * the circuit breaker's Open -> Half-Open -> Closed cycle lands in
//!   the flight dump AND the Prometheus exposition.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use zebra::backend::reference::RefSpec;
use zebra::backend::ModelOutput;
use zebra::cluster::{ClusterClient, Router, RouterConfig, WorkerNode};
use zebra::compress::{self, CodecId};
use zebra::coordinator::server::BatchExecutor;
use zebra::coordinator::{
    reference_executor, Server, ServerConfig, ShipSpills,
};
use zebra::faults::{BreakerConfig, FaultInjector, FaultPlan};
use zebra::obs::{FlightEntry, FlightRecorder, TerminalKind};
use zebra::tensor::Tensor;
use zebra::util::prng::Rng;

const WAIT: Duration = Duration::from_secs(30);

fn noise_image(hw: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let n = 3 * hw * hw;
    Tensor::from_vec(&[3, hw, hw], (0..n).map(|_| rng.normal()).collect())
}

fn fill_image(hw: usize, v: f32) -> Tensor {
    Tensor::from_vec(&[3, hw, hw], vec![v; 3 * hw * hw])
}

/// Mock executor (same shape as the coordinator's own tests): logits
/// are [mean, -mean], one 2x2-blocked mask layer.
struct MockExec {
    hw: usize,
    delay: Duration,
}

impl BatchExecutor for MockExec {
    fn execute(&self, x: &Tensor) -> Result<ModelOutput> {
        std::thread::sleep(self.delay);
        let b = x.shape()[0];
        let per = 3 * self.hw * self.hw;
        let mut logits = Vec::with_capacity(b * 2);
        let mut mask = Vec::new();
        for i in 0..b {
            let mean: f32 = x.data()[i * per..(i + 1) * per]
                .iter()
                .sum::<f32>()
                / per as f32;
            logits.extend_from_slice(&[mean, -mean]);
            let kept = if mean > 0.5 { 1.0 } else { 0.0 };
            mask.extend(std::iter::repeat(kept).take(4));
        }
        Ok(ModelOutput {
            logits: Tensor::from_vec(&[b, 2], logits),
            masks: vec![Tensor::from_vec(&[b, 1, 2, 2], mask)],
            block_elems: vec![4],
            layer_nanos: vec![100],
        })
    }
    fn batch_sizes(&self) -> Vec<usize> {
        vec![1]
    }
    fn image_hw(&self) -> usize {
        self.hw
    }
}

fn mock_worker_with(faults: Option<Arc<FaultInjector>>) -> WorkerNode {
    let exec = Arc::new(MockExec { hw: 4, delay: Duration::from_millis(5) });
    let cfg = ServerConfig {
        max_wait: Duration::ZERO,
        faults,
        io_timeout: None,
        ..ServerConfig::default()
    };
    WorkerNode::start(exec, "127.0.0.1:0", cfg, None).unwrap()
}

/// Acceptance: a seeded chaos run over loopback TCP — wire drops +
/// corruption at the router, one worker crashing mid-load — conserves
/// requests: every submit resolves as ok, shed, or failed. Nothing
/// hangs, nothing is silently dropped, and the healthy worker keeps
/// the cluster serving.
#[test]
fn chaos_run_conserves_every_request() {
    let crashing = FaultInjector::new(
        FaultPlan::parse("seed=11,worker.crash_after=10").unwrap(),
    );
    let workers = vec![
        mock_worker_with(Some(crashing)),
        mock_worker_with(None),
    ];
    let mut cfg = RouterConfig::new(
        workers.iter().map(|w| w.local_addr().to_string()).collect(),
    );
    cfg.heartbeat_every = Duration::from_millis(50);
    cfg.max_attempts = 8;
    cfg.request_timeout = Some(Duration::from_millis(300));
    cfg.io_timeout = Some(Duration::from_secs(2));
    cfg.faults = Some(FaultInjector::new(
        FaultPlan::parse("seed=11,wire.drop=0.15,wire.corrupt=2@0.1")
            .unwrap(),
    ));
    let router = Router::start(cfg, "127.0.0.1:0").unwrap();
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();

    let img = fill_image(4, 0.7);
    let n = 60usize;
    let rxs: Vec<_> = (0..n).map(|_| client.submit(&img).unwrap()).collect();
    let (mut ok, mut shed, mut failed) = (0usize, 0usize, 0usize);
    for (i, rx) in rxs.into_iter().enumerate() {
        match rx
            .recv_timeout(WAIT)
            .unwrap_or_else(|_| panic!("request {i} hung under chaos"))
        {
            Ok(resp) => {
                // Whatever survived the chaos is still correct.
                assert!((resp.response.logits[0] - 0.7).abs() < 1e-5);
                ok += 1;
            }
            Err(e) if e.is_overloaded() => shed += 1,
            Err(_) => failed += 1,
        }
    }
    assert_eq!(ok + shed + failed, n, "conservation: ok+shed+failed == n");
    assert!(ok > 0, "the healthy worker must keep serving");
    client.shutdown();
    router.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// Acceptance: `spill.corrupt=1` flips a bit in every shipped frame
/// post-checksum; the worker's decode self-check catches it, records a
/// `spill_corrupt` flight event, and re-ships the batch dense — while
/// the request's logits stay bitwise-identical to a clean run.
#[test]
fn corrupt_spill_downgrades_to_dense_with_bitwise_correct_logits() {
    let ship = Some(ShipSpills { codec: CodecId::ZeroBlock, block: 2 });
    let clean = Server::start(
        Arc::new(reference_executor(RefSpec::tiny()).unwrap()),
        ServerConfig {
            max_wait: Duration::ZERO,
            ship_spills: ship,
            ..ServerConfig::default()
        },
    );
    let (sink_tx, sink_rx) = channel();
    let flight = Arc::new(FlightRecorder::new("chaos", 64, None));
    let chaotic = Server::start(
        Arc::new(reference_executor(RefSpec::tiny()).unwrap()),
        ServerConfig {
            max_wait: Duration::ZERO,
            ship_spills: ship,
            spill_sink: Some(sink_tx),
            flight: Some(flight.clone()),
            faults: Some(FaultInjector::new(
                FaultPlan::parse("seed=3,spill.corrupt=1").unwrap(),
            )),
            ..ServerConfig::default()
        },
    );
    for i in 0..4u64 {
        let img = noise_image(8, 900 + i);
        let want = clean.classify(img.clone()).unwrap().logits;
        let got = chaotic.classify(img).unwrap().logits;
        assert_eq!(got, want, "corruption must never touch the logits");
        let frame = sink_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("the corrupted batch must still ship");
        let view = compress::EncodedView::parse(&frame)
            .expect("the re-shipped frame must be a valid .zspill");
        assert_eq!(
            view.codec,
            CodecId::Dense,
            "a corrupt zero-block frame downgrades to dense"
        );
    }
    let corrupt_events = flight
        .entries()
        .iter()
        .filter(|e| {
            matches!(
                e,
                FlightEntry::Event { kind: TerminalKind::SpillCorrupt, .. }
            )
        })
        .count();
    assert!(
        corrupt_events >= 4,
        "every corrupted frame records a spill_corrupt event \
         (got {corrupt_events})"
    );
    clean.shutdown();
    chaotic.shutdown();
}

/// Acceptance: replay-by-seed. The same chaos spec over the same
/// sequential workload journals the identical fault schedule; a
/// different seed draws a different one.
#[test]
fn same_seed_journals_the_identical_fault_schedule() {
    let spec = "seed=42,worker.stall=50@0.5,worker.slow=2@0.3,\
                spill.corrupt=0.5";
    let run = |spec: &str| -> Vec<String> {
        let fi = FaultInjector::new(FaultPlan::parse(spec).unwrap());
        let (sink_tx, sink_rx) = channel();
        let srv = Server::start(
            Arc::new(MockExec { hw: 4, delay: Duration::ZERO }),
            ServerConfig {
                max_wait: Duration::ZERO,
                ship_spills: Some(ShipSpills {
                    codec: CodecId::ZeroBlock,
                    block: 2,
                }),
                spill_sink: Some(sink_tx),
                faults: Some(fi.clone()),
                ..ServerConfig::default()
            },
        );
        // Sequential classifies: one worker thread, so every site's
        // arrival order is identical across runs.
        for i in 0..24 {
            srv.classify(fill_image(4, 0.1 * (i % 7) as f32)).unwrap();
            let _ = sink_rx.recv_timeout(Duration::from_secs(5));
        }
        srv.shutdown();
        fi.journal()
    };
    let a = run(spec);
    let b = run(spec);
    assert!(!a.is_empty(), "this spec must journal some decisions");
    assert_eq!(a, b, "same seed + same workload => same schedule");
    let c = run("seed=43,worker.stall=50@0.5,worker.slow=2@0.3,\
                 spill.corrupt=0.5");
    assert_ne!(a, c, "a different seed must draw a different schedule");
}

/// Acceptance: the per-worker circuit breaker walks its full
/// Open -> Half-Open -> Closed cycle when a worker dies and later
/// comes back — and the transitions are visible in BOTH the flight
/// ring and the Prometheus exposition.
#[test]
fn breaker_cycle_reaches_flight_ring_and_prometheus() {
    let worker = mock_worker_with(None);
    let addr = worker.local_addr().to_string();
    let flight = Arc::new(FlightRecorder::new("router", 64, None));
    let mut cfg = RouterConfig::new(vec![addr.clone()]);
    cfg.heartbeat_every = Duration::from_millis(50);
    cfg.breaker = BreakerConfig {
        threshold: 1,
        probe_ms: 100,
        max_backoff_ms: 400,
    };
    cfg.flight = Some(flight.clone());
    let router = Router::start(cfg, "127.0.0.1:0").unwrap();
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();
    client.classify(&fill_image(4, 0.6)).unwrap();

    let has_kind = |flight: &FlightRecorder, want: TerminalKind| {
        flight.entries().iter().any(|e| {
            matches!(e, FlightEntry::Event { kind, .. } if *kind == want)
        })
    };
    let wait_for = |what: &str, f: &dyn Fn() -> bool| {
        let deadline = Instant::now() + WAIT;
        while !f() {
            assert!(Instant::now() < deadline, "never saw {what}");
            std::thread::sleep(Duration::from_millis(20));
        }
    };

    // Kill the only worker: the first failure trips the breaker
    // (threshold 1) and the probe timer starts half-open redials that
    // keep failing (and re-opening) while the address stays dead.
    worker.kill();
    wait_for("breaker_open in the flight ring", &|| {
        has_kind(&flight, TerminalKind::BreakerOpen)
    });
    wait_for("breaker_half_open (a probe redial)", &|| {
        has_kind(&flight, TerminalKind::BreakerHalfOpen)
    });

    // Revive a worker on the same address: the next half-open probe's
    // redial succeeds and closes the breaker. The rebind can race the
    // OS releasing the port, so retry until the deadline.
    let deadline = Instant::now() + WAIT;
    let revived = loop {
        let exec =
            Arc::new(MockExec { hw: 4, delay: Duration::from_millis(5) });
        match WorkerNode::start(
            exec,
            &addr,
            ServerConfig {
                max_wait: Duration::ZERO,
                io_timeout: None,
                ..ServerConfig::default()
            },
            None,
        ) {
            Ok(w) => break w,
            Err(e) => {
                assert!(
                    Instant::now() < deadline,
                    "could not rebind {addr}: {e}"
                );
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    };
    wait_for("breaker_closed after the worker returned", &|| {
        has_kind(&flight, TerminalKind::BreakerClosed)
    });
    wait_for("the router to mark the worker alive", &|| {
        router.workers_alive() == 1
    });

    // The healed link serves again.
    let resp = client.classify(&fill_image(4, 0.8)).unwrap();
    assert!((resp.response.logits[0] - 0.8).abs() < 1e-5);

    // And the same transitions export over the metrics plane: the
    // breaker state gauge plus a transition counter that saw the
    // Open/Half-Open/Closed walk.
    let (state, transitions) = router.breaker_states()[0];
    assert_eq!(state, 0, "the breaker ends Closed (code 0)");
    assert!(
        transitions >= 3,
        "Open -> Half-Open -> Closed is at least 3 transitions, \
         got {transitions}"
    );
    let prom = client.obs_report().unwrap().prometheus();
    assert!(prom.contains("zebra_breaker_state"), "{prom}");
    assert!(prom.contains("zebra_breaker_transitions_total"), "{prom}");
    client.shutdown();
    router.shutdown();
    revived.shutdown();
}
