//! Continuous-batching acceptance: flooding one key at the lowest
//! priority must not starve other keys — their latency stays bounded,
//! only the lowest class is shed, and the cluster's merged metrics
//! account for every request (served / shed / failed) with no gaps.
//!
//! The worker's flush window (50ms) deliberately dominates the mock
//! executor's 5ms batches, so the latency comparison measures queue
//! isolation rather than scheduler noise: an unloaded probe waits one
//! flush window; a probe under flood waits the same window plus at
//! most one in-service batch.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use zebra::backend::ModelOutput;
use zebra::cluster::{ClusterClient, Router, RouterConfig, ShardMode, WorkerNode};
use zebra::coordinator::server::BatchExecutor;
use zebra::coordinator::{Priority, ServerConfig};
use zebra::tensor::Tensor;

const WAIT: Duration = Duration::from_secs(30);
const FLUSH: Duration = Duration::from_millis(50);
const EXEC_DELAY: Duration = Duration::from_millis(5);
const WAVES: usize = 20;
const FLOODS_PER_WAVE: usize = 6;

/// Mock executor: logits are [mean, -mean], one 2x2-blocked mask
/// layer, a fixed per-batch execution delay, and a batch-of-8 export
/// so the continuous batch manager actually coalesces.
struct SlowExec {
    hw: usize,
}

impl BatchExecutor for SlowExec {
    fn execute(&self, x: &Tensor) -> Result<ModelOutput> {
        std::thread::sleep(EXEC_DELAY);
        let b = x.shape()[0];
        let per = 3 * self.hw * self.hw;
        let mut logits = Vec::with_capacity(b * 2);
        let mut mask = Vec::new();
        for i in 0..b {
            let mean: f32 = x.data()[i * per..(i + 1) * per]
                .iter()
                .sum::<f32>()
                / per as f32;
            logits.extend_from_slice(&[mean, -mean]);
            let kept = if mean > 0.5 { 1.0 } else { 0.0 };
            mask.extend(std::iter::repeat(kept).take(4));
        }
        Ok(ModelOutput {
            logits: Tensor::from_vec(&[b, 2], logits),
            masks: vec![Tensor::from_vec(&[b, 1, 2, 2], mask)],
            block_elems: vec![4],
            layer_nanos: vec![100],
        })
    }
    fn batch_sizes(&self) -> Vec<usize> {
        vec![8]
    }
    fn image_hw(&self) -> usize {
        self.hw
    }
}

fn fill_image(hw: usize, v: f32) -> Tensor {
    Tensor::from_vec(&[3, hw, hw], vec![v; 3 * hw * hw])
}

/// Exact percentile over raw samples (the shared histogram's
/// power-of-two buckets are too coarse for a 2x latency comparison).
fn p99_us(samples: &mut Vec<u64>) -> u64 {
    samples.sort_unstable();
    let idx = ((samples.len() as f64 - 1.0) * 0.99).round() as usize;
    samples[idx]
}

#[test]
fn flooding_one_key_does_not_starve_the_others() {
    let exec = Arc::new(SlowExec { hw: 4 });
    let worker = WorkerNode::start(
        exec,
        "127.0.0.1:0",
        ServerConfig {
            max_wait: FLUSH,
            workers: 1,
            max_queue: 1024,
            max_batch: 0,
            ship_spills: None,
            spill_sink: None,
            flight: None,
            ledger: None,
            slo: None,
        },
        None,
    )
    .unwrap();
    let mut cfg = RouterConfig::new(vec![worker.local_addr().to_string()]);
    cfg.mode = ShardMode::HashKey;
    // Small budget so the flood overruns it: Low's admission cap is
    // 50% (= 2 slots), Normal/High keep headroom.
    cfg.max_outstanding = 4;
    cfg.max_attempts = 1;
    cfg.heartbeat_every = Duration::from_millis(100);
    let router = Router::start(cfg, "127.0.0.1:0").unwrap();
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();
    let img = fill_image(4, 0.7);

    // Phase 1 — unloaded baseline: closed-loop High probes, each on
    // its own key.
    let mut unloaded = Vec::with_capacity(WAVES);
    for i in 0..WAVES {
        let t = Instant::now();
        client
            .submit_request(&img, Some(1000 + i as u64), Priority::High, None)
            .unwrap()
            .recv_timeout(WAIT)
            .expect("unloaded probe got no response")
            .expect("unloaded probe failed");
        unloaded.push(t.elapsed().as_micros() as u64);
    }

    // Phase 2 — flood key 0 with Low traffic far beyond its admission
    // cap while probing other keys at High, closed loop.
    let mut flood_rxs = Vec::new();
    let mut loaded = Vec::with_capacity(WAVES);
    for i in 0..WAVES {
        for _ in 0..FLOODS_PER_WAVE {
            flood_rxs.push(
                client
                    .submit_request(&img, Some(0), Priority::Low, None)
                    .unwrap(),
            );
        }
        let t = Instant::now();
        client
            .submit_request(&img, Some(2000 + i as u64), Priority::High, None)
            .unwrap()
            .recv_timeout(WAIT)
            .expect("probe under flood got no response")
            .expect("probe under flood was shed or failed");
        loaded.push(t.elapsed().as_micros() as u64);
    }

    // Every flood request resolves explicitly: served, or shed with a
    // structured Overloaded naming the Low class. Never silently lost.
    let mut flood_ok = 0usize;
    let mut flood_shed = 0usize;
    for rx in flood_rxs {
        match rx.recv_timeout(WAIT).expect("flood request got no answer") {
            Ok(_) => flood_ok += 1,
            Err(e) => {
                assert!(e.is_overloaded(), "flood fault (not a shed): {e}");
                flood_shed += 1;
            }
        }
    }
    let floods = WAVES * FLOODS_PER_WAVE;
    assert_eq!(flood_ok + flood_shed, floods, "no silent drops");
    assert!(
        flood_shed > 0,
        "the flood must overrun Low's admission cap"
    );

    // Latency isolation: other keys' p99 stays within 2x of unloaded.
    let (p_base, p_load) = (p99_us(&mut unloaded), p99_us(&mut loaded));
    assert!(
        p_load <= 2 * p_base,
        "flooded p99 {p_load}us exceeds 2x unloaded p99 {p_base}us"
    );

    // Cluster accounting closes exactly once traffic drains: every
    // request is a response, a per-class shed, or a fault.
    let total = (2 * WAVES + floods) as u64;
    let deadline = Instant::now() + WAIT;
    let stats = loop {
        let s = router.stats();
        if s.requests == total
            && s.requests == s.responses + s.rejected
            && s.aggregate.responses == s.responses
        {
            break s;
        }
        assert!(
            Instant::now() < deadline,
            "cluster accounting never converged: {s:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    };
    assert_eq!(stats.failed, 0, "nothing may fault in this test");
    assert_eq!(stats.shed_low as usize, flood_shed);
    assert_eq!(stats.shed_normal, 0, "only the lowest class is shed");
    assert_eq!(stats.shed_high, 0, "only the lowest class is shed");
    assert_eq!(
        stats.shed_total() + stats.failed,
        stats.rejected,
        "every rejection is accounted as a shed or a fault"
    );
    // The workers themselves shed nothing (the router's caps engage
    // first), so the merged node metrics show clean conservation too.
    assert_eq!(stats.aggregate.shed_total(), 0);
    assert_eq!(stats.aggregate.failed, 0);

    client.shutdown();
    router.shutdown();
    worker.shutdown();
}
