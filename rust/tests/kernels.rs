//! Bitwise-equivalence suite for the block-sparse execution engine
//! (`backend::kernels`) against the naive oracle: the fast conv, the
//! masked (Zebra-skip) conv, the fused conv-tail
//! (ReLU + prune + zero-block encode), and thread-count determinism.
//!
//! These are the guarantees the engine rides on: the train tape keeps
//! differentiating the naive `conv3x3`, so every fast path must agree
//! with it bit for bit — across strides, block sizes, edge-heavy
//! shapes, and degenerate all-zero / all-dense masks.

use zebra::backend::kernels::{conv3x3_fast, conv3x3_masked, relu_prune_encode};
use zebra::backend::reference::conv3x3;
use zebra::compress::{Codec, SpillBuf, ZeroBlockCodec};
use zebra::tensor::Tensor;
use zebra::util::prng::Rng;
use zebra::util::prop::{forall, Config};
use zebra::zebra::prune::{block_mask, relu_prune, relu_prune_inplace, Thresholds};

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let n = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal()).collect())
}

#[test]
fn fast_conv_matches_oracle_on_arbitrary_shapes() {
    // Edge-heavy coverage: tiny maps, odd H/W (not divisible by any
    // block), strides 1 and 2 — every padding corner of the region
    // split.
    forall(Config::cases(60), |rng| {
        let (n, cin, cout) = (rng.range(1, 2), rng.range(1, 4), rng.range(1, 4));
        let (h, w) = (rng.range(1, 9), rng.range(1, 9));
        let stride = rng.range(1, 2);
        let x = rand_tensor(rng, &[n, cin, h, w]);
        let k = rand_tensor(rng, &[cout, cin, 3, 3]);
        let fast = conv3x3_fast(&x, &k, stride, 1);
        let oracle = conv3x3(&x, &k, stride);
        assert_eq!(
            fast, oracle,
            "fast != oracle at {n}x{cin}x{h}x{w} stride {stride}"
        );
    });
}

#[test]
fn masked_conv_matches_oracle_across_blocks_and_strides() {
    // The masked kernel consumes a real prune mask (so the input is
    // genuinely zero inside masked-out blocks) over block sizes
    // {2, 4, 8}, strides {1, 2}, and shapes where edge blocks dominate
    // (hb/wb as small as 1).
    forall(Config::cases(60), |rng| {
        let b = [2usize, 4, 8][rng.range(0, 2)];
        let h = b * rng.range(1, 3);
        let w = b * rng.range(1, 3);
        let (n, cin, cout) = (rng.range(1, 2), rng.range(1, 3), rng.range(1, 3));
        let stride = rng.range(1, 2);
        let x = rand_tensor(rng, &[n, cin, h, w]);
        let t = rng.f32_range(0.0, 1.2);
        let (pruned, mask) = relu_prune(&x, &Thresholds::Scalar(t), b);
        let k = rand_tensor(rng, &[cout, cin, 3, 3]);
        let fast = conv3x3_masked(&pruned, &k, stride, &mask, 1);
        let oracle = conv3x3(&pruned, &k, stride);
        assert_eq!(
            fast, oracle,
            "masked != oracle at {n}x{cin}x{h}x{w} b{b} stride {stride} \
             (zero fraction {:.2})",
            mask.zero_fraction()
        );
    });
}

#[test]
fn masked_conv_handles_all_zero_and_all_dense_masks() {
    let mut rng = Rng::new(17);
    for b in [2usize, 4] {
        for stride in [1usize, 2] {
            let (h, w) = (2 * b, 3 * b);
            let k = rand_tensor(&mut rng, &[3, 2, 3, 3]);
            // All-zero: a fully-pruned input (every block skipped).
            let zeros = Tensor::zeros(&[1, 2, h, w]);
            let m0 = block_mask(&zeros, &Thresholds::Scalar(0.0), b);
            assert_eq!(m0.kept(), 0);
            assert_eq!(
                conv3x3_masked(&zeros, &k, stride, &m0, 1),
                conv3x3(&zeros, &k, stride)
            );
            // All-dense: every block live (the skip machinery must be
            // a no-op, not a perturbation).
            let mut x = rand_tensor(&mut rng, &[1, 2, h, w]);
            for v in x.data_mut() {
                *v = v.abs() + 0.1;
            }
            let m1 = block_mask(&x, &Thresholds::Scalar(0.0), b);
            assert_eq!(m1.kept(), m1.grid.num_blocks());
            assert_eq!(
                conv3x3_masked(&x, &k, stride, &m1, 1),
                conv3x3(&x, &k, stride)
            );
        }
    }
}

#[test]
fn fused_encode_matches_separate_passes_bitwise() {
    // conv -> ReLU -> prune -> encode fused must equal the oracle
    // chain: naive conv3x3 + relu_prune + encode_into — pruned tensor,
    // mask, payload, index, and the full `.zspill` frame.
    forall(Config::cases(50), |rng| {
        let b = [2usize, 4, 8][rng.range(0, 2)];
        let stride = rng.range(1, 2);
        // The prune runs on the conv OUTPUT (h/stride), so the input
        // must be sized for the block to divide the strided map.
        let h = b * stride * rng.range(1, 3);
        let w = b * stride * rng.range(1, 3);
        let (n, cin, cout) = (rng.range(1, 2), rng.range(1, 3), rng.range(1, 3));
        let x = rand_tensor(rng, &[n, cin, h, w]);
        let k = rand_tensor(rng, &[cout, cin, 3, 3]);
        let t = rng.f32_range(0.0, 0.8);
        // Oracle: naive conv, two-pass prune, separate encode scan.
        let mut a = conv3x3(&x, &k, stride);
        let mask_a = relu_prune_inplace(&mut a, &Thresholds::Scalar(t), b);
        let codec = ZeroBlockCodec::new(b);
        let mut buf_a = SpillBuf::new();
        codec.encode_into(&a, &mut buf_a);
        // Engine: fast conv, fused prune+encode.
        let mut bt = conv3x3_fast(&x, &k, stride, 1);
        let mut buf_b = SpillBuf::new();
        let mask_b = relu_prune_encode(&mut bt, &Thresholds::Scalar(t), b, &mut buf_b);
        assert_eq!(a, bt, "pruned activations must match bitwise");
        assert_eq!(mask_a, mask_b);
        assert_eq!(buf_a.payload(), buf_b.payload());
        assert_eq!(buf_a.index(), buf_b.index());
        assert_eq!(buf_a.view().to_bytes(), buf_b.view().to_bytes());
        // And the fused frame round-trips to the pruned tensor.
        let mut dec = Tensor::zeros(&[0]);
        codec.decode_into(buf_b.view(), &mut dec);
        assert_eq!(dec, a);
    });
}

#[test]
fn fused_encode_keeps_frame_identity_at_negative_thresholds() {
    // A negative threshold "keeps" even all-zero blocks in the mask,
    // but the codec's liveness scan never stores them — the fused
    // path must agree byte-for-byte on that corner too.
    let mut rng = Rng::new(31);
    let mut x = rand_tensor(&mut rng, &[1, 2, 4, 4]);
    for v in &mut x.data_mut()[..16] {
        *v = -v.abs() - 0.1; // channel 0: all negative -> all-zero blocks
    }
    let thr = [-0.5f32, 0.2];
    let mut a = x.clone();
    let mask_a = relu_prune_inplace(&mut a, &Thresholds::PerChannel(&thr), 2);
    let mut buf_a = SpillBuf::new();
    ZeroBlockCodec::new(2).encode_into(&a, &mut buf_a);
    let mut b = x.clone();
    let mut buf_b = SpillBuf::new();
    let mask_b = relu_prune_encode(&mut b, &Thresholds::PerChannel(&thr), 2, &mut buf_b);
    assert_eq!(a, b);
    assert_eq!(mask_a, mask_b);
    assert!(mask_b.get(0), "all-zero block is kept at a negative threshold");
    assert_eq!(buf_a.view().to_bytes(), buf_b.view().to_bytes());
}

#[test]
fn fused_encode_respects_per_channel_thresholds() {
    let mut rng = Rng::new(23);
    let x = rand_tensor(&mut rng, &[2, 3, 8, 8]);
    let thr = [0.1f32, 0.6, 1.4];
    let mut a = x.clone();
    let mask_a = relu_prune_inplace(&mut a, &Thresholds::PerChannel(&thr), 4);
    let mut buf_a = SpillBuf::new();
    ZeroBlockCodec::new(4).encode_into(&a, &mut buf_a);
    let mut b = x.clone();
    let mut buf_b = SpillBuf::new();
    let mask_b = relu_prune_encode(&mut b, &Thresholds::PerChannel(&thr), 4, &mut buf_b);
    assert_eq!(a, b);
    assert_eq!(mask_a, mask_b);
    assert_eq!(buf_a.view().to_bytes(), buf_b.view().to_bytes());
}

#[test]
fn thread_count_never_changes_results() {
    // Big enough that the engine actually engages its thread pool
    // (small maps stay single-threaded by design), with a plane count
    // that does NOT divide evenly into the thread count.
    let mut rng = Rng::new(29);
    // 64px maps keep even the stride-2 output planes above the
    // engine's small-work threshold, so both strides really thread.
    let x = rand_tensor(&mut rng, &[2, 16, 64, 64]);
    let k = rand_tensor(&mut rng, &[5, 16, 3, 3]);
    let (pruned, mask) = relu_prune(&x, &Thresholds::Scalar(0.5), 4);
    for stride in [1usize, 2] {
        let dense1 = conv3x3_fast(&pruned, &k, stride, 1);
        let masked1 = conv3x3_masked(&pruned, &k, stride, &mask, 1);
        assert_eq!(dense1, conv3x3(&pruned, &k, stride));
        for threads in [2usize, 3, 8, 64] {
            assert_eq!(
                conv3x3_fast(&pruned, &k, stride, threads),
                dense1,
                "dense stride {stride} threads {threads}"
            );
            assert_eq!(
                conv3x3_masked(&pruned, &k, stride, &mask, threads),
                masked1,
                "masked stride {stride} threads {threads}"
            );
        }
    }
}
