//! Telemetry integration: a loopback cluster run (real TCP worker +
//! client) whose `serve.*` sub-stages must account for >= 95% of the
//! serving hot loop's wall time — the PR's acceptance criterion — plus
//! cross-node snapshot merging.

use std::sync::Arc;

use zebra::backend::reference::RefSpec;
use zebra::cluster::{ClusterClient, WorkerNode};
use zebra::coordinator::server::BatchExecutor;
use zebra::coordinator::{reference_executor, ServerConfig};
use zebra::tensor::Tensor;
use zebra::util::prng::Rng;

const SUB_STAGES: &[&str] =
    &["serve.assemble", "serve.ship", "serve.execute", "serve.respond"];

fn noise_image(hw: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let n = 3 * hw * hw;
    Tensor::from_vec(&[3, hw, hw], (0..n).map(|_| rng.normal()).collect())
}

#[test]
fn loopback_cluster_telemetry_accounts_the_hot_loop() {
    let exec = Arc::new(reference_executor(RefSpec::tiny()).unwrap());
    let hw = exec.image_hw();
    let node = WorkerNode::start(
        exec,
        "127.0.0.1:0",
        ServerConfig::default(),
        None,
    )
    .unwrap();
    let client =
        ClusterClient::connect(&node.local_addr().to_string()).unwrap();

    let rxs: Vec<_> = (0..32)
        .map(|i| client.submit(&noise_image(hw, 0xAB + i)).unwrap())
        .collect();
    for rx in rxs {
        rx.recv()
            .expect("worker dropped a request")
            .expect("request failed");
    }

    let snap = node.telemetry().snapshot();
    // The acceptance check: the instrumented sub-stages attribute
    // >= 95% of the umbrella serve.batch wall time.
    let cov = snap
        .coverage("serve.batch", SUB_STAGES)
        .expect("serve.batch must have recorded batches");
    assert!(
        cov >= 0.95,
        "sub-stages cover only {:.1}% of serve.batch:\n{}",
        100.0 * cov,
        snap.report(Some("serve.batch"))
    );
    // Every batch executed; the wire layer saw each submit once.
    let batches = snap.get("serve.batch").calls;
    assert!(batches >= 1);
    assert_eq!(snap.get("serve.execute").calls, batches);
    assert!(snap.get("wire.handle").calls >= 32);
    assert!(snap.get("wire.handle").bytes > 0);
    assert_eq!(snap.get("wire.respond").calls, 32);
    assert!(snap.get("wire.respond").bytes > 0);

    client.shutdown();
    node.shutdown();
}

#[test]
fn node_snapshots_merge_into_a_cluster_view() {
    // Two independent loopback nodes; their snapshots merge label-wise
    // into a cluster-wide view whose counters are the sums.
    let mut nodes = Vec::new();
    for _ in 0..2 {
        let exec = Arc::new(reference_executor(RefSpec::tiny()).unwrap());
        let hw = exec.image_hw();
        let node = WorkerNode::start(
            exec,
            "127.0.0.1:0",
            ServerConfig::default(),
            None,
        )
        .unwrap();
        nodes.push((node, hw));
    }
    let mut snaps = Vec::new();
    for (i, (node, hw)) in nodes.iter().enumerate() {
        let client =
            ClusterClient::connect(&node.local_addr().to_string()).unwrap();
        let hw = *hw;
        for j in 0..4 {
            client
                .classify(&noise_image(hw, (i * 100 + j) as u64))
                .unwrap();
        }
        client.shutdown();
        snaps.push(node.telemetry().snapshot());
    }
    let mut merged = snaps[0].clone();
    merged.merge(&snaps[1]);
    assert_eq!(
        merged.get("serve.batch").calls,
        snaps[0].get("serve.batch").calls
            + snaps[1].get("serve.batch").calls
    );
    assert_eq!(
        merged.get("wire.respond").bytes,
        snaps[0].get("wire.respond").bytes
            + snaps[1].get("wire.respond").bytes
    );
    // The report renders every merged stage.
    let r = merged.report(Some("serve.batch"));
    assert!(r.contains("serve.execute"), "{r}");
    assert!(r.contains("wire.respond"), "{r}");
    for (node, _) in nodes {
        node.shutdown();
    }
}
