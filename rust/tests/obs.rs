//! Observability integration over real TCP: distributed traces
//! assembled hop by hop through a loopback cluster, trace identity
//! surviving failover re-dispatch, the two-plane (stats + telemetry)
//! metrics merge across workers, the flight recorder capturing
//! terminal events, and v1/v2 clients round-tripping against a v3
//! server.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use zebra::backend::reference::RefSpec;
use zebra::backend::ModelOutput;
use zebra::cluster::metrics::MetricsSnapshot;
use zebra::cluster::wire::{
    self, encode_submit_traced, Frame, FrameType, CLUSTER_VERSION,
};
use zebra::cluster::{
    ClusterClient, Router, RouterConfig, ShardMode, WorkerNode,
};
use zebra::coordinator::server::BatchExecutor;
use zebra::coordinator::{
    reference_executor, reference_executor_with_ledger, Priority,
    ServerConfig,
};
use zebra::obs::{
    parse_slo, trace_id_for, FlightEntry, FlightRecorder, Ledger,
    LedgerSnapshot, SloConfig, SloEngine, TerminalKind,
};
use zebra::telemetry::StageStats;
use zebra::tensor::Tensor;
use zebra::util::prng::Rng;

const WAIT: Duration = Duration::from_secs(30);

fn noise_image(hw: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let n = 3 * hw * hw;
    Tensor::from_vec(&[3, hw, hw], (0..n).map(|_| rng.normal()).collect())
}

fn fill_image(hw: usize, v: f32) -> Tensor {
    Tensor::from_vec(&[3, hw, hw], vec![v; 3 * hw * hw])
}

/// Mock executor (same shape as the cluster tests'): logits are
/// [mean, -mean], one 2x2-blocked mask layer, a fixed compute delay so
/// client-observed wall time is dominated by traced server-side work.
struct MockExec {
    hw: usize,
    delay: Duration,
}

impl BatchExecutor for MockExec {
    fn execute(&self, x: &Tensor) -> Result<ModelOutput> {
        std::thread::sleep(self.delay);
        let b = x.shape()[0];
        let per = 3 * self.hw * self.hw;
        let mut logits = Vec::with_capacity(b * 2);
        let mut mask = Vec::new();
        for i in 0..b {
            let mean: f32 = x.data()[i * per..(i + 1) * per]
                .iter()
                .sum::<f32>()
                / per as f32;
            logits.extend_from_slice(&[mean, -mean]);
            let kept = if mean > 0.5 { 1.0 } else { 0.0 };
            mask.extend(std::iter::repeat(kept).take(4));
        }
        Ok(ModelOutput {
            logits: Tensor::from_vec(&[b, 2], logits),
            masks: vec![Tensor::from_vec(&[b, 1, 2, 2], mask)],
            block_elems: vec![4],
            layer_nanos: vec![self.delay.as_nanos() as u64 / b as u64],
        })
    }
    fn batch_sizes(&self) -> Vec<usize> {
        vec![1]
    }
    fn image_hw(&self) -> usize {
        self.hw
    }
}

fn mock_worker(delay: Duration) -> WorkerNode {
    let exec = Arc::new(MockExec { hw: 4, delay });
    let cfg = ServerConfig {
        max_wait: Duration::ZERO,
        workers: 1,
        max_queue: 1024,
        max_batch: 0,
        ship_spills: None,
        spill_sink: None,
        flight: None,
        ledger: None,
        slo: None,
    };
    WorkerNode::start(exec, "127.0.0.1:0", cfg, None).unwrap()
}

fn router_for(workers: &[WorkerNode], mode: ShardMode) -> Router {
    let addrs =
        workers.iter().map(|w| w.local_addr().to_string()).collect();
    let mut cfg = RouterConfig::new(addrs);
    cfg.mode = mode;
    cfg.heartbeat_every = Duration::from_millis(100);
    Router::start(cfg, "127.0.0.1:0").unwrap()
}

/// Acceptance: a sampled request through router + worker comes back
/// with a TraceRecord whose spans include every mandated hop and whose
/// envelope covers >= 95% of the client-observed latency — posed via
/// the telemetry `coverage` contract on the record's telemetry view.
#[test]
fn sampled_traces_cover_client_observed_wall() {
    let worker = mock_worker(Duration::from_millis(25));
    let router = router_for(
        std::slice::from_ref(&worker),
        ShardMode::RoundRobin,
    );
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();
    let img = fill_image(4, 0.7);

    for i in 0..4u64 {
        let tid = trace_id_for(0xB0B, i);
        let rx = client
            .submit_traced(&img, None, Priority::Normal, None, tid, true)
            .unwrap();
        let resp = rx.recv_timeout(WAIT).unwrap().unwrap();
        let rec = resp.trace.expect("sampled request must carry a trace");
        assert_eq!(rec.trace_id, tid, "trace id must survive every hop");

        // Every mandated hop appended its span.
        for label in [
            "router.dispatch",
            "worker.ingest",
            "queue.wait",
            "serve.assemble",
            "serve.execute",
            "layer.0.prune_encode",
        ] {
            assert!(
                rec.span(label).is_some(),
                "span {label} missing from {:?}",
                rec.spans.iter().map(|s| &s.label).collect::<Vec<_>>()
            );
        }

        // >= 95% of the client wall, via the coverage contract: the
        // record viewed as telemetry, client wall as the umbrella.
        let wall_ns = resp.wall.as_nanos() as u64;
        let mut snap = rec.as_telemetry();
        snap.stages.insert(
            "wall".to_string(),
            StageStats { nanos: wall_ns, calls: 1, bytes: 0 },
        );
        let cov = snap.coverage("wall", &["router.dispatch"]).unwrap();
        assert!(
            cov >= 0.95,
            "router.dispatch covers {cov:.3} of a {}us wall",
            wall_ns / 1_000
        );
        // And the execute span nests inside the dispatch window
        // (1 ms slack: epoch timestamps, not one monotonic clock).
        let d = rec.span("router.dispatch").unwrap();
        let e = rec.span("serve.execute").unwrap();
        assert!(
            e.start_ns + 1_000_000 >= d.start_ns
                && e.end_ns <= d.end_ns + 1_000_000,
            "serve.execute [{},{}] outside router.dispatch [{},{}]",
            e.start_ns,
            e.end_ns,
            d.start_ns,
            d.end_ns
        );
    }

    // An unsampled (but id-carrying) request returns no record.
    let rx = client
        .submit_traced(&img, None, Priority::Normal, None, 99, false)
        .unwrap();
    assert!(rx.recv_timeout(WAIT).unwrap().unwrap().trace.is_none());

    client.shutdown();
    router.shutdown();
    worker.shutdown();
}

/// Satellite: the router's MetricsResp merges worker telemetry — the
/// unified report over two real-TCP workers sums their stage counters
/// and reports both planes through one scrape.
#[test]
fn telemetry_merges_across_two_real_tcp_workers() {
    let workers: Vec<WorkerNode> =
        (0..2).map(|_| mock_worker(Duration::ZERO)).collect();
    let router = router_for(&workers, ShardMode::RoundRobin);
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();
    let img = fill_image(4, 0.2);

    let rxs: Vec<_> =
        (0..12).map(|_| client.submit(&img).unwrap()).collect();
    for rx in rxs {
        rx.recv_timeout(WAIT).unwrap().unwrap();
    }

    let report = client.obs_report().unwrap();
    assert_eq!(report.stats.workers_alive, 2);
    assert_eq!(report.stats.aggregate.responses, 12);

    // Both workers served, and the merged stage equals their sum
    // (responses are all in, so the per-worker counters are settled).
    let per_worker: Vec<StageStats> = workers
        .iter()
        .map(|w| w.telemetry().snapshot().get("serve.batch"))
        .collect();
    for (i, s) in per_worker.iter().enumerate() {
        assert!(s.calls > 0, "worker {i} recorded no batches");
    }
    let merged = report.telemetry.get("serve.batch");
    assert_eq!(
        merged.calls,
        per_worker.iter().map(|s| s.calls).sum::<u64>(),
        "merged stage calls must sum the workers'"
    );
    assert_eq!(
        merged.nanos,
        per_worker.iter().map(|s| s.nanos).sum::<u64>(),
    );
    // The router's own stages ride in the same registry.
    assert!(report.telemetry.get("router.dispatch").calls >= 12);

    client.shutdown();
    router.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// Satellite: trace identity survives failover — killing a worker
/// mid-load re-dispatches its in-flight requests, the responses still
/// carry the edge-assigned trace ids, and the router's flight recorder
/// logs the re-dispatch as a terminal event.
#[test]
fn trace_ids_survive_router_redispatch_after_worker_kill() {
    let workers: Vec<WorkerNode> = (0..2)
        .map(|_| mock_worker(Duration::from_millis(20)))
        .collect();
    let flight = Arc::new(FlightRecorder::new("router", 128, None));
    let mut cfg = RouterConfig::new(
        workers.iter().map(|w| w.local_addr().to_string()).collect(),
    );
    cfg.heartbeat_every = Duration::from_millis(100);
    cfg.flight = Some(Arc::clone(&flight));
    let router = Router::start(cfg, "127.0.0.1:0").unwrap();
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();
    let img = fill_image(4, 0.3);

    let rxs: Vec<_> = (0..30u64)
        .map(|i| {
            let tid = trace_id_for(0xF001, i);
            (
                tid,
                client
                    .submit_traced(
                        &img,
                        None,
                        Priority::Normal,
                        None,
                        tid,
                        true,
                    )
                    .unwrap(),
            )
        })
        .collect();
    std::thread::sleep(Duration::from_millis(60));
    workers[0].kill();

    let mut max_attempts = 0u64;
    for (i, (tid, rx)) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(WAIT)
            .unwrap_or_else(|_| panic!("request {i} got no response"))
            .unwrap_or_else(|e| panic!("request {i} failed: {e}"));
        let rec = resp.trace.expect("every request was sampled");
        assert_eq!(rec.trace_id, tid, "request {i} lost its trace id");
        let d = rec.span("router.dispatch").expect("dispatch span");
        max_attempts = max_attempts.max(d.aux);
    }
    assert!(router.stats().retries > 0, "the kill must force retries");
    assert!(
        max_attempts >= 2,
        "a re-dispatched trace must show attempt >= 2 in its \
         router.dispatch aux (max seen: {max_attempts})"
    );

    // The flight ring named the re-dispatched traces and the death.
    let entries = flight.entries();
    let redispatches: Vec<u64> = entries
        .iter()
        .filter_map(|e| match e {
            FlightEntry::Event {
                trace_id,
                kind: TerminalKind::Redispatch,
                ..
            } => Some(*trace_id),
            _ => None,
        })
        .collect();
    assert!(!redispatches.is_empty(), "no Redispatch events recorded");
    assert!(
        redispatches.iter().all(|&id| id != 0),
        "re-dispatch events must name their trace ids"
    );
    assert!(
        entries.iter().any(|e| matches!(
            e,
            FlightEntry::Event { kind: TerminalKind::WorkerDeath, .. }
        )),
        "the worker death itself must be recorded"
    );

    client.shutdown();
    router.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// Satellite: a forced Low-priority shed lands in the flight ring as a
/// `shed_low` terminal event naming the request's trace id.
#[test]
fn forced_shed_records_the_trace_id_in_the_flight_ring() {
    let worker = mock_worker(Duration::from_millis(200));
    let flight = Arc::new(FlightRecorder::new("router", 32, None));
    let mut cfg = RouterConfig::new(vec![worker.local_addr().to_string()]);
    cfg.max_outstanding = 1;
    cfg.max_attempts = 1;
    cfg.heartbeat_every = Duration::from_millis(100);
    cfg.flight = Some(Arc::clone(&flight));
    let router = Router::start(cfg, "127.0.0.1:0").unwrap();
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();
    let img = fill_image(4, 0.9);

    // First request occupies the single admission slot; the Low one
    // behind it is shed.
    let keep = client
        .submit_traced(&img, None, Priority::Normal, None, 1, false)
        .unwrap();
    let tid = trace_id_for(0x5EED, 0);
    let shed = client
        .submit_traced(&img, None, Priority::Low, None, tid, true)
        .unwrap();
    let e = shed.recv_timeout(WAIT).unwrap().unwrap_err();
    assert!(e.is_overloaded(), "expected a shed, got: {e}");
    keep.recv_timeout(WAIT).unwrap().unwrap();

    let named: Vec<u64> = flight
        .entries()
        .iter()
        .filter_map(|e| match e {
            FlightEntry::Event {
                trace_id,
                kind: TerminalKind::ShedLow,
                ..
            } => Some(*trace_id),
            _ => None,
        })
        .collect();
    assert_eq!(
        named,
        vec![tid],
        "the shed_low event must name the shed request's trace id"
    );

    client.shutdown();
    router.shutdown();
    worker.shutdown();
}

/// Acceptance (PR 9 tentpole): over a loopback cluster serving the
/// real reference backend, the bandwidth ledger's *achieved* savings
/// (bytes actually recorded at the fused relu->prune->encode sweep)
/// match the Eq. 2-3 *analytic* figure for the same observed zero mix
/// within 1% — per layer, read back through one obs scrape.
#[test]
fn loopback_ledger_achieved_savings_match_the_analytic_figure() {
    let ledger = Ledger::new();
    let exec = Arc::new(
        reference_executor_with_ledger(RefSpec::tiny(), Arc::clone(&ledger))
            .unwrap(),
    );
    let cfg = ServerConfig {
        ledger: Some(Arc::clone(&ledger)),
        ..ServerConfig::default()
    };
    let worker = WorkerNode::start(exec, "127.0.0.1:0", cfg, None).unwrap();
    let client =
        ClusterClient::connect(&worker.local_addr().to_string()).unwrap();

    // Synthetic workload with a known zero mix: fixed-seed noise
    // drives the tiny spec's ReLU masks deterministically.
    let rxs: Vec<_> = (0..16u64)
        .map(|i| client.submit(&noise_image(8, 100 + i)).unwrap())
        .collect();
    for rx in rxs {
        rx.recv_timeout(WAIT).unwrap().unwrap();
    }

    let report = client.obs_report().unwrap();
    let snap = LedgerSnapshot::from_telemetry(&report.telemetry);
    let layers: Vec<&str> = snap
        .cells
        .keys()
        .map(|(layer, _)| layer.as_str())
        .collect();
    assert_eq!(
        layers,
        vec!["l0", "l1"],
        "tiny spec has two spill layers"
    );
    for ((layer, codec), c) in &snap.cells {
        assert_eq!(codec, "zero-block");
        // One fused sweep per *executed batch*, so batching may fold
        // the 16 requests into fewer sweeps — but never zero.
        assert!(c.sweeps > 0, "{layer} recorded no sweeps");
        assert!(c.blocks > 0, "{layer} swept no blocks");
        let achieved = c.achieved_savings_pct();
        let analytic = c.analytic_savings_pct();
        assert!(
            (achieved - analytic).abs() < 1.0,
            "{layer}: achieved {achieved:.2}% vs Eq. 2-3 analytic \
             {analytic:.2}% drifts >= 1%"
        );
    }
    // The scrape's wire round-trip kept the exact counters: the same
    // cells come straight off the in-process ledger.
    assert_eq!(snap, ledger.snapshot());
    // And the export layer renders them as first-class families.
    let prom = report.prometheus();
    assert!(
        prom.contains(
            "zebra_ledger_dense_bytes_total{layer=\"l0\",codec=\"zero-block\"}"
        ),
        "{prom}"
    );

    client.shutdown();
    worker.shutdown();
}

/// Acceptance (PR 9 tentpole): a forced-overload run trips the
/// shed-rate SLO — the burn-rate engine fires a breach transition, the
/// flight ring records an `slo_breach` terminal event naming the
/// objective, and the breach is visible in the next obs scrape.
#[test]
fn forced_overload_trips_the_shed_rate_slo() {
    let worker = mock_worker(Duration::from_millis(200));
    let flight = Arc::new(FlightRecorder::new("router", 64, None));
    let slo =
        SloEngine::new(SloConfig::default(), Some(Arc::clone(&flight)));
    let mut cfg = RouterConfig::new(vec![worker.local_addr().to_string()]);
    cfg.max_outstanding = 1;
    cfg.max_attempts = 1;
    cfg.heartbeat_every = Duration::from_millis(100);
    cfg.flight = Some(Arc::clone(&flight));
    cfg.slo = Some(Arc::clone(&slo));
    let router = Router::start(cfg, "127.0.0.1:0").unwrap();
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();
    let img = fill_image(4, 0.9);

    // Baseline sample before any load (logical time, no wall clock).
    assert!(slo.observe(0, &router.slo_input()).is_empty());

    // One request occupies the single admission slot; the burst
    // behind it sheds — way past the 50% default threshold.
    let keep = client.submit(&img).unwrap();
    let mut sheds = 0;
    for _ in 0..8 {
        let rx = client
            .submit_traced(&img, None, Priority::Low, None, 0, false)
            .unwrap();
        if rx.recv_timeout(WAIT).unwrap().is_err() {
            sheds += 1;
        }
    }
    keep.recv_timeout(WAIT).unwrap().unwrap();
    assert!(sheds >= 4, "overload never engaged ({sheds} sheds)");

    // One fast-window later both burn windows see the shed storm.
    let fired = slo.observe(60_000, &router.slo_input());
    assert_eq!(fired, vec!["shed-rate"], "the shed-rate SLO must trip");

    // The flight ring names the objective.
    let breach_details: Vec<String> = flight
        .entries()
        .into_iter()
        .filter_map(|e| match e {
            FlightEntry::Event {
                kind: TerminalKind::SloBreach,
                detail,
                ..
            } => Some(detail),
            _ => None,
        })
        .collect();
    assert_eq!(breach_details.len(), 1, "{breach_details:?}");
    assert!(
        breach_details[0].contains("shed-rate"),
        "{}",
        breach_details[0]
    );

    // The next scrape carries the breach in both export forms.
    let report = client.obs_report().unwrap();
    let view = parse_slo(&report.telemetry);
    assert_eq!(view["shed-rate"].breaches, 1);
    assert!(view["shed-rate"].active);
    assert!(
        report
            .prometheus()
            .contains("zebra_slo_breach_total{objective=\"shed-rate\"} 1"),
        "{}",
        report.prometheus()
    );

    client.shutdown();
    router.shutdown();
    worker.shutdown();
}

/// Satellite: the flight ring holds exactly its capacity (256) — the
/// 256th entry does not evict anything, the 257th evicts exactly the
/// oldest, and ring order stays oldest-first across the wrap.
#[test]
fn flight_ring_wraps_at_exactly_capacity() {
    let flight = FlightRecorder::new("ring", 256, None);
    let trace_of = |e: &FlightEntry| match e {
        FlightEntry::Event { trace_id, .. } => *trace_id,
        FlightEntry::Trace(rec) => rec.trace_id,
    };
    for i in 1..=256u64 {
        flight.record_event(i, TerminalKind::ShedLow, "fill");
    }
    let entries = flight.entries();
    assert_eq!(entries.len(), 256, "at capacity nothing is evicted");
    assert_eq!(trace_of(&entries[0]), 1, "oldest entry still present");
    assert_eq!(trace_of(&entries[255]), 256);

    flight.record_event(257, TerminalKind::ShedLow, "wrap");
    let entries = flight.entries();
    assert_eq!(entries.len(), 256, "one past capacity evicts exactly one");
    assert_eq!(trace_of(&entries[0]), 2, "only the oldest was evicted");
    assert_eq!(trace_of(&entries[255]), 257);
    assert!(
        entries.windows(2).all(|w| trace_of(&w[0]) + 1 == trace_of(&w[1])),
        "ring order must stay oldest-first across the wrap"
    );
}

/// Satellite: ledger snapshot merge is associative (and commutative)
/// across three workers' snapshots — `(a+b)+c == a+(b+c) == (c+b)+a`,
/// including cells only some workers have.
#[test]
fn ledger_snapshot_merge_is_associative_across_three_workers() {
    let snap = |layers: &[(&str, u64)]| {
        let ledger = Ledger::new();
        for &(layer, zeros) in layers {
            ledger.cell(layer, "zero-block").record(1024, 512, 64, zeros);
        }
        ledger.snapshot()
    };
    // Worker snapshots with overlapping and disjoint cells.
    let a = snap(&[("l0", 10), ("l1", 20)]);
    let b = snap(&[("l0", 30)]);
    let c = snap(&[("l1", 5), ("spill_out", 0)]);

    let mut left = a.clone(); // (a + b) + c
    left.merge(&b);
    left.merge(&c);
    let mut right = b.clone(); // a + (b + c)
    right.merge(&c);
    let mut a_first = a.clone();
    a_first.merge(&right);
    let mut reversed = c.clone(); // (c + b) + a
    reversed.merge(&b);
    reversed.merge(&a);

    assert_eq!(left, a_first, "merge must be associative");
    assert_eq!(left, reversed, "merge must be commutative");
    let t = left.total();
    assert_eq!(t.sweeps, 5);
    assert_eq!(t.dense_bytes, 5 * 1024);
    assert_eq!(t.zero_blocks, 65);
    // Per-cell: l0 folded two workers, spill_out came from one.
    assert_eq!(
        left.cells[&("l0".to_string(), "zero-block".to_string())].sweeps,
        2
    );
    assert_eq!(
        left.cells[&("spill_out".to_string(), "zero-block".to_string())]
            .zero_blocks,
        0
    );
}

/// Satellite: `zebra top --json` once-mode scrapes a live node and
/// prints the full JSON report without entering the redraw loop.
#[test]
fn zebra_top_json_once_mode_scrapes_a_live_worker() {
    let worker = mock_worker(Duration::ZERO);
    let client =
        ClusterClient::connect(&worker.local_addr().to_string()).unwrap();
    let rx = client.submit(&fill_image(4, 0.2)).unwrap();
    rx.recv_timeout(WAIT).unwrap().unwrap();
    client.shutdown();

    let argv: Vec<String> =
        ["top", "--addr", &worker.local_addr().to_string(), "--json"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    zebra::cli::run(&argv).unwrap();

    // And without an address it fails before touching any socket.
    let e = zebra::cli::run(&["top".to_string()]).unwrap_err();
    assert!(e.to_string().contains("--addr"));

    worker.shutdown();
}

/// Satellite: v1 and v2 clients round-trip against a v3 worker — the
/// server answers in the requester's version, never appends trace or
/// telemetry tails they can't parse, and survives truncated v3 trace
/// fields without panicking.
#[test]
fn old_wire_versions_round_trip_against_a_v3_server() {
    let exec = Arc::new(reference_executor(RefSpec::tiny()).unwrap());
    let worker = WorkerNode::start(
        exec,
        "127.0.0.1:0",
        ServerConfig::default(),
        None,
    )
    .unwrap();
    let addr = worker.local_addr().to_string();
    let img = noise_image(8, 3);

    // A v3 payload is [key(8)][prio(1)][deadline(8)][tid(8)][flags(1)]
    // [spill]; older shapes are strict prefixes of the fields.
    let v3 = encode_submit_traced(5, Priority::Normal, None, 0, false, &img);
    let spill = &v3[26..];
    let v1: Vec<u8> = [&v3[..8], spill].concat();
    let v2: Vec<u8> = [&v3[..17], spill].concat();

    for (version, payload) in [(1u16, v1), (2u16, v2)] {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(WAIT)).unwrap();
        let f = Frame {
            version,
            ..Frame::new(FrameType::Submit, 40 + version as u64, payload)
        };
        f.write_to(&mut s).unwrap();
        let reply = Frame::read_from(&mut s).unwrap();
        assert_eq!(reply.ty, FrameType::Response, "v{version}");
        assert_eq!(reply.id, 40 + version as u64);
        assert_eq!(
            reply.version, version,
            "replies must speak the requester's version"
        );
        let (resp, trace) =
            wire::parse_response(reply.version, &reply.payload).unwrap();
        assert_eq!(resp.logits.len(), 10, "tiny spec has 10 classes");
        assert!(trace.is_none(), "no trace tail for v{version}");

        // Same connection, a MetricsReq: the payload must parse as a
        // bare pre-v3 snapshot (strict — no telemetry tail).
        let f = Frame {
            version,
            ..Frame::new(FrameType::MetricsReq, 90, Vec::new())
        };
        f.write_to(&mut s).unwrap();
        let reply = Frame::read_from(&mut s).unwrap();
        assert_eq!(reply.ty, FrameType::MetricsResp);
        assert_eq!(reply.version, version);
        let snap = MetricsSnapshot::parse(&reply.payload).unwrap();
        assert!(snap.responses >= 1);
    }

    // A v3 submit truncated inside the new trace fields gets a typed
    // Error frame (same id), and the connection keeps serving.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(WAIT)).unwrap();
        let truncated = v3[..25].to_vec();
        Frame::new(FrameType::Submit, 77, truncated)
            .write_to(&mut s)
            .unwrap();
        let reply = Frame::read_from(&mut s).unwrap();
        assert_eq!(reply.ty, FrameType::Error);
        assert_eq!(reply.id, 77);

        Frame::new(FrameType::Submit, 78, v3.clone())
            .write_to(&mut s)
            .unwrap();
        let reply = Frame::read_from(&mut s).unwrap();
        assert_eq!(reply.ty, FrameType::Response);
        assert_eq!(reply.id, 78);
        assert_eq!(reply.version, CLUSTER_VERSION);
    }

    // Bit-flipped v3 frames (flips landing in the new header/trace
    // bytes included) are rejected by checksum — the worker tears the
    // connection down instead of serving corrupt data.
    {
        let good = Frame::new(FrameType::Submit, 80, v3.clone()).encode();
        let mut rng = Rng::new(0xF11B);
        for _ in 0..8 {
            let mut bad = good.clone();
            let bit = rng.below(bad.len() as u64 * 8) as usize;
            bad[bit / 8] ^= 1 << (bit % 8);
            let mut s = TcpStream::connect(&addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            s.write_all(&bad).unwrap();
            // Either an error frame or a closed connection — never a
            // valid Response for a corrupt frame.
            if let Ok(f) = Frame::read_from(&mut s) {
                assert_ne!(f.ty, FrameType::Response);
            }
        }
    }

    worker.shutdown();
}
