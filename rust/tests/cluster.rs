//! Loopback cluster integration: router + in-process workers over
//! real TCP, verifying the acceptance criteria end to end —
//! bitwise logits parity with a direct `coordinator::Server`, zero
//! lost requests when a worker is killed mid-load, shipped-spill
//! accounting that matches the workers' own Eq. 2 metering, and
//! malformed wire input rejected without panics.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use zebra::backend::reference::RefSpec;
use zebra::backend::ModelOutput;
use zebra::cluster::wire::{encode_submit, Frame, FrameType};
use zebra::cluster::{
    ClusterClient, ClusterError, Router, RouterConfig, ShardMode,
    WorkerNode,
};
use zebra::compress::CodecId;
use zebra::coordinator::server::BatchExecutor;
use zebra::coordinator::{
    reference_executor, Priority, Server, ServerConfig, ShipSpills,
};
use zebra::tensor::Tensor;
use zebra::util::prng::Rng;

const WAIT: Duration = Duration::from_secs(30);

fn noise_image(hw: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let n = 3 * hw * hw;
    Tensor::from_vec(&[3, hw, hw], (0..n).map(|_| rng.normal()).collect())
}

fn fill_image(hw: usize, v: f32) -> Tensor {
    Tensor::from_vec(&[3, hw, hw], vec![v; 3 * hw * hw])
}

/// Mock executor from the coordinator's own tests: logits are
/// [mean, -mean], one 2x2-blocked mask layer.
struct MockExec {
    hw: usize,
    delay: Duration,
}

impl BatchExecutor for MockExec {
    fn execute(&self, x: &Tensor) -> Result<ModelOutput> {
        std::thread::sleep(self.delay);
        let b = x.shape()[0];
        let per = 3 * self.hw * self.hw;
        let mut logits = Vec::with_capacity(b * 2);
        let mut mask = Vec::new();
        for i in 0..b {
            let mean: f32 = x.data()[i * per..(i + 1) * per]
                .iter()
                .sum::<f32>()
                / per as f32;
            logits.extend_from_slice(&[mean, -mean]);
            let kept = if mean > 0.5 { 1.0 } else { 0.0 };
            mask.extend(std::iter::repeat(kept).take(4));
        }
        Ok(ModelOutput {
            logits: Tensor::from_vec(&[b, 2], logits),
            masks: vec![Tensor::from_vec(&[b, 1, 2, 2], mask)],
            block_elems: vec![4],
            layer_nanos: vec![100],
        })
    }
    fn batch_sizes(&self) -> Vec<usize> {
        vec![1]
    }
    fn image_hw(&self) -> usize {
        self.hw
    }
}

fn ref_worker() -> WorkerNode {
    let exec = Arc::new(reference_executor(RefSpec::tiny()).unwrap());
    WorkerNode::start(exec, "127.0.0.1:0", ServerConfig::default(), None)
        .unwrap()
}

fn mock_worker(delay: Duration) -> WorkerNode {
    let exec = Arc::new(MockExec { hw: 4, delay });
    let cfg = ServerConfig {
        max_wait: Duration::ZERO,
        workers: 1,
        max_queue: 1024,
        max_batch: 0,
        ship_spills: None,
        spill_sink: None,
        flight: None,
        ledger: None,
        slo: None,
        faults: None,
        io_timeout: None,
    };
    WorkerNode::start(exec, "127.0.0.1:0", cfg, None).unwrap()
}

fn router_for(workers: &[WorkerNode], mode: ShardMode) -> Router {
    let addrs = workers.iter().map(|w| w.local_addr().to_string()).collect();
    let mut cfg = RouterConfig::new(addrs);
    cfg.mode = mode;
    cfg.heartbeat_every = Duration::from_millis(100);
    Router::start(cfg, "127.0.0.1:0").unwrap()
}

/// Acceptance: router + 3 workers return logits bitwise-identical to
/// a direct coordinator run on the same requests.
#[test]
fn cluster_logits_match_direct_server_bitwise() {
    let workers: Vec<WorkerNode> = (0..3).map(|_| ref_worker()).collect();
    for w in &workers {
        assert_ne!(w.local_addr().port(), 0, "port 0 must resolve");
    }
    let router = router_for(&workers, ShardMode::RoundRobin);
    assert_ne!(router.local_addr().port(), 0);
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();

    let direct = Server::start(
        Arc::new(reference_executor(RefSpec::tiny()).unwrap()),
        ServerConfig::default(),
    );
    let images: Vec<Tensor> =
        (0..12).map(|i| noise_image(8, 100 + i as u64)).collect();
    let want: Vec<Vec<f32>> = images
        .iter()
        .map(|im| direct.classify(im.clone()).unwrap().logits)
        .collect();

    let rxs: Vec<_> =
        images.iter().map(|im| client.submit(im).unwrap()).collect();
    for (rx, want) in rxs.into_iter().zip(&want) {
        let resp = rx
            .recv_timeout(WAIT)
            .expect("cluster dropped a request")
            .expect("cluster request failed");
        assert_eq!(
            &resp.response.logits, want,
            "cluster logits must be bitwise identical to a direct run"
        );
        assert!(resp.response.dense_bytes > 0, "Eq. 2 accounting rides along");
        assert!(resp.response.latency_us > 0);
    }
    // Round-robin spread the 12 requests over all three workers.
    for w in &workers {
        assert!(
            w.metrics().requests.load(Ordering::Relaxed) > 0,
            "round-robin must touch every worker"
        );
    }
    direct.shutdown();
    client.shutdown();
    router.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// Acceptance: killing a worker mid-load loses zero accepted requests
/// — its in-flight work completes via retry on the peers.
#[test]
fn killing_a_worker_mid_load_loses_zero_requests() {
    let workers: Vec<WorkerNode> = (0..3)
        .map(|_| mock_worker(Duration::from_millis(20)))
        .collect();
    let router = router_for(&workers, ShardMode::RoundRobin);
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();

    let img = fill_image(4, 0.7);
    let rxs: Vec<_> =
        (0..45).map(|_| client.submit(&img).unwrap()).collect();
    // Let a few requests finish, then kill a worker with ~10 queued.
    std::thread::sleep(Duration::from_millis(100));
    workers[0].kill();

    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(WAIT)
            .unwrap_or_else(|_| panic!("request {i} got no response"))
            .unwrap_or_else(|e| panic!("request {i} failed: {e}"));
        assert_eq!(resp.response.predicted, 0);
        assert!((resp.response.logits[0] - 0.7).abs() < 1e-5);
    }
    let stats = router.stats();
    assert!(
        stats.retries > 0,
        "the killed worker must have had work to retry: {stats:?}"
    );
    assert_eq!(stats.workers_alive, 2, "one worker is gone");
    assert_eq!(stats.rejected, 0, "no request may be dropped");
    client.shutdown();
    router.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// Acceptance: the spill bytes workers meter (Eq. 2 over their
/// `.zspill` batch frames) arrive at the router byte-for-byte, and
/// `zebra loadgen` reports the matching totals.
#[test]
fn shipped_spill_bytes_match_worker_eq2_accounting() {
    // The workers need the router's address before it exists, so
    // reserve a port first; the upstream pump retries until the
    // router actually binds it.
    let router_addr = {
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        probe.local_addr().unwrap().to_string()
    };
    let workers: Vec<WorkerNode> = (0..2)
        .map(|_| {
            let exec =
                Arc::new(reference_executor(RefSpec::tiny()).unwrap());
            let cfg = ServerConfig {
                max_wait: Duration::from_millis(1),
                workers: 1,
                max_queue: 1024,
                max_batch: 0,
                ship_spills: Some(ShipSpills {
                    codec: CodecId::ZeroBlock,
                    block: 2,
                }),
                spill_sink: None,
                flight: None,
                ledger: None,
                slo: None,
                faults: None,
                io_timeout: None,
            };
            WorkerNode::start(
                exec,
                "127.0.0.1:0",
                cfg,
                Some(router_addr.clone()),
            )
            .unwrap()
        })
        .collect();
    let mut cfg = RouterConfig::new(
        workers.iter().map(|w| w.local_addr().to_string()).collect(),
    );
    cfg.heartbeat_every = Duration::from_millis(100);
    let router = Router::start(cfg, &router_addr).unwrap();
    let client = ClusterClient::connect(&router_addr).unwrap();

    let rxs: Vec<_> = (0..16)
        .map(|i| client.submit(&noise_image(8, i as u64)).unwrap())
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(WAIT).unwrap().unwrap();
        assert!(
            resp.response.spill_frame_bytes > 0,
            "shipping must meter per-request frame bytes"
        );
    }

    // The workers metered every frame at encode time; the upstream
    // pumps deliver asynchronously — poll until the router has
    // received *exactly* what the workers shipped.
    let deadline = Instant::now() + WAIT;
    loop {
        let shipped: u64 = workers
            .iter()
            .map(|w| {
                w.metrics().shipped_spill_bytes.load(Ordering::Relaxed)
            })
            .sum();
        let stats = router.stats();
        if shipped > 0
            && stats.spill_bytes_in == shipped
            && stats.aggregate.shipped_spill_bytes == shipped
        {
            assert!(stats.spill_frames_in > 0);
            break;
        }
        assert!(
            Instant::now() < deadline,
            "spill accounting never converged: workers metered \
             {shipped}B, router received {}B (aggregate says {}B)",
            stats.spill_bytes_in,
            stats.aggregate.shipped_spill_bytes
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // Acceptance: loadgen against the 2-worker cluster reports
    // percentiles and the matching spill totals (it prints them; a
    // failed request or unreachable router errors the command).
    zebra::cli::run(&[
        "loadgen".into(),
        "--addr".into(),
        router_addr.clone(),
        "--requests".into(),
        "8".into(),
        "--hw".into(),
        "8".into(),
        "--fail-on-error".into(),
    ])
    .expect("loadgen against the loopback cluster must succeed");

    client.shutdown();
    router.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// Consistent-hash mode pins a request key to one worker; distinct
/// keys still spread.
#[test]
fn hash_mode_pins_keys_and_spreads_distinct_ones() {
    let workers: Vec<WorkerNode> =
        (0..3).map(|_| mock_worker(Duration::ZERO)).collect();
    let router = router_for(&workers, ShardMode::HashKey);
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();
    let img = fill_image(4, 0.2);

    for _ in 0..20 {
        client
            .submit_keyed(&img, 0xFEED_F00D)
            .unwrap()
            .recv_timeout(WAIT)
            .unwrap()
            .unwrap();
    }
    let counts: Vec<u64> = workers
        .iter()
        .map(|w| w.metrics().requests.load(Ordering::Relaxed))
        .collect();
    assert_eq!(counts.iter().sum::<u64>(), 20);
    assert_eq!(
        counts.iter().filter(|&&c| c > 0).count(),
        1,
        "one key must map to one worker: {counts:?}"
    );

    for k in 0..48u64 {
        client
            .submit_keyed(&img, k)
            .unwrap()
            .recv_timeout(WAIT)
            .unwrap()
            .unwrap();
    }
    let counts: Vec<u64> = workers
        .iter()
        .map(|w| w.metrics().requests.load(Ordering::Relaxed))
        .collect();
    assert!(
        counts.iter().filter(|&&c| c > 0).count() >= 2,
        "distinct keys must spread: {counts:?}"
    );
    client.shutdown();
    router.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// Per-worker admission limits shed overload with structured
/// `Overloaded` frames instead of queueing without bound — and the
/// sheds land in the per-class counters, never as silent drops.
#[test]
fn admission_limit_sheds_overload_with_structured_frames() {
    let worker = mock_worker(Duration::from_millis(200));
    let mut cfg = RouterConfig::new(vec![worker.local_addr().to_string()]);
    cfg.max_outstanding = 1;
    cfg.max_attempts = 1;
    cfg.heartbeat_every = Duration::from_millis(100);
    let router = Router::start(cfg, "127.0.0.1:0").unwrap();
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();
    let img = fill_image(4, 0.9);
    let rxs: Vec<_> =
        (0..5).map(|_| client.submit(&img).unwrap()).collect();
    let mut ok = 0;
    let mut shed = 0;
    for rx in rxs {
        match rx.recv_timeout(WAIT).unwrap() {
            Ok(_) => ok += 1,
            Err(e) => {
                // The refusal is the typed admission outcome, not a
                // generic fault, and it names the class and cause.
                assert!(e.is_overloaded(), "expected a shed, got: {e}");
                match e {
                    ClusterError::Overloaded {
                        priority, detail, ..
                    } => {
                        assert_eq!(priority, Priority::Normal);
                        assert!(
                            detail.contains("workers available"),
                            "unexpected shed detail: {detail}"
                        );
                    }
                    other => panic!("not a shed: {other}"),
                }
                shed += 1;
            }
        }
    }
    assert_eq!(ok, 1, "exactly the admitted request completes");
    assert_eq!(shed, 4, "the rest are shed by admission control");
    let stats = router.stats();
    assert_eq!(stats.rejected, 4);
    assert_eq!(stats.shed_normal, 4, "sheds are accounted per class");
    assert_eq!(stats.shed_low + stats.shed_high, 0);
    assert_eq!(stats.failed, 0, "a shed is not a fault");
    assert_eq!(
        stats.shed_total() + stats.failed,
        stats.rejected,
        "every rejection is a shed or a fault — no silent drops"
    );
    client.shutdown();
    router.shutdown();
    worker.shutdown();
}

/// Regression: the router's per-worker in-flight counters must return
/// to zero once traffic drains — including across a worker death
/// under load. The old accounting incremented `outstanding` outside
/// the pending-map lock, so a concurrent `fail_link` drain could
/// subtract first and underflow the counter to `usize::MAX`, wedging
/// that worker's admission cap forever (every later request shed).
#[test]
fn redial_returns_in_flight_counters_to_zero() {
    let workers: Vec<WorkerNode> = (0..2)
        .map(|_| mock_worker(Duration::from_millis(20)))
        .collect();
    let router = router_for(&workers, ShardMode::RoundRobin);
    let client =
        ClusterClient::connect(&router.local_addr().to_string()).unwrap();
    let img = fill_image(4, 0.3);

    // Load both workers, then kill one while its queue is non-empty
    // (the router keeps redialing the dead address in the background).
    let rxs: Vec<_> =
        (0..30).map(|_| client.submit(&img).unwrap()).collect();
    std::thread::sleep(Duration::from_millis(60));
    workers[0].kill();
    for (i, rx) in rxs.into_iter().enumerate() {
        rx.recv_timeout(WAIT)
            .unwrap_or_else(|_| panic!("request {i} got no response"))
            .unwrap_or_else(|e| panic!("request {i} failed: {e}"));
    }

    // Quiescent: every per-worker counter drains to exactly zero.
    // An underflow shows up here as a usize::MAX that never drains.
    let deadline = Instant::now() + WAIT;
    loop {
        let in_flight = router.worker_in_flight();
        if in_flight.iter().all(|&c| c == 0) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "in-flight counters never returned to zero: {in_flight:?}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // And the surviving worker's admission cap is not wedged: fresh
    // traffic is admitted and served, and drains back to zero again.
    let rxs: Vec<_> =
        (0..10).map(|_| client.submit(&img).unwrap()).collect();
    for rx in rxs {
        rx.recv_timeout(WAIT)
            .expect("post-failure request got no response")
            .expect("post-failure request failed");
    }
    let deadline = Instant::now() + WAIT;
    while !router.worker_in_flight().iter().all(|&c| c == 0) {
        assert!(
            Instant::now() < deadline,
            "counters did not drain after the second wave: {:?}",
            router.worker_in_flight()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(router.stats().rejected, 0, "nothing was shed or lost");
    client.shutdown();
    router.shutdown();
    for w in workers {
        w.shutdown();
    }
}

/// Malformed wire input — garbage bytes, junk payloads, wrong image
/// geometry, absurd length prefixes — is rejected with errors (or a
/// closed connection), never a panic, and the nodes keep serving.
#[test]
fn malformed_wire_input_never_panics_the_nodes() {
    let worker = ref_worker();
    let waddr = worker.local_addr().to_string();

    // Garbage bytes: the worker closes the connection.
    {
        let mut s = TcpStream::connect(&waddr).unwrap();
        s.write_all(&[0xAB; 64]).unwrap();
        expect_closed(&mut s);
    }

    // A well-framed Submit with a junk payload gets an Error frame
    // and the connection survives for the next frame.
    {
        let mut s = TcpStream::connect(&waddr).unwrap();
        Frame::new(FrameType::Submit, 42, vec![1, 2, 3])
            .write_to(&mut s)
            .unwrap();
        let f = Frame::read_from(&mut s).unwrap();
        assert_eq!(f.ty, FrameType::Error);
        assert_eq!(f.id, 42);

        // Wrong image geometry for this worker: Error, not a panic.
        let img5 = noise_image(5, 1);
        Frame::new(
            FrameType::Submit,
            43,
            encode_submit(0, Priority::Normal, None, &img5),
        )
            .write_to(&mut s)
            .unwrap();
        let f = Frame::read_from(&mut s).unwrap();
        assert_eq!(f.ty, FrameType::Error);
        assert_eq!(f.id, 43);
        let msg = String::from_utf8_lossy(&f.payload).into_owned();
        assert!(msg.contains("shape"), "{msg}");

        // An absurd length prefix tears the connection down before
        // any allocation happens.
        let mut hdr = Frame::new(FrameType::Submit, 44, Vec::new()).encode();
        hdr[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
        s.write_all(&hdr).unwrap();
        expect_closed(&mut s);
    }

    // The worker still serves valid traffic afterwards — and so does
    // a router that got fed the same garbage.
    let router = router_for(std::slice::from_ref(&worker), ShardMode::RoundRobin);
    let raddr = router.local_addr().to_string();
    {
        let mut s = TcpStream::connect(&raddr).unwrap();
        s.write_all(b"ZSPL not a cluster frame at all............")
            .unwrap();
        expect_closed(&mut s);
    }
    let client = ClusterClient::connect(&raddr).unwrap();
    let resp = client.classify(&noise_image(8, 2)).unwrap();
    assert_eq!(resp.response.logits.len(), 10, "tiny spec has 10 classes");
    client.shutdown();
    router.shutdown();
    worker.shutdown();
}

/// Drain a socket until the peer closes it (EOF or reset), with a
/// bounded read timeout so a hung node fails the test instead of
/// wedging it.
fn expect_closed(s: &mut TcpStream) {
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = [0u8; 64];
    loop {
        match s.read(&mut buf) {
            Ok(0) | Err(_) => return,
            Ok(_) => continue,
        }
    }
}
