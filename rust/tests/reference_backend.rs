//! Integration: the pure-Rust reference backend through the real
//! serving pipeline — no PJRT, no artifacts, no closure executor.
//!
//! This is the configuration CI gates: `BackendExecutor` bridges a
//! `ReferenceBackend` onto the coordinator exactly the way production
//! bridges PJRT, and the per-request Eq. 2–3 accounting is
//! cross-checked against the accelerator model's analytic mode.

use std::sync::Arc;
use std::time::Duration;

use zebra::accel::{simulate_analytic, AccelConfig, LayerDesc};
use zebra::backend::reference::{RefSpec, ReferenceBackend};
use zebra::backend::InferenceBackend;
use zebra::coordinator::{
    BackendExecutor, Server, ServerConfig, SubmitOutcome, SubmitRequest,
};
use zebra::tensor::Tensor;
use zebra::util::prng::Rng;

fn noise_image(hw: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let n = 3 * hw * hw;
    Tensor::from_vec(&[3, hw, hw], (0..n).map(|_| rng.normal()).collect())
}

fn argv(s: &[&str]) -> Vec<String> {
    s.iter().map(|x| x.to_string()).collect()
}

#[test]
fn coordinator_serves_end_to_end_on_the_reference_backend() {
    let exec = BackendExecutor::spawn(|| ReferenceBackend::new(RefSpec::tiny()))
        .unwrap();
    assert_eq!(exec.backend_name(), "reference");
    let srv = Server::start(
        Arc::new(exec),
        ServerConfig {
            max_wait: Duration::from_millis(1),
            workers: 2,
            max_queue: 256,
            max_batch: 0,
            ship_spills: None,
            spill_sink: None,
            flight: None,
            ledger: None,
            slo: None,
        },
    );
    let img = noise_image(8, 11);
    let r = srv.classify(img.clone()).unwrap();
    assert_eq!(r.logits.len(), 10, "tiny spec has 10 classes");
    assert!(r.predicted < 10);
    // Nonzero bandwidth accounting, derived from real masks.
    assert!(r.dense_bytes > 0, "dense bytes must be nonzero");
    assert!(r.stored_bytes <= r.dense_bytes);
    assert!(r.index_bytes > 0, "Eq. 3 index is never free");
    // Deterministic backend => identical answers for identical images.
    let r2 = srv.classify(img).unwrap();
    assert_eq!(r2.logits, r.logits);
    assert_eq!(r2.stored_bytes, r.stored_bytes);
    // A different image routes its own answer back.
    let r3 = srv.classify(noise_image(8, 99)).unwrap();
    assert_ne!(r3.logits, r.logits);
    srv.shutdown();
}

#[test]
fn batching_engages_over_the_reference_backend() {
    let exec = BackendExecutor::spawn(|| ReferenceBackend::new(RefSpec::tiny()))
        .unwrap();
    let srv = Arc::new(Server::start(
        Arc::new(exec),
        ServerConfig {
            max_wait: Duration::from_millis(20),
            workers: 1,
            max_queue: 1024,
            max_batch: 0,
            ship_spills: None,
            spill_sink: None,
            flight: None,
            ledger: None,
            slo: None,
        },
    ));
    let rxs: Vec<_> = (0..16)
        .map(|i| {
            let (tx, rx) = std::sync::mpsc::channel();
            let req = SubmitRequest::new(noise_image(8, i as u64));
            match srv.submit(req, tx) {
                SubmitOutcome::Enqueued { .. } => rx,
                other => panic!("expected admission, got {other:?}"),
            }
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    assert!(
        srv.metrics.mean_batch() > 1.0,
        "batcher should coalesce: mean {}",
        srv.metrics.mean_batch()
    );
    let dense = srv
        .metrics
        .dense_bytes
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(dense > 0, "aggregate accounting must be nonzero");
    Arc::try_unwrap(srv).ok().map(|s| s.shutdown());
}

#[test]
fn backend_startup_errors_propagate_to_the_caller() {
    let bad = BackendExecutor::spawn(|| {
        let mut spec = RefSpec::tiny();
        spec.spills.clear();
        ReferenceBackend::new(spec)
    });
    let msg = format!("{:#}", bad.err().unwrap());
    assert!(msg.contains("no layers"), "{msg}");
}

#[test]
fn mask_accounting_matches_simulate_analytic() {
    // Eq. 2 bytes derived from the backend's masks must agree with the
    // accelerator model's analytic mode fed the same kept fractions —
    // the two independent accountings of the paper's headline number.
    let spec = RefSpec::tiny();
    let be = ReferenceBackend::new(spec.clone()).unwrap();
    let x = noise_image(8, 5).reshape(&[1, 3, 8, 8]);
    let out = be.execute(&x).unwrap();
    assert_eq!(out.masks.len(), spec.spills.len());

    let mut kept = Vec::new();
    let mut eq2_bytes = Vec::new();
    for (m, sp) in out.masks.iter().zip(&spec.spills) {
        let total = m.len();
        let k = m.data().iter().filter(|&&v| v != 0.0).count();
        kept.push(k as f64 / total as f64);
        eq2_bytes.push((k * sp.block * sp.block * 4) as f64);
    }
    let layers = LayerDesc::from_plan(&spec.spills);
    let sim = simulate_analytic(&AccelConfig::default(), &layers, &kept, "ref");
    assert_eq!(sim.layers.len(), eq2_bytes.len());
    for (l, want) in sim.layers.iter().zip(&eq2_bytes) {
        let got = l.act_bytes_out as f64;
        assert!(
            (got - want).abs() <= 1.0,
            "layer {}: analytic {got} B vs mask-derived Eq.2 {want} B",
            l.name
        );
    }
}

#[test]
fn serve_cli_runs_artifact_free_on_the_reference_backend() {
    // The acceptance path: `zebra serve --backend reference` must
    // classify end to end with zero artifacts on disk (synthetic test
    // set kicks in).
    let args = zebra::cli::Args::parse(&argv(&[
        "serve",
        "--backend",
        "reference",
        "--model",
        "ref-tiny",
        "--requests",
        "5",
        "--wait-ms",
        "0",
    ]))
    .unwrap();
    let empty = std::env::temp_dir()
        .join(format!("zebra-no-artifacts-{}", std::process::id()));
    zebra::cli::serve::run_with(&args, empty).unwrap();
}

#[test]
fn serve_cli_ships_spills_on_the_reference_backend() {
    // --ship-codec composes with --backend reference: batches are
    // framed as `.zspill` on the way through.
    let args = zebra::cli::Args::parse(&argv(&[
        "serve",
        "--backend",
        "reference",
        "--model",
        "ref-tiny",
        "--requests",
        "3",
        "--ship-codec",
        "zero-block",
        "--ship-block",
        "2",
    ]))
    .unwrap();
    let empty = std::env::temp_dir()
        .join(format!("zebra-no-artifacts-ship-{}", std::process::id()));
    zebra::cli::serve::run_with(&args, empty).unwrap();
}
