#!/usr/bin/env bash
# rust/loadgen_smoke.sh — admission-control smoke gate: one
# cluster-worker behind a router whose outstanding budget is forced
# tiny, flooded by `zebra loadgen` from concurrent mixed-priority
# connections. Passes only when overload is handled the designed way:
# nonzero sheds (--expect-sheds), zero faults (--fail-on-error — a
# shed is not a fault), and loadgen's built-in conservation check
# (every request ends as exactly one of ok/shed/failed). Ephemeral
# ports throughout. `make loadgen-smoke` runs this; rust/check.sh and
# .github/workflows/ci.yml invoke that target.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release --no-default-features
BIN=target/release/zebra

tmp=$(mktemp -d)
pids=()
cleanup() {
  for p in ${pids[@]+"${pids[@]}"}; do kill "$p" 2>/dev/null || true; done
  rm -rf "$tmp"
}
trap cleanup EXIT

# Harvest the "... listening on HOST:PORT" line a node prints.
wait_addr() {
  local log="$1" i addr
  for i in $(seq 1 100); do
    addr=$(sed -n 's/.*listening on \([0-9.]*:[0-9]*\).*/\1/p' "$log" | head -n1)
    if [ -n "$addr" ]; then
      echo "$addr"
      return 0
    fi
    sleep 0.1
  done
  echo "timed out waiting for an address in $log" >&2
  cat "$log" >&2
  return 1
}

# The worker exercises the shared batching flags (--flush-us,
# --max-batch); --run-s bounds every node's lifetime so a wedged run
# cannot outlive CI even if the cleanup trap is skipped.
"$BIN" cluster-worker --model ref-tiny --flush-us 2000 --max-batch 4 \
  --port 0 --run-s 120 >"$tmp/w1.log" 2>&1 &
pids+=($!)
W1=$(wait_addr "$tmp/w1.log")

# --max-outstanding 2 makes overload certain: Low's admission cap is
# 1 slot, Normal/High get 2. --max-attempts 1 sheds deterministically
# instead of retrying the only worker.
"$BIN" cluster-router --workers "$W1" --max-outstanding 2 \
  --max-attempts 1 --port 0 --run-s 120 >"$tmp/r.log" 2>&1 &
pids+=($!)
R=$(wait_addr "$tmp/r.log")

ZEBRA_BENCH_SMOKE=1 "$BIN" loadgen --addr "$R" --requests 240 \
  --conns 8 --priority mixed --keys 4 --hw 8 \
  --expect-sheds --fail-on-error

echo "loadgen smoke OK (router $R, worker $W1: sheds observed, no faults, no lost requests)"
