//! Hardware abstraction layer: target manifests for the accelerator
//! simulator.
//!
//! The paper's bandwidth argument (Eq. 2–3) is target-dependent — what
//! 70% zero blocks buy you differs wildly between a 25.6 GB/s edge NPU
//! and a 900 GB/s HBM part. This module makes the hardware envelope an
//! explicit, versioned input instead of constants buried in
//! `accel::AccelConfig`:
//!
//! - [`TargetManifest`] — the envelope (DRAM GB/s, burst bytes, local
//!   buffer KiB, PE geometry, clock MHz, optional int8 TOPS, energy
//!   proxies), parsed from TOML-like `.target` files with the same
//!   strict never-panicking validation as `.zspill`/`.zten`
//!   (`hal::manifest`).
//! - `rust/targets/` — committed profiles, compiled into the binary
//!   ([`builtin_targets`]) so `zebra simulate --target edge-npu` works
//!   from any working directory, and `zebra targets` sweeps one model
//!   across every profile.
//! - [`TargetManifest::accel_config`] — lowering to the simulator's
//!   [`AccelConfig`](crate::accel::AccelConfig); the `default` profile
//!   lowers to exactly `AccelConfig::default()` (parity-tested), so
//!   pre-HAL simulation numbers are unchanged.
//!
//! Schema and authoring guide: `rust/docs/targets.md`.

mod manifest;

pub use manifest::{TargetManifest, MAX_TARGET_FILE_BYTES};

use anyhow::{Context, Result};

/// The committed `rust/targets/` profiles, embedded at compile time.
/// Order is the sweep order of `zebra targets` (default first, then
/// ascending bandwidth class).
pub const BUILTIN_TARGET_SOURCES: &[(&str, &str)] = &[
    ("default", include_str!("../../targets/default.target")),
    ("fpga-small", include_str!("../../targets/fpga-small.target")),
    ("edge-npu", include_str!("../../targets/edge-npu.target")),
    ("mobile-soc", include_str!("../../targets/mobile-soc.target")),
    (
        "datacenter-hbm",
        include_str!("../../targets/datacenter-hbm.target"),
    ),
];

/// Parse every embedded profile. Errors only if a committed manifest
/// is invalid — which the test suite prevents from ever shipping.
pub fn builtin_targets() -> Result<Vec<TargetManifest>> {
    BUILTIN_TARGET_SOURCES
        .iter()
        .map(|(name, src)| {
            let m = TargetManifest::parse(src)
                .with_context(|| format!("builtin target {name:?}"))?;
            anyhow::ensure!(
                m.name == *name,
                "builtin target {name:?} declares mismatched name {:?}",
                m.name
            );
            Ok(m)
        })
        .collect()
}

/// Names of the embedded profiles (for error messages and sweeps).
pub fn builtin_names() -> Vec<&'static str> {
    BUILTIN_TARGET_SOURCES.iter().map(|(n, _)| *n).collect()
}

/// Resolve `--target SPEC`: a path to a `.target` file (anything that
/// looks like one or exists on disk), else a builtin profile name.
pub fn resolve_target(spec: &str) -> Result<TargetManifest> {
    let looks_like_path = spec.contains('/')
        || spec.contains('\\')
        || spec.ends_with(".target");
    if looks_like_path || std::path::Path::new(spec).is_file() {
        return TargetManifest::from_file(spec);
    }
    if let Some((_, src)) =
        BUILTIN_TARGET_SOURCES.iter().find(|(n, _)| *n == spec)
    {
        return TargetManifest::parse(src)
            .with_context(|| format!("builtin target {spec:?}"));
    }
    anyhow::bail!(
        "unknown target {spec:?}: not a .target file, and not one of the \
         builtin profiles ({})",
        builtin_names().join(", ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AccelConfig;

    #[test]
    fn every_builtin_parses_and_validates() {
        let all = builtin_targets().unwrap();
        assert!(all.len() >= 5, "expected 5+ profiles, got {}", all.len());
        for m in &all {
            m.validate().unwrap();
            // Round-trip through the canonical serialization.
            assert_eq!(TargetManifest::parse(&m.to_text()).unwrap(), m.clone());
        }
    }

    #[test]
    fn default_builtin_matches_the_pre_hal_accelerator() {
        let d = resolve_target("default").unwrap();
        assert_eq!(d, TargetManifest::default());
        assert_eq!(d.accel_config(), AccelConfig::default());
    }

    #[test]
    fn resolve_by_name_and_unknown_name_errors() {
        assert_eq!(resolve_target("edge-npu").unwrap().name, "edge-npu");
        let e = resolve_target("nope").unwrap_err().to_string();
        assert!(e.contains("edge-npu"), "{e}");
        assert!(e.contains("datacenter-hbm"), "{e}");
    }

    #[test]
    fn resolve_by_path_uses_the_file_loader() {
        // A path-looking spec that does not exist errors through the
        // file loader (not the builtin list).
        let e = format!(
            "{:#}",
            resolve_target("no/such/file.target").unwrap_err()
        );
        assert!(e.contains("file.target"), "{e}");
    }

    #[test]
    fn builtins_cover_distinct_bandwidth_classes() {
        let all = builtin_targets().unwrap();
        let lo = all
            .iter()
            .map(|m| m.dram_gbps)
            .fold(f64::INFINITY, f64::min);
        let hi = all.iter().map(|m| m.dram_gbps).fold(0.0, f64::max);
        assert!(
            hi / lo > 20.0,
            "profiles should span edge..HBM: {lo} .. {hi} GB/s"
        );
    }
}
