//! `.target` manifest parsing: strict, never-panicking, like `.zspill`
//! and `.zten`.
//!
//! The format is a line-based TOML subset — `key = value` pairs, `#`
//! comments, double-quoted strings — chosen so the committed profiles
//! in `rust/targets/` stay hand-editable while the parser keeps the
//! repo's wire-format discipline: every malformed input (unknown key,
//! duplicate key, missing key, zero/negative/non-finite number,
//! truncated line, oversized file, non-UTF-8 bytes) is a structured
//! `Err`, never a panic.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::accel::AccelConfig;

/// Largest `.target` file the loader will read (stat-before-read, the
/// same pre-allocation bound discipline the `.zten` reader uses). A
/// real manifest is a few hundred bytes.
pub const MAX_TARGET_FILE_BYTES: u64 = 64 * 1024;

/// Every key the format accepts, with its requiredness — the single
/// source of truth for parse-time validation and error messages.
const KEYS: &[(&str, bool)] = &[
    ("name", true),
    ("description", false),
    ("dram_gbps", true),
    ("burst_bytes", true),
    ("local_buffer_kib", true),
    ("pe_rows", true),
    ("pe_cols", true),
    ("clock_mhz", true),
    ("int8_tops", false),
    ("pj_per_mac", false),
    ("pj_per_byte_dram", false),
    ("sustained_fraction", false),
];

/// One hardware target: the envelope `accel::sim` simulates against.
///
/// Numeric semantics: `dram_gbps` is the channel's *peak* bandwidth
/// (1 GB = 1e9 bytes, matching datasheets); `sustained_fraction`
/// derates it for page misses/refresh/sharing; `clock_mhz` is the PE
/// array clock the cycle counts are reported in.
#[derive(Debug, Clone, PartialEq)]
pub struct TargetManifest {
    pub name: String,
    pub description: String,
    /// Peak DRAM bandwidth in GB/s.
    pub dram_gbps: f64,
    /// DRAM burst size in bytes; transfers round up to whole bursts.
    pub burst_bytes: usize,
    /// On-chip activation/weight buffer in KiB.
    pub local_buffer_kib: usize,
    /// PE array geometry (MACs/cycle = rows * cols at full
    /// utilization).
    pub pe_rows: usize,
    pub pe_cols: usize,
    /// Core clock in MHz.
    pub clock_mhz: f64,
    /// Advertised int8 throughput in TOPS, when the part quotes one
    /// (informational — the simulator models f32 activations).
    pub int8_tops: Option<f64>,
    /// Energy proxies (pJ); default to the crate's Eyeriss-class
    /// numbers when the manifest omits them.
    pub pj_per_mac: f64,
    pub pj_per_byte_dram: f64,
    /// Sustained/peak DRAM bandwidth derate, in (0, 1].
    pub sustained_fraction: f64,
}

impl Default for TargetManifest {
    /// The crate's historical implicit accelerator:
    /// [`AccelConfig::default`] expressed as a manifest (the committed
    /// `rust/targets/default.target` mirrors this — a parity test pins
    /// all three together).
    fn default() -> Self {
        let c = AccelConfig::default();
        TargetManifest {
            name: "default".to_string(),
            description:
                "Eyeriss-class edge accelerator (the pre-HAL implicit target)"
                    .to_string(),
            dram_gbps: c.dram_bytes_per_cycle * c.freq_ghz,
            burst_bytes: c.burst_bytes,
            local_buffer_kib: c.sram_bytes / 1024,
            pe_rows: c.pe_rows,
            pe_cols: c.pe_cols,
            clock_mhz: c.freq_ghz * 1000.0,
            int8_tops: None,
            pj_per_mac: c.pj_per_mac,
            pj_per_byte_dram: c.pj_per_byte_dram,
            sustained_fraction: c.sustained_frac,
        }
    }
}

impl TargetManifest {
    /// Parse a `.target` document. Strict: unknown or duplicate keys,
    /// missing required keys, and out-of-range values all error.
    pub fn parse(src: &str) -> Result<TargetManifest> {
        // Optional keys fall back to the crate's Eyeriss-class energy /
        // derate defaults; `description`/`int8_tops` default to absent.
        let mut m = TargetManifest {
            description: String::new(),
            int8_tops: None,
            ..TargetManifest::default()
        };
        let mut seen: Vec<&'static str> = Vec::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                anyhow!(
                    "target line {}: expected `key = value`, got {raw:?}",
                    lineno + 1
                )
            })?;
            let key = key.trim();
            let value = value.trim();
            let known = KEYS
                .iter()
                .find(|(k, _)| *k == key)
                .ok_or_else(|| {
                    anyhow!(
                        "target line {}: unknown key {key:?} (valid keys: {})",
                        lineno + 1,
                        key_list()
                    )
                })?
                .0;
            if seen.contains(&known) {
                bail!("target line {}: duplicate key {key:?}", lineno + 1);
            }
            seen.push(known);
            let ctx = |what: &str| {
                format!("target line {}: key {key:?} {what}", lineno + 1)
            };
            // Typed accessors with the per-line error context baked in.
            let s = || {
                parse_string(value)
                    .with_context(|| ctx("wants a quoted string"))
            };
            let f = || {
                parse_f64(value).with_context(|| ctx("wants a number"))
            };
            let u = || {
                parse_usize(value).with_context(|| ctx("wants an integer"))
            };
            match known {
                "name" => m.name = s()?,
                "description" => m.description = s()?,
                "dram_gbps" => m.dram_gbps = f()?,
                "burst_bytes" => m.burst_bytes = u()?,
                "local_buffer_kib" => m.local_buffer_kib = u()?,
                "pe_rows" => m.pe_rows = u()?,
                "pe_cols" => m.pe_cols = u()?,
                "clock_mhz" => m.clock_mhz = f()?,
                "int8_tops" => m.int8_tops = Some(f()?),
                "pj_per_mac" => m.pj_per_mac = f()?,
                "pj_per_byte_dram" => m.pj_per_byte_dram = f()?,
                "sustained_fraction" => m.sustained_fraction = f()?,
                _ => unreachable!("KEYS and the match arms are in sync"),
            }
        }
        for (key, required) in KEYS {
            if *required && !seen.contains(key) {
                bail!("target manifest is missing required key {key:?}");
            }
        }
        m.validate()?;
        Ok(m)
    }

    /// Load and parse a `.target` file, with the `.zten` loader's
    /// stat-before-read size bound.
    pub fn from_file(path: impl AsRef<Path>) -> Result<TargetManifest> {
        let path = path.as_ref();
        let meta = std::fs::metadata(path)
            .with_context(|| format!("target manifest {path:?}"))?;
        anyhow::ensure!(
            meta.len() <= MAX_TARGET_FILE_BYTES,
            "target manifest {path:?} is {} bytes (limit {})",
            meta.len(),
            MAX_TARGET_FILE_BYTES
        );
        let bytes = std::fs::read(path)
            .with_context(|| format!("target manifest {path:?}"))?;
        let src = String::from_utf8(bytes)
            .map_err(|_| anyhow!("target manifest {path:?} is not UTF-8"))?;
        Self::parse(&src)
            .with_context(|| format!("target manifest {path:?}"))
    }

    /// Range-check every field (called by [`TargetManifest::parse`];
    /// public so hand-built manifests can be checked too).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            !self.name.is_empty() && self.name.len() <= 64,
            "target name must be 1..=64 characters"
        );
        anyhow::ensure!(
            self.name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
            "target name {:?} may only contain [A-Za-z0-9_-]",
            self.name
        );
        check_pos_finite("dram_gbps", self.dram_gbps, 100_000.0)?;
        anyhow::ensure!(
            (1..=65536).contains(&self.burst_bytes),
            "burst_bytes {} out of range 1..=65536",
            self.burst_bytes
        );
        anyhow::ensure!(
            (1..=16 * 1024 * 1024).contains(&self.local_buffer_kib),
            "local_buffer_kib {} out of range 1..=16777216",
            self.local_buffer_kib
        );
        for (what, v) in [("pe_rows", self.pe_rows), ("pe_cols", self.pe_cols)] {
            anyhow::ensure!(
                (1..=65536).contains(&v),
                "{what} {v} out of range 1..=65536"
            );
        }
        check_pos_finite("clock_mhz", self.clock_mhz, 1_000_000.0)?;
        if let Some(t) = self.int8_tops {
            check_pos_finite("int8_tops", t, 1_000_000.0)?;
        }
        anyhow::ensure!(
            self.pj_per_mac.is_finite() && self.pj_per_mac >= 0.0,
            "pj_per_mac {} must be finite and >= 0",
            self.pj_per_mac
        );
        anyhow::ensure!(
            self.pj_per_byte_dram.is_finite() && self.pj_per_byte_dram >= 0.0,
            "pj_per_byte_dram {} must be finite and >= 0",
            self.pj_per_byte_dram
        );
        anyhow::ensure!(
            self.sustained_fraction.is_finite()
                && self.sustained_fraction > 0.0
                && self.sustained_fraction <= 1.0,
            "sustained_fraction {} must be in (0, 1]",
            self.sustained_fraction
        );
        Ok(())
    }

    /// Canonical serialization — `parse(to_text(m)) == m` (the
    /// round-trip property the manifest tests pin for every committed
    /// profile).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("name = \"{}\"\n", self.name));
        if !self.description.is_empty() {
            out.push_str(&format!("description = \"{}\"\n", self.description));
        }
        out.push_str(&format!("dram_gbps = {}\n", self.dram_gbps));
        out.push_str(&format!("burst_bytes = {}\n", self.burst_bytes));
        out.push_str(&format!("local_buffer_kib = {}\n", self.local_buffer_kib));
        out.push_str(&format!("pe_rows = {}\n", self.pe_rows));
        out.push_str(&format!("pe_cols = {}\n", self.pe_cols));
        out.push_str(&format!("clock_mhz = {}\n", self.clock_mhz));
        if let Some(t) = self.int8_tops {
            out.push_str(&format!("int8_tops = {t}\n"));
        }
        out.push_str(&format!("pj_per_mac = {}\n", self.pj_per_mac));
        out.push_str(&format!("pj_per_byte_dram = {}\n", self.pj_per_byte_dram));
        out.push_str(&format!(
            "sustained_fraction = {}\n",
            self.sustained_fraction
        ));
        out
    }

    /// Lower this target to the simulator's [`AccelConfig`]. DRAM
    /// bytes/cycle is bandwidth divided by the core clock (the
    /// simulator counts core cycles), so e.g. 12.8 GB/s at 1 GHz is
    /// 12.8 B/cycle.
    pub fn accel_config(&self) -> AccelConfig {
        let freq_ghz = self.clock_mhz / 1000.0;
        AccelConfig {
            pe_rows: self.pe_rows,
            pe_cols: self.pe_cols,
            freq_ghz,
            sram_bytes: self.local_buffer_kib * 1024,
            dram_bytes_per_cycle: self.dram_gbps / freq_ghz,
            burst_bytes: self.burst_bytes,
            pj_per_mac: self.pj_per_mac,
            pj_per_byte_dram: self.pj_per_byte_dram,
            sustained_frac: self.sustained_fraction,
        }
    }

    /// Sustained DRAM bandwidth in GB/s — the peak channel rate
    /// derated by `sustained_fraction`. This is the denominator the
    /// bandwidth ledger (`obs::ledger`) uses to turn byte totals into
    /// channel time: aggregated counters cannot be burst-rounded
    /// per-transfer anymore, but the sustained envelope still
    /// converts them into a target-honest figure.
    pub fn sustained_gbps(&self) -> f64 {
        self.dram_gbps * self.sustained_fraction
    }

    /// Peak f32 throughput in GFLOP/s (2 ops per MAC).
    pub fn peak_gflops(&self) -> f64 {
        (self.pe_rows * self.pe_cols) as f64 * 2.0 * self.clock_mhz / 1000.0
    }

    /// One-line summary for sweep headers and `--json` reports.
    pub fn describe(&self) -> String {
        format!(
            "{}: {}x{} PEs @ {:.0} MHz ({:.1} GFLOP/s f32{}), DRAM {:.1} \
             GB/s peak x{:.2} sustained, {} B bursts, {} KiB buffer",
            self.name,
            self.pe_rows,
            self.pe_cols,
            self.clock_mhz,
            self.peak_gflops(),
            match self.int8_tops {
                Some(t) => format!(", {t:.1} TOPS int8"),
                None => String::new(),
            },
            self.dram_gbps,
            self.sustained_fraction,
            self.burst_bytes,
            self.local_buffer_kib,
        )
    }
}

fn key_list() -> String {
    KEYS.iter()
        .map(|(k, _)| *k)
        .collect::<Vec<_>>()
        .join(", ")
}

/// Strip a trailing `#` comment, respecting `#` inside quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_quotes = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quotes = !in_quotes,
            '#' if !in_quotes => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_string(v: &str) -> Result<String> {
    let inner = v
        .strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .ok_or_else(|| anyhow!("expected a double-quoted string, got {v:?}"))?;
    anyhow::ensure!(
        !inner.contains('"'),
        "embedded quotes are not supported: {v:?}"
    );
    Ok(inner.to_string())
}

fn parse_f64(v: &str) -> Result<f64> {
    let x: f64 = v
        .parse()
        .map_err(|_| anyhow!("expected a number, got {v:?}"))?;
    anyhow::ensure!(x.is_finite(), "expected a finite number, got {v:?}");
    Ok(x)
}

fn parse_usize(v: &str) -> Result<usize> {
    v.parse()
        .map_err(|_| anyhow!("expected a non-negative integer, got {v:?}"))
}

fn check_pos_finite(what: &str, v: f64, max: f64) -> Result<()> {
    anyhow::ensure!(
        v.is_finite() && v > 0.0 && v <= max,
        "{what} {v} must be finite, positive and <= {max}"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn valid_src() -> String {
        TargetManifest::default().to_text()
    }

    #[test]
    fn default_round_trips_through_text() {
        let m = TargetManifest::default();
        let parsed = TargetManifest::parse(&m.to_text()).unwrap();
        assert_eq!(parsed, m);
    }

    #[test]
    fn default_manifest_lowers_to_default_accel_config() {
        // The parity contract: the pre-HAL hard-coded accelerator and
        // the "default" manifest are the same machine.
        assert_eq!(
            TargetManifest::default().accel_config(),
            AccelConfig::default()
        );
    }

    #[test]
    fn comments_and_blank_lines_are_ignored() {
        let src = format!(
            "# a profile\n\n{}\n# trailing comment\n",
            valid_src().replace(
                "dram_gbps = 12.8",
                "dram_gbps = 12.8   # LPDDR4-ish"
            )
        );
        let m = TargetManifest::parse(&src).unwrap();
        assert_eq!(m.dram_gbps, 12.8);
    }

    #[test]
    fn hash_inside_quotes_is_not_a_comment() {
        let src = valid_src().replace("target)\"", "target) #1\"");
        assert_ne!(src, valid_src());
        let m = TargetManifest::parse(&src).unwrap();
        assert!(m.description.ends_with("#1"), "{}", m.description);
    }

    #[test]
    fn unknown_key_errors_with_the_valid_list() {
        let src = format!("{}warp_drive = 9\n", valid_src());
        let e = TargetManifest::parse(&src).unwrap_err().to_string();
        assert!(e.contains("warp_drive"), "{e}");
        assert!(e.contains("dram_gbps"), "{e}");
    }

    #[test]
    fn duplicate_key_errors() {
        let src = format!("{}dram_gbps = 1.0\n", valid_src());
        let e = format!(
            "{:#}",
            TargetManifest::parse(&src).unwrap_err()
        );
        assert!(e.contains("duplicate"), "{e}");
    }

    #[test]
    fn missing_required_key_errors() {
        let src = valid_src()
            .lines()
            .filter(|l| !l.starts_with("dram_gbps"))
            .collect::<Vec<_>>()
            .join("\n");
        let e = TargetManifest::parse(&src).unwrap_err().to_string();
        assert!(e.contains("dram_gbps"), "{e}");
    }

    #[test]
    fn zero_or_negative_bandwidth_errors() {
        for bad in ["0", "-12.8", "nan", "inf"] {
            let src = valid_src()
                .replace("dram_gbps = 12.8", &format!("dram_gbps = {bad}"));
            assert!(
                TargetManifest::parse(&src).is_err(),
                "dram_gbps = {bad} must be rejected"
            );
        }
    }

    #[test]
    fn malformed_lines_error_not_panic() {
        for src in [
            "dram_gbps",                       // no `=`
            "name = \"unterminated",           // truncated quote
            "name = bare",                     // unquoted string
            "burst_bytes = 64.5",              // fractional integer
            "burst_bytes = -64",               // negative integer
            "pe_rows = 99999999999999999999",  // overflow
            "= 3",                             // empty key
        ] {
            assert!(
                TargetManifest::parse(src).is_err(),
                "must reject {src:?}"
            );
        }
    }

    #[test]
    fn truncated_document_never_panics() {
        let full = valid_src();
        // Required keys come before `clock_mhz` in the canonical
        // order, so any cut up to it must error (missing key or a
        // broken line)...
        let strict_until = full.find("clock_mhz").unwrap();
        for cut in 0..full.len() {
            if !full.is_char_boundary(cut) {
                continue;
            }
            // ...and any longer prefix that happens to parse (a cut
            // can land after all required keys, or even mid-number:
            // "1000" -> "10") must still validate.
            if let Ok(m) = TargetManifest::parse(&full[..cut]) {
                assert!(cut > strict_until, "cut {cut} parsed");
                m.validate().unwrap();
            }
        }
    }

    #[test]
    fn out_of_range_fields_error() {
        for (find, replace) in [
            ("burst_bytes = 64", "burst_bytes = 0"),
            ("burst_bytes = 64", "burst_bytes = 131072"),
            ("pe_rows = 16", "pe_rows = 0"),
            ("clock_mhz = 1000", "clock_mhz = 0"),
            ("sustained_fraction = 0.85", "sustained_fraction = 1.5"),
            ("sustained_fraction = 0.85", "sustained_fraction = 0"),
            (
                "name = \"default\"",
                "name = \"has spaces and such\"",
            ),
            ("name = \"default\"", "name = \"\""),
        ] {
            let src = valid_src().replace(find, replace);
            assert_ne!(src, valid_src(), "replacement {replace:?} missed");
            assert!(
                TargetManifest::parse(&src).is_err(),
                "{replace:?} must be rejected"
            );
        }
    }

    #[test]
    fn optional_keys_default_sensibly() {
        let src = "\
name = \"bare\"
dram_gbps = 10
burst_bytes = 32
local_buffer_kib = 128
pe_rows = 8
pe_cols = 8
clock_mhz = 500
";
        let m = TargetManifest::parse(src).unwrap();
        let d = TargetManifest::default();
        assert_eq!(m.description, "");
        assert_eq!(m.int8_tops, None);
        assert_eq!(m.pj_per_mac, d.pj_per_mac);
        assert_eq!(m.pj_per_byte_dram, d.pj_per_byte_dram);
        assert_eq!(m.sustained_fraction, d.sustained_fraction);
        // And it round-trips.
        assert_eq!(TargetManifest::parse(&m.to_text()).unwrap(), m);
    }

    #[test]
    fn accel_config_scales_bandwidth_by_clock() {
        let m = TargetManifest {
            clock_mhz: 500.0,
            dram_gbps: 6.4,
            ..TargetManifest::default()
        };
        let c = m.accel_config();
        assert!((c.freq_ghz - 0.5).abs() < 1e-12);
        assert!((c.dram_bytes_per_cycle - 12.8).abs() < 1e-9);
        assert_eq!(c.sram_bytes, m.local_buffer_kib * 1024);
    }

    #[test]
    fn sustained_bandwidth_derates_the_peak() {
        let m = TargetManifest {
            dram_gbps: 10.0,
            sustained_fraction: 0.8,
            ..TargetManifest::default()
        };
        assert!((m.sustained_gbps() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn describe_mentions_the_envelope() {
        let d = TargetManifest::default().describe();
        assert!(d.contains("16x16"), "{d}");
        assert!(d.contains("12.8"), "{d}");
        let m = TargetManifest {
            int8_tops: Some(4.0),
            ..TargetManifest::default()
        };
        assert!(m.describe().contains("TOPS int8"));
    }
}
