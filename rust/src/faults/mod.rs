//! Deterministic, seeded fault injection — the chaos engine behind
//! `--chaos SPEC` / `ZEBRA_CHAOS` (`rust/docs/robustness.md`).
//!
//! Zebra ships activations compressed, which makes the serving path
//! *more* fragile, not less: one flipped bit in an entropy-dense
//! `.zspill` or ZCLU frame destroys a whole layer's activations
//! (Cavigelli & Benini, arXiv:1810.03979, treat this decode-failure
//! surface as the cost of bandwidth savings). This module exists to
//! *prove* the recovery paths — failover, retry budgets, circuit
//! breakers, dense fallback — under loss, corruption, stalls and
//! crashes, instead of hoping.
//!
//! Two rules make the engine trustworthy:
//!
//! 1. **Strict parsing.** A [`FaultPlan`] comes from a `key=value`
//!    spec with the same never-panicking discipline as `.target` /
//!    `.zspill`: unknown keys, out-of-range probabilities, or junk
//!    numbers are structured errors, never surprises at fire time.
//! 2. **Determinism.** Every decision is a pure function of
//!    `(seed, site, per-site arrival index)` via [`Rng`] — no wall
//!    clock, no global RNG — so the same seed replays the identical
//!    fault schedule at every site regardless of thread interleaving,
//!    and a capped decision journal lets tests assert exactly that.
//!
//! Injection points (threaded as `Option<Arc<FaultInjector>>`, zero
//! cost when absent):
//!
//! - **wire** ([`FaultInjector::on_wire_frame`]): drop a frame, delay
//!   it N µs, flip K payload bits, or truncate it — applied to
//!   encoded ZCLU frames at the cluster writer threads.
//! - **worker** ([`FaultInjector::stall`], [`FaultInjector::slow_mult`],
//!   [`FaultInjector::crash_now`]): stall before execute, multiply
//!   execute latency, or crash the node after its N-th request.
//! - **spill** ([`FaultInjector::corrupt_spill`]): flip a bit in an
//!   encoded `.zspill` frame *after* its checksum was computed, so the
//!   decode-side corruption handling (dense fallback / retransmit) is
//!   exercised.

pub mod breaker;

pub use breaker::{
    Backoff, Breaker, BreakerConfig, BreakerState, Transition,
};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::prng::Rng;

/// Cap on journaled decisions (oldest kept; enough for any test run,
/// bounded for long chaos soaks).
pub const JOURNAL_CAP: usize = 8192;

/// A parsed `--chaos` spec: rates and parameters for every injection
/// point. All-zero (the default) injects nothing.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultPlan {
    /// Seed every decision derives from (`seed=N`, default 0).
    pub seed: u64,
    /// P(drop an outbound wire frame) — `wire.drop=P`.
    pub wire_drop: f32,
    /// Delay an outbound frame `wire_delay_us` µs with probability
    /// `wire_delay_p` — `wire.delay=US@P`.
    pub wire_delay_us: u64,
    pub wire_delay_p: f32,
    /// Flip one bit in each of K frame bytes with probability P —
    /// `wire.corrupt=K@P`.
    pub wire_corrupt_bytes: u64,
    pub wire_corrupt_p: f32,
    /// P(truncate an outbound frame) — `wire.truncate=P`.
    pub wire_truncate_p: f32,
    /// Stall `stall_us` µs before executing a batch with probability
    /// `stall_p` — `worker.stall=US@P`.
    pub stall_us: u64,
    pub stall_p: f32,
    /// Multiply a batch's execute latency by `slow_mult` with
    /// probability `slow_p` — `worker.slow=M@P`.
    pub slow_mult: u32,
    pub slow_p: f32,
    /// Crash the worker after its N-th accepted request (0 = never) —
    /// `worker.crash_after=N`.
    pub crash_after: u64,
    /// P(flip a bit in an encoded spill frame post-checksum) —
    /// `spill.corrupt=P`.
    pub spill_corrupt_p: f32,
}

const SPEC_KEYS: &str = "seed=N, wire.drop=P, wire.delay=US@P, \
     wire.corrupt=K@P, wire.truncate=P, worker.stall=US@P, \
     worker.slow=M@P, worker.crash_after=N, spill.corrupt=P";

fn parse_prob(key: &str, s: &str) -> Result<f32> {
    let p: f32 = s
        .parse()
        .with_context(|| format!("chaos {key}: {s:?} is not a number"))?;
    if !(0.0..=1.0).contains(&p) {
        bail!("chaos {key}: probability {p} outside [0, 1]");
    }
    Ok(p)
}

fn parse_u64(key: &str, s: &str) -> Result<u64> {
    s.parse()
        .with_context(|| format!("chaos {key}: {s:?} is not an integer"))
}

/// Split `N@P` into (count, probability).
fn parse_at(key: &str, s: &str) -> Result<(u64, f32)> {
    let Some((n, p)) = s.split_once('@') else {
        bail!("chaos {key}: expected N@P, got {s:?}");
    };
    Ok((parse_u64(key, n)?, parse_prob(key, p)?))
}

impl FaultPlan {
    /// Parse a comma-separated `key=value` spec. Strict: unknown keys
    /// and malformed values are errors listing the valid grammar.
    /// Empty segments (trailing commas) are tolerated.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty())
        {
            let Some((key, val)) = part.split_once('=') else {
                bail!(
                    "chaos spec segment {part:?} is not key=value \
                     (valid keys: {SPEC_KEYS})"
                );
            };
            let (key, val) = (key.trim(), val.trim());
            match key {
                "seed" => plan.seed = parse_u64(key, val)?,
                "wire.drop" => plan.wire_drop = parse_prob(key, val)?,
                "wire.delay" => {
                    (plan.wire_delay_us, plan.wire_delay_p) =
                        parse_at(key, val)?;
                }
                "wire.corrupt" => {
                    (plan.wire_corrupt_bytes, plan.wire_corrupt_p) =
                        parse_at(key, val)?;
                    if plan.wire_corrupt_bytes == 0 {
                        bail!("chaos wire.corrupt: K must be >= 1");
                    }
                }
                "wire.truncate" => {
                    plan.wire_truncate_p = parse_prob(key, val)?;
                }
                "worker.stall" => {
                    (plan.stall_us, plan.stall_p) = parse_at(key, val)?;
                }
                "worker.slow" => {
                    let (m, p) = parse_at(key, val)?;
                    if m < 2 {
                        bail!("chaos worker.slow: multiplier must be >= 2");
                    }
                    plan.slow_mult = u32::try_from(m).unwrap_or(u32::MAX);
                    plan.slow_p = p;
                }
                "worker.crash_after" => {
                    plan.crash_after = parse_u64(key, val)?;
                }
                "spill.corrupt" => {
                    plan.spill_corrupt_p = parse_prob(key, val)?;
                }
                other => bail!(
                    "chaos spec has unknown key {other:?} \
                     (valid keys: {SPEC_KEYS})"
                ),
            }
        }
        Ok(plan)
    }

    /// The plan from `ZEBRA_CHAOS`, if the variable is set (the CLI's
    /// `--chaos` flag wins over the environment).
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("ZEBRA_CHAOS") {
            Ok(spec) if !spec.trim().is_empty() => {
                Ok(Some(FaultPlan::parse(&spec)?))
            }
            _ => Ok(None),
        }
    }

    /// Does this plan inject anything at all?
    pub fn is_active(&self) -> bool {
        self.wire_drop > 0.0
            || self.wire_delay_p > 0.0
            || self.wire_corrupt_p > 0.0
            || self.wire_truncate_p > 0.0
            || self.stall_p > 0.0
            || self.slow_p > 0.0
            || self.crash_after > 0
            || self.spill_corrupt_p > 0.0
    }

    /// One-line operator summary for node startup logs.
    pub fn summary(&self) -> String {
        format!(
            "seed={} wire[drop={} delay={}us@{} corrupt={}B@{} trunc={}] \
             worker[stall={}us@{} slow=x{}@{} crash_after={}] \
             spill[corrupt={}]",
            self.seed,
            self.wire_drop,
            self.wire_delay_us,
            self.wire_delay_p,
            self.wire_corrupt_bytes,
            self.wire_corrupt_p,
            self.wire_truncate_p,
            self.stall_us,
            self.stall_p,
            self.slow_mult,
            self.slow_p,
            self.crash_after,
            self.spill_corrupt_p,
        )
    }
}

/// FNV-1a over a site name (same constants as the router's key hash);
/// folds the site into the decision seed.
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The live injector: one per node, shared `Arc` across its threads.
/// Each decision draws a fresh [`Rng`] seeded from
/// `(plan.seed, site, per-site sequence number)`, so schedules are
/// per-site deterministic no matter how threads interleave.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    /// Per-site arrival counters.
    seqs: Mutex<HashMap<String, u64>>,
    /// Capped decision journal (`site#seq action`) — the replay-by-seed
    /// acceptance surface.
    journal: Mutex<Vec<String>>,
    /// Requests seen by [`FaultInjector::crash_now`].
    handled: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Arc<FaultInjector> {
        Arc::new(FaultInjector {
            plan,
            seqs: Mutex::new(HashMap::new()),
            journal: Mutex::new(Vec::new()),
            handled: AtomicU64::new(0),
        })
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// True when any fault can fire — callers gate work that only
    /// exists to observe faults (e.g. spill self-check decode).
    pub fn active(&self) -> bool {
        self.plan.is_active()
    }

    /// Deterministic per-(site, arrival) RNG.
    fn draw(&self, site: &str) -> (Rng, u64) {
        let seq = {
            let mut seqs = self.seqs.lock().unwrap();
            let n = seqs.entry(site.to_string()).or_insert(0);
            let seq = *n;
            *n += 1;
            seq
        };
        let seed = self.plan.seed
            ^ fnv64(site.as_bytes())
            ^ seq.wrapping_mul(0x9E3779B97F4A7C15);
        (Rng::new(seed), seq)
    }

    fn note(&self, site: &str, seq: u64, what: &str) {
        let mut j = self.journal.lock().unwrap();
        if j.len() < JOURNAL_CAP {
            j.push(format!("{site}#{seq} {what}"));
        }
    }

    /// Snapshot of every journaled decision, in arrival order per
    /// site (interleaving across sites follows wall scheduling; tests
    /// compare sorted or per-site).
    pub fn journal(&self) -> Vec<String> {
        self.journal.lock().unwrap().clone()
    }

    /// Apply wire faults to one encoded outbound frame. Returns
    /// `false` when the frame must be dropped; otherwise the buffer
    /// may have been delayed, bit-flipped, or truncated in place.
    ///
    /// Corruption skips the 8-byte length field (bytes 20..28 of a
    /// ZCLU header): mangling the length turns an integrity fault into
    /// a stall fault, and stalls are `wire.delay`'s job. Everything
    /// else — magic, checksum, payload — is fair game; the peer's
    /// strict parse tears the connection down and failover takes over.
    pub fn on_wire_frame(&self, site: &str, frame: &mut Vec<u8>) -> bool {
        if self.plan.wire_drop == 0.0
            && self.plan.wire_delay_p == 0.0
            && self.plan.wire_corrupt_p == 0.0
            && self.plan.wire_truncate_p == 0.0
        {
            return true;
        }
        let (mut rng, seq) = self.draw(site);
        if self.plan.wire_drop > 0.0 && rng.chance(self.plan.wire_drop) {
            self.note(site, seq, "drop");
            return false;
        }
        if self.plan.wire_delay_p > 0.0 && rng.chance(self.plan.wire_delay_p)
        {
            self.note(
                site,
                seq,
                &format!("delay {}us", self.plan.wire_delay_us),
            );
            std::thread::sleep(Duration::from_micros(
                self.plan.wire_delay_us,
            ));
        }
        if self.plan.wire_corrupt_p > 0.0
            && rng.chance(self.plan.wire_corrupt_p)
            && !frame.is_empty()
        {
            let mut flipped = 0;
            for _ in 0..self.plan.wire_corrupt_bytes {
                // Bounded retry past the length field; a tiny frame
                // that is all length field just skips the flip.
                for _ in 0..16 {
                    let off = rng.below(frame.len() as u64) as usize;
                    if (20..28).contains(&off) && frame.len() > 28 {
                        continue;
                    }
                    frame[off] ^= 1 << rng.below(8);
                    flipped += 1;
                    break;
                }
            }
            if flipped > 0 {
                self.note(site, seq, &format!("corrupt {flipped}"));
            }
        }
        if self.plan.wire_truncate_p > 0.0
            && rng.chance(self.plan.wire_truncate_p)
            && frame.len() > 1
        {
            let keep = 1 + rng.below(frame.len() as u64 - 1) as usize;
            frame.truncate(keep);
            self.note(site, seq, &format!("truncate {keep}"));
        }
        true
    }

    /// Stall duration to sleep before executing a batch, if this
    /// arrival drew one.
    pub fn stall(&self) -> Option<Duration> {
        if self.plan.stall_p == 0.0 {
            return None;
        }
        let (mut rng, seq) = self.draw("worker.stall");
        if rng.chance(self.plan.stall_p) {
            self.note(
                "worker.stall",
                seq,
                &format!("stall {}us", self.plan.stall_us),
            );
            return Some(Duration::from_micros(self.plan.stall_us));
        }
        None
    }

    /// Execute-latency multiplier for this batch, if drawn (the caller
    /// sleeps `(mult - 1) x` the measured execute time).
    pub fn slow_mult(&self) -> Option<u32> {
        if self.plan.slow_p == 0.0 {
            return None;
        }
        let (mut rng, seq) = self.draw("worker.slow");
        if rng.chance(self.plan.slow_p) {
            self.note(
                "worker.slow",
                seq,
                &format!("slow x{}", self.plan.slow_mult),
            );
            return Some(self.plan.slow_mult.max(2));
        }
        None
    }

    /// Count one accepted request; true exactly once, on the N-th
    /// (`worker.crash_after=N`) — the caller then severs the node.
    pub fn crash_now(&self) -> bool {
        if self.plan.crash_after == 0 {
            return false;
        }
        let n = self.handled.fetch_add(1, Ordering::Relaxed) + 1;
        if n == self.plan.crash_after {
            self.note("worker.crash", 0, &format!("crash after {n}"));
            return true;
        }
        false
    }

    /// Flip one bit per journaled corruption in an encoded `.zspill`
    /// frame (post-checksum, so the decode side must catch it).
    /// Returns true when the buffer was mutated.
    pub fn corrupt_spill(&self, bytes: &mut Vec<u8>) -> bool {
        if self.plan.spill_corrupt_p == 0.0 || bytes.is_empty() {
            return false;
        }
        let (mut rng, seq) = self.draw("spill.ship");
        if !rng.chance(self.plan.spill_corrupt_p) {
            return false;
        }
        let off = rng.below(bytes.len() as u64) as usize;
        bytes[off] ^= 1 << rng.below(8);
        self.note("spill.ship", seq, &format!("corrupt @{off}"));
        true
    }
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultInjector")
            .field("plan", &self.plan)
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse(
            "seed=7, wire.drop=0.05, wire.delay=500@0.1, \
             wire.corrupt=2@0.2, wire.truncate=0.01, \
             worker.stall=1000@0.3, worker.slow=4@0.25, \
             worker.crash_after=40, spill.corrupt=0.5,",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.wire_drop, 0.05);
        assert_eq!((p.wire_delay_us, p.wire_delay_p), (500, 0.1));
        assert_eq!((p.wire_corrupt_bytes, p.wire_corrupt_p), (2, 0.2));
        assert_eq!(p.wire_truncate_p, 0.01);
        assert_eq!((p.stall_us, p.stall_p), (1000, 0.3));
        assert_eq!((p.slow_mult, p.slow_p), (4, 0.25));
        assert_eq!(p.crash_after, 40);
        assert_eq!(p.spill_corrupt_p, 0.5);
        assert!(p.is_active());
        assert!(!FaultPlan::parse("seed=3").unwrap().is_active());
        assert!(!FaultPlan::default().is_active());
        assert!(!p.summary().is_empty());
    }

    #[test]
    fn rejects_malformed_specs_with_named_errors() {
        for (spec, needle) in [
            ("wire.drop=1.5", "outside [0, 1]"),
            ("wire.drop=-0.1", "outside [0, 1]"),
            ("wire.drop=abc", "not a number"),
            ("seed=xyz", "not an integer"),
            ("wire.corrupt=0.5", "expected N@P"),
            ("wire.corrupt=0@0.5", "K must be >= 1"),
            ("worker.slow=1@0.5", "multiplier must be >= 2"),
            ("bogus.key=1", "unknown key"),
            ("dropframes", "not key=value"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err().to_string();
            assert!(err.contains(needle), "{spec:?} -> {err}");
        }
        // Unknown-key errors teach the grammar.
        let err = FaultPlan::parse("zap=1").unwrap_err().to_string();
        assert!(err.contains("wire.drop=P"), "{err}");
    }

    #[test]
    fn same_seed_same_schedule_different_seed_different() {
        let plan = FaultPlan::parse(
            "seed=42,wire.drop=0.3,wire.corrupt=1@0.3,spill.corrupt=0.4",
        )
        .unwrap();
        let run = |plan: FaultPlan| {
            let inj = FaultInjector::new(plan);
            let mut outcomes = Vec::new();
            for i in 0..200u64 {
                let mut frame = vec![0u8; 64 + (i as usize % 32)];
                let delivered =
                    inj.on_wire_frame("wire.w0.out", &mut frame);
                outcomes.push((delivered, frame));
                let mut spill = vec![1u8; 40];
                inj.corrupt_spill(&mut spill);
                outcomes.push((true, spill));
            }
            (outcomes, inj.journal())
        };
        let (a, ja) = run(plan);
        let (b, jb) = run(plan);
        assert_eq!(a, b, "same seed must replay bit-identically");
        assert_eq!(ja, jb);
        assert!(!ja.is_empty(), "the schedule must have fired");
        let (_, jc) = run(FaultPlan { seed: 43, ..plan });
        assert_ne!(ja, jc, "a different seed must reschedule");
    }

    #[test]
    fn sites_are_independent_streams() {
        let plan = FaultPlan::parse("seed=1,wire.drop=0.5").unwrap();
        let inj = FaultInjector::new(plan);
        let mut a = Vec::new();
        let mut b = Vec::new();
        for _ in 0..100 {
            let mut f = vec![0u8; 32];
            a.push(inj.on_wire_frame("wire.w0.out", &mut f));
            let mut f = vec![0u8; 32];
            b.push(inj.on_wire_frame("wire.w1.out", &mut f));
        }
        assert_ne!(a, b, "sites must not mirror each other");
        // Rates land near p for both sites.
        for drops in [&a, &b] {
            let n = drops.iter().filter(|&&d| !d).count();
            assert!((25..=75).contains(&n), "drop count {n} far from p=0.5");
        }
    }

    #[test]
    fn corruption_never_touches_the_length_field() {
        let plan =
            FaultPlan::parse("seed=9,wire.corrupt=4@1.0").unwrap();
        let inj = FaultInjector::new(plan);
        for _ in 0..200 {
            let mut frame = vec![0u8; 64];
            assert!(inj.on_wire_frame("wire.out", &mut frame));
            assert_eq!(
                &frame[20..28],
                &[0u8; 8],
                "length field must never be mangled"
            );
            assert!(
                frame.iter().any(|&b| b != 0),
                "corruption at p=1 must flip something"
            );
        }
    }

    #[test]
    fn crash_fires_exactly_once_at_n() {
        let plan = FaultPlan::parse("worker.crash_after=5").unwrap();
        let inj = FaultInjector::new(plan);
        let fired: Vec<bool> = (0..10).map(|_| inj.crash_now()).collect();
        assert_eq!(
            fired,
            [false, false, false, false, true, false, false, false, false,
             false]
        );
        // Disabled plans never fire.
        let off = FaultInjector::new(FaultPlan::default());
        assert!((0..10).all(|_| !off.crash_now()));
    }

    #[test]
    fn corrupt_spill_defeats_the_frame_checksum() {
        use crate::tensor::Tensor;
        let codec = crate::compress::from_name("zero-block", 2).unwrap();
        let x = Tensor::from_vec(
            &[1, 4, 4],
            (0..16).map(|i| if i % 3 == 0 { 0.0 } else { i as f32 })
                .collect(),
        );
        let clean = codec.encode(&x).to_bytes();
        assert!(crate::compress::EncodedView::parse(&clean).is_ok());
        let plan = FaultPlan::parse("seed=2,spill.corrupt=1.0").unwrap();
        let inj = FaultInjector::new(plan);
        for _ in 0..50 {
            let mut bytes = clean.clone();
            assert!(inj.corrupt_spill(&mut bytes));
            assert!(
                crate::compress::EncodedView::parse(&bytes).is_err(),
                "a post-checksum bit flip must be detected"
            );
        }
    }

    #[test]
    fn env_plan_is_optional_and_strict() {
        // Not set in the test environment -> None. (Set/unset dances
        // are avoided: env mutation races parallel tests.)
        if std::env::var("ZEBRA_CHAOS").is_err() {
            assert!(FaultPlan::from_env().unwrap().is_none());
        }
    }
}
