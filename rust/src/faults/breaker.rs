//! Per-worker circuit breaker and deterministic exponential backoff —
//! the self-healing half of the chaos engine (`rust/docs/robustness.md`).
//!
//! The breaker is a three-state machine over *consecutive* failures:
//!
//! ```text
//!            >= threshold failures
//!   Closed ──────────────────────────> Open
//!     ^                                  │ probe interval elapses
//!     │ probe succeeds                   v
//!     └─────────────────────────── Half-Open
//!                                        │ probe fails
//!                                        └──> Open (backoff doubled,
//!                                             capped at max_backoff)
//! ```
//!
//! Time is an explicit `now_ms` argument on every method — the breaker
//! holds no clock, so property tests (and replay) drive it
//! deterministically. The router feeds it a monotonic
//! milliseconds-since-start counter.

use crate::util::prng::Rng;

/// Breaker tuning. `threshold` consecutive failures open the breaker;
/// `probe_ms` is the first Open interval; each Half-Open failure
/// doubles the interval up to `max_backoff_ms`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerConfig {
    /// Consecutive failures that trip Closed -> Open.
    pub threshold: u32,
    /// First Open interval before a Half-Open probe is allowed (ms).
    pub probe_ms: u64,
    /// Cap on the doubled Open interval (ms).
    pub max_backoff_ms: u64,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            threshold: 3,
            probe_ms: 1_000,
            max_backoff_ms: 30_000,
        }
    }
}

/// The three breaker states. Wire/scrape code is stable:
/// Closed=0, Open=1, HalfOpen=2 (`zebra_breaker_state`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    /// Stable numeric code for the `zebra_breaker_state` gauge.
    pub fn code(self) -> u64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::Open => 1,
            BreakerState::HalfOpen => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// A state transition the caller should surface (flight event,
/// transition counter). `Reopened` is Half-Open -> Open with the
/// backoff doubled; `Opened` is the initial Closed -> Open trip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transition {
    Opened,
    HalfOpened,
    Closed,
    Reopened,
}

/// Circuit breaker over one worker link. All methods are cheap and
/// non-blocking; the caller serializes access (the router keeps one
/// behind the link's mutex).
#[derive(Debug, Clone)]
pub struct Breaker {
    cfg: BreakerConfig,
    state: BreakerState,
    /// Consecutive failures while Closed.
    failures: u32,
    /// When the current Open interval started (caller's ms clock).
    opened_at_ms: u64,
    /// Current Open interval; doubles on each Half-Open failure.
    backoff_ms: u64,
    transitions: u64,
}

impl Breaker {
    pub fn new(cfg: BreakerConfig) -> Breaker {
        let cfg = BreakerConfig {
            threshold: cfg.threshold.max(1),
            probe_ms: cfg.probe_ms.max(1),
            max_backoff_ms: cfg.max_backoff_ms.max(cfg.probe_ms.max(1)),
        };
        Breaker {
            backoff_ms: cfg.probe_ms,
            cfg,
            state: BreakerState::Closed,
            failures: 0,
            opened_at_ms: 0,
            transitions: 0,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Total state transitions (the `zebra_breaker_transitions_total`
    /// counter).
    pub fn transitions(&self) -> u64 {
        self.transitions
    }

    /// Current Open interval (exposed for tests and reports).
    pub fn backoff_ms(&self) -> u64 {
        self.backoff_ms
    }

    /// May the caller attempt work (a dial, a dispatch) right now?
    /// Closed and Half-Open admit; Open refuses until [`Breaker::poll`]
    /// expires the interval.
    pub fn admits(&self) -> bool {
        self.state != BreakerState::Open
    }

    /// Record a success. Half-Open -> Closed (the probe worked; backoff
    /// resets); Closed just clears the consecutive-failure count.
    pub fn on_success(&mut self) -> Option<Transition> {
        self.failures = 0;
        match self.state {
            BreakerState::HalfOpen => {
                self.state = BreakerState::Closed;
                self.backoff_ms = self.cfg.probe_ms;
                self.transitions += 1;
                Some(Transition::Closed)
            }
            // Open admits no work, so a success here means the caller
            // raced a poll; treat it as the Half-Open success.
            BreakerState::Open => {
                self.state = BreakerState::Closed;
                self.backoff_ms = self.cfg.probe_ms;
                self.transitions += 1;
                Some(Transition::Closed)
            }
            BreakerState::Closed => None,
        }
    }

    /// Record a failure at `now_ms`. Closed counts toward the
    /// threshold; Half-Open re-opens with the interval doubled
    /// (capped); Open is already refusing and absorbs it.
    pub fn on_failure(&mut self, now_ms: u64) -> Option<Transition> {
        match self.state {
            BreakerState::Closed => {
                self.failures += 1;
                if self.failures >= self.cfg.threshold {
                    self.trip(now_ms, self.cfg.probe_ms);
                    Some(Transition::Opened)
                } else {
                    None
                }
            }
            BreakerState::HalfOpen => {
                let doubled = self
                    .backoff_ms
                    .saturating_mul(2)
                    .min(self.cfg.max_backoff_ms);
                self.trip(now_ms, doubled);
                Some(Transition::Reopened)
            }
            BreakerState::Open => None,
        }
    }

    /// Advance time: an Open breaker whose interval has elapsed moves
    /// to Half-Open (one probe is now admitted).
    pub fn poll(&mut self, now_ms: u64) -> Option<Transition> {
        if self.state == BreakerState::Open
            && now_ms.saturating_sub(self.opened_at_ms) >= self.backoff_ms
        {
            self.state = BreakerState::HalfOpen;
            self.transitions += 1;
            return Some(Transition::HalfOpened);
        }
        None
    }

    fn trip(&mut self, now_ms: u64, interval_ms: u64) {
        self.state = BreakerState::Open;
        self.opened_at_ms = now_ms;
        self.backoff_ms = interval_ms;
        self.failures = 0;
        self.transitions += 1;
    }
}

/// Deterministic exponential backoff with jitter for redial pacing:
/// attempt `k` waits in `[base * 2^k / 2, base * 2^k]` ms (capped at
/// `max_ms`), with the jitter drawn from the seed — the same seed
/// replays the same delay schedule, per `rust/docs/robustness.md`.
#[derive(Debug, Clone)]
pub struct Backoff {
    base_ms: u64,
    max_ms: u64,
    seed: u64,
    attempt: u32,
}

impl Backoff {
    pub fn new(base_ms: u64, max_ms: u64, seed: u64) -> Backoff {
        let base_ms = base_ms.max(1);
        Backoff { base_ms, max_ms: max_ms.max(base_ms), seed, attempt: 0 }
    }

    /// Consecutive failed attempts so far (the retry-budget gauge).
    pub fn attempt(&self) -> u32 {
        self.attempt
    }

    /// Delay before the next attempt, advancing the attempt counter.
    pub fn next_delay_ms(&mut self) -> u64 {
        let shift = self.attempt.min(32);
        let exp = self
            .base_ms
            .saturating_mul(1u64 << shift)
            .min(self.max_ms)
            .max(1);
        let mut rng = Rng::new(
            self.seed ^ (self.attempt as u64).wrapping_mul(0x9E3779B97F4A7C15),
        );
        let jitter = rng.below(exp / 2 + 1);
        self.attempt = self.attempt.saturating_add(1);
        exp - jitter
    }

    /// A successful attempt resets the schedule to the base delay.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Config};

    fn cfg(threshold: u32, probe_ms: u64, max_ms: u64) -> BreakerConfig {
        BreakerConfig { threshold, probe_ms, max_backoff_ms: max_ms }
    }

    #[test]
    fn trips_after_threshold_and_cycles_through_half_open() {
        let mut b = Breaker::new(cfg(3, 100, 1000));
        assert_eq!(b.on_failure(0), None);
        assert_eq!(b.on_failure(1), None);
        assert_eq!(b.on_failure(2), Some(Transition::Opened));
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.admits());
        // Not yet expired.
        assert_eq!(b.poll(50), None);
        assert_eq!(b.poll(102), Some(Transition::HalfOpened));
        assert!(b.admits());
        assert_eq!(b.on_success(), Some(Transition::Closed));
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.transitions(), 3);
    }

    #[test]
    fn half_open_failure_doubles_the_backoff_up_to_the_cap() {
        let mut b = Breaker::new(cfg(1, 100, 350));
        assert_eq!(b.on_failure(0), Some(Transition::Opened));
        assert_eq!(b.backoff_ms(), 100);
        b.poll(100).unwrap();
        assert_eq!(b.on_failure(100), Some(Transition::Reopened));
        assert_eq!(b.backoff_ms(), 200);
        b.poll(300).unwrap();
        assert_eq!(b.on_failure(300), Some(Transition::Reopened));
        assert_eq!(b.backoff_ms(), 350, "doubling is capped");
        // A later success resets the interval to the probe base.
        b.poll(650).unwrap();
        b.on_success().unwrap();
        assert_eq!(b.backoff_ms(), 100);
    }

    #[test]
    fn closed_success_clears_the_consecutive_count() {
        let mut b = Breaker::new(cfg(2, 100, 1000));
        assert_eq!(b.on_failure(0), None);
        assert_eq!(b.on_success(), None);
        // The streak restarted, so one more failure does not trip it.
        assert_eq!(b.on_failure(1), None);
        assert_eq!(b.on_failure(2), Some(Transition::Opened));
    }

    /// Property: only legal transitions ever occur, and each reported
    /// transition lands in the state it names.
    #[test]
    fn prop_only_legal_transitions() {
        forall(Config::cases(200), |rng| {
            let mut b = Breaker::new(cfg(
                rng.range(1, 5) as u32,
                rng.range(1, 50) as u64,
                rng.range(50, 400) as u64,
            ));
            let mut now = 0u64;
            let mut prev = b.state();
            for _ in 0..rng.range(10, 120) {
                now += rng.range(0, 60) as u64;
                let t = match rng.range(0, 2) {
                    0 => b.on_failure(now),
                    1 => b.on_success(),
                    _ => b.poll(now),
                };
                let cur = b.state();
                if let Some(t) = t {
                    let legal = matches!(
                        (prev, t, cur),
                        (
                            BreakerState::Closed,
                            Transition::Opened,
                            BreakerState::Open
                        ) | (
                            BreakerState::Open,
                            Transition::HalfOpened,
                            BreakerState::HalfOpen
                        ) | (
                            BreakerState::Open,
                            Transition::Closed,
                            BreakerState::Closed
                        ) | (
                            BreakerState::HalfOpen,
                            Transition::Closed,
                            BreakerState::Closed
                        ) | (
                            BreakerState::HalfOpen,
                            Transition::Reopened,
                            BreakerState::Open
                        )
                    );
                    assert!(legal, "illegal {prev:?} -{t:?}-> {cur:?}");
                } else {
                    assert_eq!(prev, cur, "state moved without a transition");
                }
                prev = cur;
            }
        });
    }

    /// Property: an Open breaker always yields a Half-Open probe once
    /// its interval elapses — it can never stick Open forever.
    #[test]
    fn prop_open_always_expires_to_half_open() {
        forall(Config::cases(200), |rng| {
            let mut b = Breaker::new(cfg(
                rng.range(1, 4) as u32,
                rng.range(1, 100) as u64,
                rng.range(100, 1000) as u64,
            ));
            let mut now = rng.range(0, 1000) as u64;
            // Drive to Open; `now` stops advancing at the trip, so the
            // breaker's opened_at is exactly `now`.
            while b.state() != BreakerState::Open {
                b.on_failure(now);
                if b.state() != BreakerState::Open {
                    now += rng.range(0, 3) as u64;
                }
            }
            let interval = b.backoff_ms();
            // Any poll strictly before expiry keeps it Open ...
            if interval > 1 {
                let early = now + rng.range(0, (interval - 1) as usize) as u64;
                assert_eq!(b.poll(early), None, "expired early");
            }
            // ... and the poll at/after expiry always half-opens.
            assert_eq!(
                b.poll(now + interval),
                Some(Transition::HalfOpened),
                "Open must expire after its interval"
            );
        });
    }

    /// Property: every Half-Open failure re-opens with the interval
    /// exactly doubled, capped at `max_backoff_ms`.
    #[test]
    fn prop_half_open_failure_doubles_backoff() {
        forall(Config::cases(200), |rng| {
            let probe = rng.range(1, 50) as u64;
            let max = rng.range(50, 2000) as u64;
            let mut b = Breaker::new(cfg(1, probe, max));
            let mut now = 0u64;
            b.on_failure(now);
            for _ in 0..rng.range(1, 12) {
                let before = b.backoff_ms();
                now += before;
                assert_eq!(b.poll(now), Some(Transition::HalfOpened));
                assert_eq!(
                    b.on_failure(now),
                    Some(Transition::Reopened)
                );
                assert_eq!(
                    b.backoff_ms(),
                    before.saturating_mul(2).min(max),
                    "doubling must be exact and capped"
                );
            }
        });
    }

    #[test]
    fn backoff_is_deterministic_and_bounded() {
        let mut a = Backoff::new(50, 1000, 7);
        let mut b = Backoff::new(50, 1000, 7);
        let da: Vec<u64> = (0..10).map(|_| a.next_delay_ms()).collect();
        let db: Vec<u64> = (0..10).map(|_| b.next_delay_ms()).collect();
        assert_eq!(da, db, "same seed, same schedule");
        for (k, &d) in da.iter().enumerate() {
            let exp = (50u64 << k.min(32)).min(1000);
            assert!(d >= exp / 2 && d <= exp, "attempt {k}: {d} vs {exp}");
        }
        // Different seeds decorrelate the jitter.
        let mut c = Backoff::new(50, 1000, 8);
        let dc: Vec<u64> = (0..10).map(|_| c.next_delay_ms()).collect();
        assert_ne!(da, dc);
        // Reset restarts the schedule.
        a.reset();
        assert_eq!(a.next_delay_ms(), da[0]);
    }
}
