//! Layer-by-layer CNN accelerator simulator (DESIGN.md §9).
//!
//! The paper's evaluation assumes "a layer-by-layer hardware processing
//! that will store the activation maps to external DRAM for each
//! convolutional layer" (Sec. III-B, Table V). This module is that
//! substrate, made concrete: a weight-stationary PE array with
//! double-buffered SRAM and a burst-quantized DRAM channel, where every
//! activation spill goes through a pluggable [`Codec`] — so the Zebra
//! codec's savings (and the baselines' lack thereof) become cycles,
//! joules and GB/s instead of percentages.

mod dram;
mod pe;
mod sim;

pub use dram::DramModel;
pub use pe::PeArray;
pub use sim::{simulate_analytic, simulate_analytic_on, simulate_trace,
              simulate_trace_on, simulate_trace_with, LayerDesc,
              LayerStats, SimReport};

/// Accelerator configuration. Defaults model a small edge accelerator
/// in the Eyeriss class (16x16 MACs @ 1 GHz, LPDDR4-ish single channel)
/// — the setting where the paper's activation-bandwidth argument bites.
///
/// Configs normally come from a [`hal::TargetManifest`](crate::hal)
/// (`.target` file or builtin profile) via
/// [`TargetManifest::accel_config`](crate::hal::TargetManifest::accel_config);
/// the `default` profile lowers to exactly this `Default` (pinned by a
/// parity test), so hand-constructed configs and manifest-driven ones
/// agree.
#[derive(Debug, Clone, PartialEq)]
pub struct AccelConfig {
    /// PE array dimensions (MACs = rows * cols per cycle at 100% util).
    pub pe_rows: usize,
    pub pe_cols: usize,
    /// Core clock in GHz.
    pub freq_ghz: f64,
    /// On-chip activation/weight buffer (bytes). Layers whose working
    /// set fits are still spilled (the paper's layer-by-layer
    /// assumption) but weights stream once.
    pub sram_bytes: usize,
    /// DRAM peak bandwidth in bytes/cycle (e.g. 12.8 GB/s @ 1 GHz
    /// = 12.8 B/cycle).
    pub dram_bytes_per_cycle: f64,
    /// DRAM burst size in bytes; every transfer rounds up to bursts.
    pub burst_bytes: usize,
    /// Energy proxies.
    pub pj_per_mac: f64,
    pub pj_per_byte_dram: f64,
    /// Sustained/peak DRAM bandwidth derate (page misses, refresh,
    /// channel sharing), in (0, 1]. Was a constant 0.85 inside
    /// [`DramModel`] before the HAL made it a per-target knob.
    pub sustained_frac: f64,
}

impl Default for AccelConfig {
    fn default() -> Self {
        AccelConfig {
            pe_rows: 16,
            pe_cols: 16,
            freq_ghz: 1.0,
            sram_bytes: 512 * 1024,
            dram_bytes_per_cycle: 12.8,
            burst_bytes: 64,
            pj_per_mac: 0.5,
            // DRAM access energy dominates on-chip compute by ~2 orders
            // of magnitude (Eyeriss, ref [9]) — the premise of the paper.
            pj_per_byte_dram: 60.0,
            sustained_frac: 0.85,
        }
    }
}

impl AccelConfig {
    /// Peak MACs per cycle.
    pub fn peak_macs(&self) -> usize {
        self.pe_rows * self.pe_cols
    }

    /// Round a byte count up to whole DRAM bursts.
    pub fn burst_quantize(&self, bytes: usize) -> usize {
        bytes.div_ceil(self.burst_bytes) * self.burst_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = AccelConfig::default();
        assert_eq!(c.peak_macs(), 256);
        assert_eq!(c.burst_quantize(0), 0);
        assert_eq!(c.burst_quantize(1), 64);
        assert_eq!(c.burst_quantize(64), 64);
        assert_eq!(c.burst_quantize(65), 128);
    }
}
