//! DRAM channel model: burst-quantized transfers at a fixed peak
//! bandwidth, with simple page-hit efficiency derating.
//!
//! This is a transaction-level model, not cycle-accurate DRAM timing —
//! the paper's bandwidth numbers are byte counts, and what we add on
//! top is exactly the two effects that matter for small-block codecs:
//! burst rounding (a 4-byte index read still moves a 64-byte burst) and
//! sustained-vs-peak derating.

use super::AccelConfig;

/// Accumulates DRAM traffic and converts it to cycles/energy.
#[derive(Debug, Clone, Default)]
pub struct DramModel {
    /// Logical payload bytes requested.
    pub logical_bytes: u64,
    /// Bytes actually moved after burst quantization.
    pub bus_bytes: u64,
    /// Number of discrete transfers (DMA descriptors).
    pub transfers: u64,
}

impl DramModel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one transfer of `bytes` logical bytes.
    pub fn transfer(&mut self, cfg: &AccelConfig, bytes: usize) {
        if bytes == 0 {
            return;
        }
        self.logical_bytes += bytes as u64;
        self.bus_bytes += cfg.burst_quantize(bytes) as u64;
        self.transfers += 1;
    }

    /// Cycles to move the accumulated traffic at sustained bandwidth.
    /// Sustained = peak * `cfg.sustained_frac` (page misses, refresh,
    /// channel sharing — a per-target knob since the HAL landed).
    pub fn cycles(&self, cfg: &AccelConfig) -> u64 {
        let sustained = cfg.dram_bytes_per_cycle * cfg.sustained_frac;
        (self.bus_bytes as f64 / sustained).ceil() as u64
    }

    /// Energy in pJ for the accumulated traffic.
    pub fn energy_pj(&self, cfg: &AccelConfig) -> f64 {
        self.bus_bytes as f64 * cfg.pj_per_byte_dram
    }

    /// Bus efficiency: logical / moved (1.0 = no burst waste).
    pub fn efficiency(&self) -> f64 {
        if self.bus_bytes == 0 {
            return 1.0;
        }
        self.logical_bytes as f64 / self.bus_bytes as f64
    }

    pub fn merge(&mut self, other: &DramModel) {
        self.logical_bytes += other.logical_bytes;
        self.bus_bytes += other.bus_bytes;
        self.transfers += other.transfers;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_rounding_charges_full_bursts() {
        let cfg = AccelConfig::default();
        let mut d = DramModel::new();
        d.transfer(&cfg, 4); // one tiny index read
        assert_eq!(d.logical_bytes, 4);
        assert_eq!(d.bus_bytes, 64);
        assert!((d.efficiency() - 4.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn zero_transfer_is_free() {
        let cfg = AccelConfig::default();
        let mut d = DramModel::new();
        d.transfer(&cfg, 0);
        assert_eq!(d.transfers, 0);
        assert_eq!(d.cycles(&cfg), 0);
        assert_eq!(d.efficiency(), 1.0);
    }

    #[test]
    fn cycles_scale_with_bytes() {
        let cfg = AccelConfig::default();
        let mut d = DramModel::new();
        d.transfer(&cfg, 1024 * 1024);
        let one_mb = d.cycles(&cfg);
        d.transfer(&cfg, 1024 * 1024);
        assert!((d.cycles(&cfg) as f64 / one_mb as f64 - 2.0).abs() < 0.01);
    }

    #[test]
    fn sustained_fraction_derates_bandwidth() {
        let mut d = DramModel::new();
        let full = AccelConfig { sustained_frac: 1.0, ..Default::default() };
        d.transfer(&full, 1024 * 1024);
        let at_full = d.cycles(&full);
        let shared = AccelConfig { sustained_frac: 0.5, ..Default::default() };
        let at_half = d.cycles(&shared);
        assert!(
            (at_half as f64 / at_full as f64 - 2.0).abs() < 0.01,
            "halving the sustained fraction must double cycles: {at_full} \
             -> {at_half}"
        );
    }

    #[test]
    fn merge_accumulates() {
        let cfg = AccelConfig::default();
        let mut a = DramModel::new();
        let mut b = DramModel::new();
        a.transfer(&cfg, 100);
        b.transfer(&cfg, 200);
        a.merge(&b);
        assert_eq!(a.logical_bytes, 300);
        assert_eq!(a.transfers, 2);
    }
}
