//! PE-array compute model: weight-stationary MAC grid with a geometric
//! utilization estimate.
//!
//! Utilization follows the standard mapping argument: output channels
//! tile one PE dimension, input channels the other; ragged edges leave
//! PEs idle. This is deliberately simple — the paper's contribution is
//! on the *memory* side, and the simulator only needs compute cycles
//! good enough to decide whether a layer is compute- or memory-bound.

use super::AccelConfig;

/// Compute-side stats for one layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeArray {
    pub macs: u64,
    pub utilization: f64,
    pub cycles: u64,
}

impl PeArray {
    /// Model a conv layer: `cin x k x k` reduction per output element,
    /// `cout * h * w` outputs (already divided by stride via h/w).
    pub fn conv(
        cfg: &AccelConfig,
        cin: usize,
        cout: usize,
        k: usize,
        h: usize,
        w: usize,
    ) -> PeArray {
        let macs = (cin * k * k * cout * h * w) as u64;
        // Output channels map to rows, input channels to cols; the last
        // partial tile idles the remainder.
        let row_util = tile_util(cout, cfg.pe_rows);
        let col_util = tile_util(cin * k * k, cfg.pe_cols);
        let utilization = (row_util * col_util).max(1e-3);
        let peak = cfg.peak_macs() as f64;
        let cycles = (macs as f64 / (peak * utilization)).ceil() as u64;
        PeArray { macs, utilization, cycles }
    }

    pub fn energy_pj(&self, cfg: &AccelConfig) -> f64 {
        self.macs as f64 * cfg.pj_per_mac
    }
}

/// Average occupancy when `n` work items tile a dimension of size `d`.
fn tile_util(n: usize, d: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let tiles = n.div_ceil(d);
    n as f64 / (tiles * d) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfectly_tiled_layer_hits_full_utilization() {
        let cfg = AccelConfig::default(); // 16x16
        let pe = PeArray::conv(&cfg, 16, 16, 1, 8, 8);
        assert!((pe.utilization - 1.0).abs() < 1e-9);
        // 16*16*64 MACs at 256/cycle = 64 cycles.
        assert_eq!(pe.cycles, 64);
    }

    #[test]
    fn ragged_channels_lose_utilization() {
        let cfg = AccelConfig::default();
        let full = PeArray::conv(&cfg, 16, 16, 3, 8, 8);
        let ragged = PeArray::conv(&cfg, 16, 17, 3, 8, 8);
        assert!(ragged.utilization < full.utilization);
        assert!(ragged.cycles > full.cycles);
    }

    #[test]
    fn macs_match_eq4() {
        // Eq. 4: C*W*H*F*F*O / s — with h,w already post-stride.
        let cfg = AccelConfig::default();
        let pe = PeArray::conv(&cfg, 64, 128, 3, 16, 16);
        assert_eq!(pe.macs, 64 * 128 * 9 * 256);
    }

    #[test]
    fn tile_util_bounds() {
        assert_eq!(tile_util(0, 16), 0.0);
        assert_eq!(tile_util(16, 16), 1.0);
        assert!((tile_util(8, 16) - 0.5).abs() < 1e-12);
        assert!((tile_util(17, 16) - 17.0 / 32.0).abs() < 1e-12);
    }
}
