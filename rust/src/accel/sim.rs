//! The layer-by-layer simulation loop.
//!
//! For every layer: weights stream from DRAM (dense), the input
//! activation is *read back* in its encoded form, computed on the PE
//! array, and the output activation is encoded and *written* to DRAM
//! (the paper's layer-by-layer assumption — outputs never stay
//! resident). Compute and memory overlap (double buffering), so a
//! layer's latency is `max(compute, memory)` — which is precisely where
//! activation compression turns into end-to-end speedup for
//! memory-bound layers.

use anyhow::Result;

use super::{AccelConfig, DramModel, PeArray};
use crate::compress::{Codec, SpillBuf};
use crate::hal::TargetManifest;
use crate::telemetry::Telemetry;
use crate::tensor::Tensor;
use crate::zebra::bandwidth::SpillShape;

/// Static description of one simulated conv layer.
#[derive(Debug, Clone)]
pub struct LayerDesc {
    /// Output spill shape (C = cout).
    pub spill: SpillShape,
    /// Input channels and kernel geometry for weight/compute modeling.
    pub cin: usize,
    pub k: usize,
}

impl LayerDesc {
    /// Derive a plausible layer list from a spill plan: cin = previous
    /// layer's C (RGB for the stem), 3x3 kernels, stride folded into
    /// the spill shapes already.
    pub fn from_plan(spills: &[SpillShape]) -> Vec<LayerDesc> {
        let mut out = Vec::with_capacity(spills.len());
        let mut cin = 3;
        for s in spills {
            out.push(LayerDesc { spill: s.clone(), cin, k: 3 });
            cin = s.c;
        }
        out
    }

    pub fn weight_bytes(&self) -> usize {
        self.cin * self.spill.c * self.k * self.k * 4
    }
}

/// Per-layer simulation outcome.
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub name: String,
    pub compute_cycles: u64,
    pub mem_cycles: u64,
    pub cycles: u64,
    pub act_bytes_out: usize,
    pub act_bytes_in: usize,
    pub weight_bytes: usize,
    pub index_bytes: usize,
    pub utilization: f64,
    pub memory_bound: bool,
    pub energy_pj: f64,
}

/// Whole-network simulation outcome.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub codec: String,
    /// Name of the [`TargetManifest`] simulated against (empty for
    /// raw-`AccelConfig` runs).
    pub target: String,
    pub layers: Vec<LayerStats>,
    pub total_cycles: u64,
    pub dram: DramModel,
    pub total_energy_pj: f64,
}

impl SimReport {
    /// End-to-end latency in milliseconds.
    pub fn latency_ms(&self, cfg: &AccelConfig) -> f64 {
        self.total_cycles as f64 / (cfg.freq_ghz * 1e9) * 1e3
    }

    /// Activation bytes moved (in + out), excluding weights.
    pub fn activation_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| (l.act_bytes_in + l.act_bytes_out + l.index_bytes) as u64)
            .sum()
    }

    /// Activation-traffic reduction vs a dense report (percent).
    pub fn reduction_vs(&self, dense: &SimReport) -> f64 {
        let d = dense.activation_bytes() as f64;
        if d == 0.0 {
            return 0.0;
        }
        100.0 * (1.0 - self.activation_bytes() as f64 / d)
    }
}

/// Simulate with *real* activation tensors (trace replay): every spill
/// is encoded by `codec`, and its encoded size is what moves on the bus
/// (per image: tensors carry a batch; traffic is divided by N).
pub fn simulate_trace(
    cfg: &AccelConfig,
    layers: &[LayerDesc],
    tensors: &[Tensor],
    codec: &dyn Codec,
) -> Result<SimReport> {
    simulate_trace_with(cfg, layers, tensors, codec, &Telemetry::new())
}

/// [`simulate_trace`] with telemetry: per-layer encode wall time (and
/// the encoded bytes that hit the simulated bus) land in `sim.encode`,
/// the cycle model itself in `sim.model`.
pub fn simulate_trace_with(
    cfg: &AccelConfig,
    layers: &[LayerDesc],
    tensors: &[Tensor],
    codec: &dyn Codec,
    telemetry: &Telemetry,
) -> Result<SimReport> {
    anyhow::ensure!(
        layers.len() == tensors.len(),
        "layer/tensor count mismatch: {} vs {}",
        layers.len(),
        tensors.len()
    );
    let st_encode = telemetry.stage("sim.encode");
    let st_model = telemetry.stage("sim.model");
    // One reused SpillBuf across the whole layer loop: arena capacity
    // settles at the largest spill, so the per-layer encode is
    // allocation-free (the v2 streaming hot path).
    let mut buf = SpillBuf::new();
    let sizes: Vec<(usize, usize)> = tensors
        .iter()
        .map(|t| {
            let n = t.shape()[0].max(1);
            let _t = st_encode.time();
            codec.encode_into(t, &mut buf);
            let per = (buf.payload().len() / n, buf.index().len() / n);
            st_encode.add_bytes((per.0 + per.1) as u64);
            per
        })
        .collect();
    let _t = st_model.time();
    Ok(run(cfg, layers, &sizes, codec.name()))
}

/// Trace-replay simulation against a named [`TargetManifest`] — the
/// HAL entry point `zebra simulate --target` / `zebra targets` use.
pub fn simulate_trace_on(
    target: &TargetManifest,
    layers: &[LayerDesc],
    tensors: &[Tensor],
    codec: &dyn Codec,
    telemetry: &Telemetry,
) -> Result<SimReport> {
    let cfg = target.accel_config();
    let mut r = simulate_trace_with(&cfg, layers, tensors, codec, telemetry)?;
    r.target = target.name.clone();
    Ok(r)
}

/// Simulate from per-layer kept-block fractions (analytic mode — used
/// by benches that sweep sparsity without real tensors).
pub fn simulate_analytic(
    cfg: &AccelConfig,
    layers: &[LayerDesc],
    kept_frac: &[f64],
    codec_name: &str,
) -> SimReport {
    let sizes: Vec<(usize, usize)> = layers
        .iter()
        .zip(kept_frac)
        .map(|(l, &kf)| {
            let payload = (l.spill.dense_bytes() as f64 * kf).round() as usize;
            (payload, l.spill.index_bytes().ceil() as usize)
        })
        .collect();
    run(cfg, layers, &sizes, codec_name)
}

/// Analytic simulation against a named [`TargetManifest`].
pub fn simulate_analytic_on(
    target: &TargetManifest,
    layers: &[LayerDesc],
    kept_frac: &[f64],
    codec_name: &str,
) -> SimReport {
    let mut r =
        simulate_analytic(&target.accel_config(), layers, kept_frac, codec_name);
    r.target = target.name.clone();
    r
}

fn run(
    cfg: &AccelConfig,
    layers: &[LayerDesc],
    act_sizes: &[(usize, usize)],
    codec: &str,
) -> SimReport {
    let mut report = SimReport { codec: codec.to_string(), ..Default::default() };
    // The network input (image) is read dense; negligible, skipped.
    let mut prev_encoded: usize = 0;
    let mut prev_index: usize = 0;
    for (l, &(payload, index)) in layers.iter().zip(act_sizes) {
        let pe = PeArray::conv(
            cfg,
            l.cin,
            l.spill.c,
            l.k,
            l.spill.h,
            l.spill.w,
        );
        let mut dram = DramModel::new();
        dram.transfer(cfg, l.weight_bytes()); // weights in (dense)
        dram.transfer(cfg, prev_encoded); // input activations in
        dram.transfer(cfg, prev_index); // input block index in
        dram.transfer(cfg, payload); // output activations out
        dram.transfer(cfg, index); // output block index out
        let mem_cycles = dram.cycles(cfg);
        let cycles = pe.cycles.max(mem_cycles);
        let energy = pe.energy_pj(cfg) + dram.energy_pj(cfg);
        report.layers.push(LayerStats {
            name: l.spill.name.clone(),
            compute_cycles: pe.cycles,
            mem_cycles,
            cycles,
            act_bytes_out: payload,
            act_bytes_in: prev_encoded,
            weight_bytes: l.weight_bytes(),
            index_bytes: index + prev_index,
            utilization: pe.utilization,
            memory_bound: mem_cycles > pe.cycles,
            energy_pj: energy,
        });
        report.total_cycles += cycles;
        report.total_energy_pj += energy;
        report.dram.merge(&dram);
        prev_encoded = payload;
        prev_index = index;
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{DenseCodec, ZeroBlockCodec};
    use crate::util::prng::Rng;
    use crate::zebra::prune::{relu_prune, Thresholds};

    fn toy_layers() -> Vec<LayerDesc> {
        let spills = vec![
            SpillShape { name: "a".into(), c: 16, h: 16, w: 16, block: 4 },
            SpillShape { name: "b".into(), c: 32, h: 8, w: 8, block: 4 },
        ];
        LayerDesc::from_plan(&spills)
    }

    fn toy_tensors(sparse: bool) -> Vec<Tensor> {
        let mut rng = Rng::new(11);
        toy_layers()
            .iter()
            .map(|l| {
                let s = &l.spill;
                let data = (0..s.elems()).map(|_| rng.normal()).collect();
                let x = Tensor::from_vec(&[1, s.c, s.h, s.w], data);
                // max over a 4x4 block of N(0,1) concentrates ~2+, so a
                // "sparse" trace needs a threshold well above that.
                let t = if sparse { 2.5 } else { 0.0 };
                relu_prune(&x, &Thresholds::Scalar(t), s.block).0
            })
            .collect()
    }

    #[test]
    fn from_plan_chains_channels() {
        let ls = toy_layers();
        assert_eq!(ls[0].cin, 3);
        assert_eq!(ls[1].cin, 16);
        assert_eq!(ls[1].weight_bytes(), 16 * 32 * 9 * 4);
    }

    #[test]
    fn zebra_codec_reduces_cycles_on_sparse_traces() {
        let cfg = AccelConfig::default();
        let layers = toy_layers();
        let tensors = toy_tensors(true);
        let dense =
            simulate_trace(&cfg, &layers, &tensors, &DenseCodec).unwrap();
        let zb = simulate_trace(&cfg, &layers, &tensors, &ZeroBlockCodec::new(4))
            .unwrap();
        assert!(zb.activation_bytes() < dense.activation_bytes());
        assert!(zb.total_cycles <= dense.total_cycles);
        assert!(zb.reduction_vs(&dense) > 30.0);
    }

    #[test]
    fn analytic_matches_trace_at_full_density() {
        let cfg = AccelConfig::default();
        let layers = toy_layers();
        let kept = vec![1.0; layers.len()];
        let analytic = simulate_analytic(&cfg, &layers, &kept, "zero-block");
        let trace = simulate_trace(
            &cfg,
            &layers,
            &toy_tensors(false),
            &ZeroBlockCodec::new(4),
        )
        .unwrap();
        // Not exact (trace has some natural zeros) but same ballpark.
        let a = analytic.activation_bytes() as f64;
        let t = trace.activation_bytes() as f64;
        assert!((a - t).abs() / a < 0.25, "analytic {a} vs trace {t}");
    }

    #[test]
    fn latency_and_energy_are_positive_and_consistent() {
        let cfg = AccelConfig::default();
        let layers = toy_layers();
        let r = simulate_analytic(&cfg, &layers, &[0.5, 0.5], "x");
        assert!(r.latency_ms(&cfg) > 0.0);
        assert!(r.total_energy_pj > 0.0);
        assert_eq!(
            r.total_cycles,
            r.layers.iter().map(|l| l.cycles).sum::<u64>()
        );
    }

    #[test]
    fn mismatched_lengths_error() {
        let cfg = AccelConfig::default();
        let layers = toy_layers();
        let r = simulate_trace(&cfg, &layers, &[], &DenseCodec);
        assert!(r.is_err());
    }

    #[test]
    fn default_manifest_parity_with_raw_config() {
        // The acceptance contract for the HAL refactor: simulating on
        // the `default` manifest produces byte-for-byte the numbers the
        // pre-refactor hard-coded AccelConfig produced.
        let m = TargetManifest::default();
        let layers = toy_layers();
        let kept = [0.6, 0.4];
        let via_manifest = simulate_analytic_on(&m, &layers, &kept, "zb");
        let direct =
            simulate_analytic(&AccelConfig::default(), &layers, &kept, "zb");
        assert_eq!(via_manifest.total_cycles, direct.total_cycles);
        assert_eq!(
            via_manifest.activation_bytes(),
            direct.activation_bytes()
        );
        assert_eq!(via_manifest.total_energy_pj, direct.total_energy_pj);
        assert_eq!(via_manifest.target, "default");
        assert_eq!(direct.target, "");
        // And the trace path agrees with itself across the two entry
        // points.
        let tensors = toy_tensors(false);
        let t1 = simulate_trace_on(
            &m,
            &layers,
            &tensors,
            &DenseCodec,
            &Telemetry::new(),
        )
        .unwrap();
        let t2 = simulate_trace(
            &AccelConfig::default(),
            &layers,
            &tensors,
            &DenseCodec,
        )
        .unwrap();
        assert_eq!(t1.total_cycles, t2.total_cycles);
        assert_eq!(t1.activation_bytes(), t2.activation_bytes());
    }

    #[test]
    fn starved_targets_run_slower_than_hbm() {
        // Same trace, two envelopes: the bandwidth-starved profile
        // must take more cycles AND more wall time than an HBM part.
        let layers = toy_layers();
        let kept = [1.0, 1.0];
        let slow = TargetManifest {
            name: "slow".into(),
            dram_gbps: 1.0,
            ..TargetManifest::default()
        };
        let fast = TargetManifest {
            name: "fast".into(),
            dram_gbps: 900.0,
            pe_rows: 128,
            pe_cols: 128,
            ..TargetManifest::default()
        };
        let rs = simulate_analytic_on(&slow, &layers, &kept, "d");
        let rf = simulate_analytic_on(&fast, &layers, &kept, "d");
        assert!(rs.total_cycles > rf.total_cycles);
        assert!(
            rs.latency_ms(&slow.accel_config())
                > rf.latency_ms(&fast.accel_config())
        );
    }

    #[test]
    fn trace_simulation_records_telemetry() {
        let tel = Telemetry::new();
        let layers = toy_layers();
        let r = simulate_trace_with(
            &AccelConfig::default(),
            &layers,
            &toy_tensors(false),
            &DenseCodec,
            &tel,
        )
        .unwrap();
        let snap = tel.snapshot();
        let enc = snap.get("sim.encode");
        assert_eq!(enc.calls as usize, layers.len());
        // Encoded bytes (each spill once) are bounded by the bus
        // traffic (most spills cross twice: write, then read back).
        assert!(enc.bytes > 0);
        assert!(enc.bytes <= r.activation_bytes());
        assert_eq!(snap.get("sim.model").calls, 1);
    }
}
