//! `zebra obs` — the unified observability surface:
//!
//! ```text
//! zebra obs --addr HOST:PORT           # Prometheus text exposition
//! zebra obs --addr HOST:PORT --json    # same registry as JSON
//! zebra obs replay FILE.jsonl          # render a flight dump
//! ```
//!
//! The live forms scrape one [`ObsReport`] (cluster counters, latency
//! percentiles, Eq. 2-3 bandwidth accounting, and the merged telemetry
//! stages) from a router or worker over the `MetricsReq` wire. The
//! replay form parses a flight-recorder dump (JSON-lines written on
//! shed / deadline-miss / conn-error / worker-death, or at node exit)
//! and renders every sampled request as a waterfall plus the terminal
//! events in ring order. Formats are documented in
//! `rust/docs/observability.md`.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::Args;
use crate::cluster::ClusterClient;
use crate::obs::flight::parse_jsonl;
use crate::obs::{render_waterfall, FlightEntry};
use crate::util::json;

/// Entry point. Takes raw argv (not parsed [`Args`]) because `replay`
/// is the CLI's one positional form — everything else goes through the
/// standard `--flag` parser.
pub fn run(argv: &[String]) -> Result<()> {
    if argv.get(1).map(String::as_str) == Some("replay") {
        anyhow::ensure!(
            argv.len() == 3,
            "usage: zebra obs replay FILE.jsonl"
        );
        return replay(Path::new(&argv[2]));
    }
    let args = Args::parse(argv)?;
    let addr = args.get("addr").context(
        "zebra obs needs --addr HOST:PORT (or: zebra obs replay FILE)",
    )?;
    let client = ClusterClient::connect(addr)?;
    let report = client.obs_report()?;
    client.shutdown();
    if args.get("json").is_some() {
        println!("{}", json::to_string(&report.to_json()));
    } else {
        print!("{}", report.prometheus());
    }
    Ok(())
}

/// Render a flight dump: one waterfall per sampled trace, one line per
/// terminal event, in the order the ring recorded them.
///
/// The error contract is part of the CLI surface: a malformed dump
/// returns `Err` (so the binary exits 1, never 0) and the message
/// names the file and the offending line (`FILE: flight line N: ...`)
/// — scripts can grep it, and a truncated dump from a crashed node is
/// diagnosed instead of half-rendered.
fn replay(path: &Path) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("zebra obs replay {path:?}"))?;
    let entries = parse_jsonl(&text)
        .map_err(|e| anyhow!("{}: {e}", path.display()))?;
    let (mut traces, mut events) = (0usize, 0usize);
    for entry in &entries {
        match entry {
            FlightEntry::Trace(rec) => {
                traces += 1;
                print!("{}", render_waterfall(rec));
            }
            FlightEntry::Event { trace_id, kind, detail, .. } => {
                events += 1;
                println!(
                    "event {:<13} trace {:#018x}  {}",
                    kind.name(),
                    trace_id,
                    detail
                );
            }
        }
    }
    println!(
        "{}: {traces} traces, {events} terminal events",
        path.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The replay error contract: malformed dumps return `Err` (exit
    /// code 1 via main) and name the file + line, never a partial
    /// render with exit 0.
    #[test]
    fn replay_names_the_file_and_line_on_malformed_input() {
        let dir = std::env::temp_dir()
            .join(format!("zebra-obs-replay-err-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.jsonl");
        std::fs::write(
            &bad,
            "{\"type\":\"event\",\"at_ns\":\"1\",\
             \"trace_id\":\"0x0000000000000001\",\
             \"kind\":\"shed_low\",\"detail\":\"x\"}\n\
             not json at all\n",
        )
        .unwrap();
        let e = replay(&bad).unwrap_err().to_string();
        assert!(e.contains("bad.jsonl"), "{e}");
        assert!(e.contains("flight line 2"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
