//! `zebra analyze` — sparsity + bandwidth analysis of a trace, and
//! `zebra table5` — the paper's static overhead arithmetic.

use anyhow::Result;

use super::Args;
use crate::bench::Table;
use crate::compress::{registry, Codec as _, SpillBuf};
use crate::models;
use crate::zebra::bandwidth::{self, fmt_bytes};
use crate::zebra::prune::{block_mask, natural_zero_fraction, Thresholds};

pub fn run(args: &Args) -> Result<()> {
    let dir = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("analyze needs --trace DIR"))?;
    let tr = crate::trace::load(dir)?;
    println!(
        "trace {} ({} images, dataset {}, zebra={}, T_obj={})",
        tr.model,
        tr.batch(),
        tr.dataset,
        tr.zebra,
        tr.t_obj
    );

    let mut table = Table::new(&[
        "layer", "shape", "block", "zero-elem %", "zero-blk %", "dense",
        "stored", "index",
    ]);
    let mut report = bandwidth::BandwidthReport::default();
    for sp in &tr.spills {
        let t = &sp.tensor;
        let mask = block_mask(t, &Thresholds::Scalar(0.0), sp.shape.block);
        let kept = 1.0 - mask.zero_fraction();
        let dense = sp.shape.dense_bytes() as f64;
        let stored = sp.shape.stored_bytes(kept);
        let index = sp.shape.index_bytes();
        report.required_bytes += dense;
        report.stored_bytes += stored;
        report.overhead_bytes += index;
        table.row(&[
            sp.shape.name.clone(),
            format!("{}x{}x{}", sp.shape.c, sp.shape.h, sp.shape.w),
            sp.shape.block.to_string(),
            format!("{:.1}", 100.0 * t.zero_fraction()),
            format!("{:.1}", 100.0 * mask.zero_fraction()),
            fmt_bytes(dense),
            fmt_bytes(stored),
            fmt_bytes(index),
        ]);
    }
    table.print(&format!("Per-layer activation analysis — {}", tr.model));
    println!(
        "TOTAL per image: required {}  stored {}  index {}  -> reduced {:.1}%",
        fmt_bytes(report.required_bytes / tr.batch() as f64),
        fmt_bytes(report.stored_bytes / tr.batch() as f64),
        fmt_bytes(report.overhead_bytes / tr.batch() as f64),
        report.reduced_pct()
    );

    // Measured encoded size per codec, from the registry, through the
    // v2 streaming path (one reused SpillBuf for the whole sweep).
    let mut rows: Vec<(&str, f64)> = Vec::new();
    let mut buf = SpillBuf::new();
    for spec in registry() {
        let mut total = 0.0f64;
        for sp in &tr.spills {
            let codec = spec.build(sp.shape.block.max(1));
            codec.encode_into(&sp.tensor, &mut buf);
            total += buf.total_bytes() as f64;
        }
        rows.push((spec.name, total / tr.batch().max(1) as f64));
    }
    let dense = rows
        .iter()
        .find(|r| r.0 == "dense")
        .map(|r| r.1)
        .unwrap_or(0.0);
    let mut tc = Table::new(&["codec", "encoded/img", "reduction %"]);
    for (name, bytes) in rows {
        let red = if dense > 0.0 {
            100.0 * (1.0 - bytes / dense)
        } else {
            0.0
        };
        tc.row(&[
            name.to_string(),
            fmt_bytes(bytes),
            format!("{red:.1}"),
        ]);
    }
    tc.print("Encoded spill bytes by codec (payload + index)");

    // Table-I style block-size sweep on this trace.
    let mut t1 = Table::new(&["block size", "zero blocks %"]);
    for label in ["2", "4", "8", "whole"] {
        let mut num = 0.0;
        let mut den = 0.0;
        for sp in &tr.spills {
            let s = &sp.shape;
            let b = match label {
                "whole" => s.h.min(s.w),
                l => l.parse::<usize>().unwrap(),
            };
            if s.h % b != 0 || s.w % b != 0 {
                continue;
            }
            let frac = natural_zero_fraction(&sp.tensor, b);
            let blocks = (sp.tensor.len() / (b * b)) as f64;
            num += frac * blocks;
            den += blocks;
        }
        if den > 0.0 {
            t1.row(&[label.to_string(), format!("{:.1}", 100.0 * num / den)]);
        }
    }
    t1.print("Zero-block fraction vs block size (cf. paper Table I)");
    Ok(())
}

/// `zebra table5`: Eq. 2–3 overhead arithmetic on the paper's
/// full-width architectures — reproduces Table V exactly (it is pure
/// arithmetic, no training involved).
pub fn table5(args: &Args) -> Result<()> {
    let ds = args.get_or("dataset", "both");
    let mut table = Table::new(&[
        "model", "dataset", "required bw", "bw overhead", "overhead %",
        "paper",
    ]);
    let rows: Vec<(&str, usize, usize, &str)> = match ds.as_str() {
        "cifar10" => vec![("resnet18", 32, 4, "2.06 MB / 4.13 KB (0.2%)")],
        "tiny" => vec![("resnet18", 64, 8, "7.86 MB / 3.15 KB (0.04%)")],
        _ => vec![
            ("resnet18", 32, 4, "2.06 MB / 4.13 KB (0.2%)"),
            ("resnet18", 64, 8, "7.86 MB / 3.15 KB (0.04%)"),
        ],
    };
    for (arch, hw, block, paper) in rows {
        let plan = models::paper_plan(arch, hw, block)?;
        let req = plan.required_bytes();
        let idx = plan.index_bytes();
        table.row(&[
            arch.to_string(),
            if hw == 32 { "CIFAR-10" } else { "Tiny-ImageNet" }.to_string(),
            fmt_bytes(req),
            fmt_bytes(idx),
            format!("{:.2}%", 100.0 * idx / req),
            paper.to_string(),
        ]);
    }
    table.print("Table V — memory bandwidth overhead (Eq. 2-3)");
    Ok(())
}
