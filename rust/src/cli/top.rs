//! `zebra top` — refresh-in-place live cluster dashboard:
//!
//! ```text
//! zebra top --addr ROUTER_ADDR [--interval-ms 500]
//! zebra top --addr ROUTER_ADDR --json      # one scrape, JSON, exit
//! ```
//!
//! Each tick scrapes one [`ObsReport`] over the same `MetricsReq` wire
//! `zebra obs` uses, then redraws in place (ANSI clear + home):
//! cluster summary, active SLO breach banners, the per-worker table
//! reassembled from the router's `cluster.w<idx>.*` stages, and the
//! bandwidth ledger with a sparkline of each layer's recent zero-block
//! permille. `--frames N` exits after N redraws (smoke tests);
//! `--json` is a single-scrape once-mode for scripts.
//!
//! Rendering is a pure function of the report plus the kept history —
//! the unit tests drive it with synthetic reports, no sockets.

use std::collections::{BTreeMap, VecDeque};
use std::fmt::Write as _;
use std::time::Duration;

use anyhow::{Context, Result};

use super::Args;
use crate::cluster::ClusterClient;
use crate::obs::{parse_slo, parse_workers, LedgerSnapshot, ObsReport};
use crate::util::json;

/// Sparkline alphabet, lowest to highest.
const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Ticks of per-cell history kept for the sparkline column.
const HISTORY: usize = 24;

pub fn run(args: &Args) -> Result<()> {
    let addr = args
        .get("addr")
        .context("zebra top needs --addr HOST:PORT")?;
    let interval = args.get_usize("interval-ms", 500)? as u64;
    anyhow::ensure!(interval > 0, "--interval-ms must be > 0");
    let frames = args.get_usize("frames", 0)?;
    if args.get("json").is_some() {
        // Once-mode: one scrape, machine-readable, no redraw loop.
        let report = scrape(addr)?;
        println!("{}", json::to_string(&report.to_json()));
        return Ok(());
    }
    let mut dash = Dashboard::default();
    let mut tick = 0usize;
    loop {
        tick += 1;
        let body = match scrape(addr) {
            Ok(report) => dash.frame(addr, tick, interval, &report),
            // A refused/dropped scrape is a frame, not an exit: nodes
            // restart, and top should ride it out.
            Err(e) => {
                format!("zebra top — {addr} — tick {tick}\n\n  scrape failed: {e:#}\n")
            }
        };
        // Clear + home, then the whole frame in one write.
        print!("\x1b[2J\x1b[H{body}");
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        if frames > 0 && tick >= frames {
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(interval));
    }
}

/// One scrape over a fresh connection (reconnect-per-tick keeps top
/// resilient to node restarts at these refresh rates).
fn scrape(addr: &str) -> Result<ObsReport> {
    let client = ClusterClient::connect(addr)?;
    let report = client.obs_report();
    client.shutdown();
    report
}

/// The dashboard's only state: per-ledger-cell zero-permille history
/// for the sparkline column.
#[derive(Default)]
struct Dashboard {
    history: BTreeMap<(String, String), VecDeque<u64>>,
}

impl Dashboard {
    /// Fold one report into the history and render the full frame.
    fn frame(
        &mut self,
        addr: &str,
        tick: usize,
        interval: u64,
        report: &ObsReport,
    ) -> String {
        let ledger = LedgerSnapshot::from_telemetry(&report.telemetry);
        for (key, cell) in &ledger.cells {
            let h = self.history.entry(key.clone()).or_default();
            if h.len() == HISTORY {
                h.pop_front();
            }
            h.push_back(cell.zero_permille());
        }
        render(addr, tick, interval, report, &ledger, &self.history)
    }
}

/// Pure frame renderer (unit-testable without sockets or ANSI).
fn render(
    addr: &str,
    tick: usize,
    interval: u64,
    report: &ObsReport,
    ledger: &LedgerSnapshot,
    history: &BTreeMap<(String, String), VecDeque<u64>>,
) -> String {
    let s = &report.stats;
    let a = &s.aggregate;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "zebra top — {addr} — tick {tick} (every {interval} ms)"
    );
    out.push('\n');
    if s.workers_total > 0 {
        let _ = writeln!(
            out,
            "cluster: {}/{} workers alive | routed {} | retries {} | \
             rejected {} | spill in {} over {} frames",
            s.workers_alive,
            s.workers_total,
            s.routed,
            s.retries,
            s.rejected,
            fmt_bytes(s.spill_bytes_in),
            s.spill_frames_in,
        );
    } else {
        let _ = writeln!(out, "single node (no router counters)");
    }
    let _ = writeln!(
        out,
        "serving: requests {} | responses {} | shed {}/{}/{} | \
         misses {} | failed {} | queue {}",
        a.requests,
        a.responses,
        a.shed_low,
        a.shed_normal,
        a.shed_high,
        a.deadline_miss,
        a.failed,
        a.queue_depth,
    );
    let _ = writeln!(
        out,
        "latency: p50 {} | p95 {} | p99 {}",
        fmt_us(a.latency_percentile_us(0.5)),
        fmt_us(a.latency_percentile_us(0.95)),
        fmt_us(a.latency_percentile_us(0.99)),
    );

    // SLO banners: active breaches shout, quiet objectives get one
    // summary line so the panel proves the engine is wired in.
    let slo = parse_slo(&report.telemetry);
    if !slo.is_empty() {
        out.push('\n');
        let mut quiet = 0usize;
        for (name, view) in &slo {
            if view.active {
                let _ = writeln!(
                    out,
                    "!! SLO BREACH {name} (threshold {:.3}, {} \
                     breach{} so far)",
                    view.threshold_milli as f64 / 1000.0,
                    view.breaches,
                    if view.breaches == 1 { "" } else { "es" },
                );
            } else {
                quiet += 1;
            }
        }
        let _ = writeln!(
            out,
            "slo: {quiet}/{} objectives healthy",
            slo.len()
        );
    }

    let workers = parse_workers(&report.telemetry);
    if !workers.is_empty() {
        out.push('\n');
        let _ = writeln!(
            out,
            "{:>3}  {:>5}  {:>9}  {:>5}  {:>10}  {:>8}",
            "wkr", "alive", "in-flight", "queue", "responses", "shed"
        );
        for (idx, w) in &workers {
            let _ = writeln!(
                out,
                "{idx:>3}  {:>5}  {:>9}  {:>5}  {:>10}  {:>8}",
                if w.alive { "yes" } else { "NO" },
                w.in_flight,
                w.queue_depth,
                w.responses,
                w.shed,
            );
        }
    }

    if !ledger.cells.is_empty() {
        out.push('\n');
        let _ = writeln!(
            out,
            "{:<24} {:>9} {:>9} {:>6} {:>7} {:>8}  trend",
            "ledger cell", "dense", "encoded", "zero‰", "saved", "analytic"
        );
        for ((layer, codec), c) in &ledger.cells {
            let trend = history
                .get(&(layer.clone(), codec.clone()))
                .map(|h| sparkline(h))
                .unwrap_or_default();
            let _ = writeln!(
                out,
                "{:<24} {:>9} {:>9} {:>6} {:>6.1}% {:>7.1}%  {trend}",
                format!("{layer}/{codec}"),
                fmt_bytes(c.dense_bytes),
                fmt_bytes(c.encoded_bytes),
                c.zero_permille(),
                c.achieved_savings_pct(),
                c.analytic_savings_pct(),
            );
        }
        let total = ledger.total();
        let _ = writeln!(
            out,
            "ledger total: {} -> {} ({:.1}% of dense traffic never \
             hit the channel)",
            fmt_bytes(total.dense_bytes),
            fmt_bytes(total.encoded_bytes),
            total.achieved_savings_pct(),
        );
    }
    out
}

/// Render a permille series (0..=1000) on the fixed 0..=1000 scale so
/// two frames of the same value always draw the same bar.
fn sparkline(h: &VecDeque<u64>) -> String {
    h.iter()
        .map(|&v| SPARK[(v.min(1000) as usize * (SPARK.len() - 1)) / 1000])
        .collect()
}

/// `1234` -> `1.2KB`-style humanized byte counts (fixed-point, no
/// locale, stable under test).
fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 4] = ["B", "KB", "MB", "GB"];
    let mut v = b as f64;
    let mut u = 0usize;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.1}{}", UNITS[u])
    }
}

/// Microseconds humanized to us/ms/s.
fn fmt_us(us: u64) -> String {
    if us < 1_000 {
        format!("{us}us")
    } else if us < 1_000_000 {
        format!("{:.1}ms", us as f64 / 1e3)
    } else {
        format!("{:.2}s", us as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterStats, MetricsSnapshot};
    use crate::obs::Ledger;
    use crate::telemetry::{StageStats, TelemetrySnapshot};

    fn report() -> ObsReport {
        let mut telemetry = TelemetrySnapshot::default();
        let ledger = Ledger::new();
        ledger.cell("l0", "zero-block").record(1000, 400, 64, 32);
        ledger.snapshot().to_stages(&mut telemetry);
        telemetry.stages.insert(
            "slo.shed-rate.breach".into(),
            StageStats { nanos: 50, calls: 2, bytes: 0 },
        );
        telemetry.stages.insert(
            "slo.shed-rate.active".into(),
            StageStats { nanos: 0, calls: 1, bytes: 0 },
        );
        telemetry.stages.insert(
            "cluster.w0.link".into(),
            StageStats { nanos: 3, calls: 1, bytes: 0 },
        );
        telemetry.stages.insert(
            "cluster.w0.node".into(),
            StageStats { nanos: 2, calls: 97, bytes: 4 },
        );
        ObsReport {
            stats: ClusterStats {
                aggregate: MetricsSnapshot {
                    requests: 100,
                    responses: 97,
                    ..Default::default()
                },
                workers_total: 1,
                workers_alive: 1,
                routed: 100,
                ..Default::default()
            },
            telemetry,
        }
    }

    #[test]
    fn frame_renders_every_panel() {
        let mut dash = Dashboard::default();
        let frame = dash.frame("127.0.0.1:9", 1, 500, &report());
        assert!(frame.contains("1/1 workers alive"), "{frame}");
        assert!(frame.contains("SLO BREACH shed-rate"), "{frame}");
        assert!(frame.contains("l0/zero-block"), "{frame}");
        // 32 of 64 blocks zero -> permille 500 -> mid sparkline.
        assert!(frame.contains("500"), "{frame}");
        assert!(frame.contains('▄'), "{frame}");
        // The per-worker table reassembles from cluster.w0.* stages.
        assert!(frame.contains("yes"), "{frame}");
        assert!(frame.contains("97"), "{frame}");
        // No panel leaks raw stage labels.
        assert!(!frame.contains("cluster.w0"), "{frame}");
        assert!(!frame.contains("slo.shed-rate"), "{frame}");
    }

    #[test]
    fn sparkline_history_is_bounded_and_scaled() {
        let mut dash = Dashboard::default();
        for i in 0..(HISTORY + 10) {
            let mut t = TelemetrySnapshot::default();
            let ledger = Ledger::new();
            // Zero fraction ramps 0 -> 1000 permille over the run.
            let zeros = (i as u64).min(64);
            ledger.cell("l0", "zero-block").record(1000, 400, 64, zeros);
            ledger.snapshot().to_stages(&mut t);
            let r = ObsReport {
                stats: ClusterStats::default(),
                telemetry: t,
            };
            dash.frame("x", i + 1, 500, &r);
        }
        let h = dash
            .history
            .get(&("l0".to_string(), "zero-block".to_string()))
            .unwrap();
        assert_eq!(h.len(), HISTORY);
        let line = sparkline(h);
        assert_eq!(line.chars().count(), HISTORY);
        // Monotone ramp: first char is lower than the last.
        let first = line.chars().next().unwrap();
        let last = line.chars().last().unwrap();
        assert!(
            SPARK.iter().position(|&c| c == first)
                < SPARK.iter().position(|&c| c == last),
            "{line}"
        );
    }

    #[test]
    fn formatting_helpers_are_stable() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.0MB");
        assert_eq!(fmt_us(900), "900us");
        assert_eq!(fmt_us(1500), "1.5ms");
        assert_eq!(fmt_us(2_000_000), "2.00s");
        assert_eq!(sparkline(&VecDeque::from([0, 1000])), "▁█");
    }

    #[test]
    fn empty_report_renders_the_single_node_banner() {
        let frame = render(
            "a:1",
            1,
            500,
            &ObsReport::default(),
            &LedgerSnapshot::default(),
            &BTreeMap::new(),
        );
        assert!(frame.contains("single node"), "{frame}");
        assert!(!frame.contains("ledger cell"), "{frame}");
    }
}
