//! `zebra train` — native Zebra training on the reference-backend
//! model family: learn block-prunable activations with the
//! `CE + lambda * sum ||block||` objective and checkpoint `w%05d.zten`
//! leaves that `zebra serve --backend reference --weights DIR` loads
//! unchanged. No Python, no artifacts, no native deps anywhere in the
//! path.
//!
//! ```text
//! zebra train --model ref-tiny --lambda 1e-4 --steps 200 --out /tmp/zt
//! zebra train --model rn18-c10-t0.1 --block 4 --steps 400 \
//!             --images imgs.zten --labels lbls.zten --out weights/
//! ```

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::Args;
use crate::backend::reference::RefSpec;
use crate::train::{train_on, Dataset, TrainConfig};

pub fn run(args: &Args) -> Result<()> {
    let mut cfg = TrainConfig {
        model: args.get_or("model", "ref-tiny"),
        lambda: args.get_f32("lambda", 1e-4)?,
        block: args.get("block").map(|_| args.get_usize("block", 0)).transpose()?,
        t_obj: match args.get("t-obj") {
            Some(_) => Some(args.get_f32("t-obj", 0.0)?),
            None => None,
        },
        steps: args.get_usize("steps", 200)?,
        batch: args.get_usize("batch", 16)?,
        lr: args.get_f32("lr", 0.05)?,
        momentum: args.get_f32("momentum", 0.9)?,
        weight_decay: args.get_f32("weight-decay", 1e-4)?,
        seed: args.get_usize("seed", 42)? as u64,
        n_train: args.get_usize("train-n", 256)?,
        n_holdout: args.get_usize("holdout", 64)?,
        eval_every: args.get_usize("eval-every", 0)?,
        threads: args.get_usize("threads", 0)?,
        quiet: false,
    };
    if crate::bench::smoke() {
        // ZEBRA_BENCH_SMOKE: the CI fast path every bench honors —
        // cap the budget so the smoke job finishes in seconds.
        cfg.steps = cfg.steps.min(25);
        cfg.n_train = cfg.n_train.min(64);
        cfg.n_holdout = cfg.n_holdout.min(32);
        println!(
            "(ZEBRA_BENCH_SMOKE: capped at {} steps / {} train images)",
            cfg.steps, cfg.n_train
        );
    }

    let spec = RefSpec::from_key(&cfg.model)?;
    let (data, holdout) = match (args.get("images"), args.get("labels")) {
        (Some(im), Some(lb)) => {
            let ds = Dataset::from_zten(
                std::path::Path::new(im),
                std::path::Path::new(lb),
                spec.in_hw,
            )?;
            anyhow::ensure!(
                ds.len() > cfg.n_holdout,
                "--holdout {} leaves no training images of the {} loaded",
                cfg.n_holdout,
                ds.len()
            );
            ds.split(cfg.n_holdout)
        }
        (None, None) => {
            let ds = Dataset::synthetic(
                spec.in_hw,
                spec.classes,
                cfg.n_train + cfg.n_holdout,
                cfg.seed,
            );
            ds.split(cfg.n_holdout)
        }
        _ => bail!("--images and --labels must be given together"),
    };

    // Validate --out before burning the training budget: a typo'd or
    // unwritable path must fail in milliseconds, not after the run.
    let out_dir = match args.get("out") {
        Some(dir) => {
            let dir = PathBuf::from(dir);
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("--out {dir:?} is not writable"))?;
            Some(dir)
        }
        None => None,
    };

    println!(
        "training {} | {} conv layers | lambda {} | {} steps x batch {} | \
         {} train / {} held-out images",
        cfg.model,
        spec.spills.len(),
        cfg.lambda,
        cfg.steps,
        cfg.batch,
        data.len(),
        holdout.len()
    );
    let t0 = Instant::now();
    let outcome = train_on(&cfg, &data, &holdout)?;
    let fin = outcome.final_stat();
    println!(
        "\ntrained in {:.1}s | final loss {:.4} | holdout top-1 {:.1}% | \
         zero blocks {:.1}% | Eq.2-3 bandwidth reduction {:.1}%",
        t0.elapsed().as_secs_f64(),
        fin.loss,
        100.0 * fin.holdout_acc,
        fin.zero_block_pct,
        fin.reduced_pct
    );

    if let Some(dir) = out_dir {
        outcome
            .write_leaves(&dir)
            .with_context(|| format!("checkpointing to {dir:?}"))?;
        println!(
            "wrote {} weight leaves to {}",
            outcome.params.conv_w.len() + 1,
            dir.display()
        );
        println!(
            "  serve:    zebra serve --backend reference --model {} --weights {}",
            cfg.model,
            dir.display()
        );
        println!(
            "  simulate: zebra simulate --backend reference --model {} --weights {}",
            cfg.model,
            dir.display()
        );
    } else {
        println!("(no --out DIR given; weights were not checkpointed)");
    }
    Ok(())
}
