//! `zebra cluster-worker` / `zebra cluster-router` — the multi-node
//! serving topology (see `rust/docs/cluster.md`):
//!
//! ```text
//! zebra cluster-worker --model ref-tiny --port 0          # x N
//! zebra cluster-router --workers HOST:P1,HOST:P2 --port 0
//! zebra loadgen --addr ROUTER_ADDR --requests 256
//! ```
//!
//! Both node commands accept `--port 0` for an ephemeral port and
//! print one `... listening on HOST:PORT` line so scripts harvest the
//! bound address instead of racing on fixed ports. `--run-s N` exits
//! after N seconds (0 = run until killed), which keeps smoke tests
//! self-terminating.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::opts::ServeOpts;
use super::Args;
use crate::cluster::{Router, RouterConfig, ShardMode, WorkerNode};
use crate::coordinator::server::BatchExecutor;
use crate::obs::{Ledger, SloEngine};

/// `zebra cluster-worker`: build the serving executor exactly like
/// `zebra serve` and expose it as a cluster worker node.
pub fn run_worker(args: &Args) -> Result<()> {
    let opts = ServeOpts::from_args(args)?;
    let (exec, _classes, backend, ledger) =
        super::serve::build_executor(args, &crate::artifacts_dir())?;
    println!(
        "cluster-worker backend {} | batches {:?} | threads {}",
        backend.name(),
        exec.batch_sizes(),
        exec.exec_threads()
    );
    expose_worker(&opts, args, exec, ledger)
}

/// Shared TCP front for `cluster-worker` and `serve --port`: wrap the
/// executor in a coordinator server behind a listener, print the
/// bound address, and hold until `--run-s` elapses (or forever). The
/// hold loop doubles as the node's SLO sampler.
pub(crate) fn expose_worker(
    opts: &ServeOpts,
    args: &Args,
    exec: Arc<dyn BatchExecutor>,
    ledger: Arc<Ledger>,
) -> Result<()> {
    let ship_upstream = args.get("ship-upstream").map(String::from);
    let image_hw = exec.image_hw();
    // server_config threads --chaos / --io-timeout-ms through for us;
    // announce the plan so a replayed run can be checked by eye.
    let mut cfg = opts.server_config(image_hw)?;
    if let Some(fi) = &opts.faults {
        println!("cluster-worker chaos: {}", fi.plan().summary());
    }
    let flight = opts.flight_recorder("worker");
    cfg.flight = flight.clone();
    cfg.ledger = Some(ledger);
    let slo = SloEngine::new(opts.slo.clone(), flight);
    cfg.slo = Some(slo.clone());
    let node = WorkerNode::start(
        exec,
        &opts.listen_addr(),
        // WorkerNode wires the spill sink to the upstream itself.
        cfg,
        ship_upstream,
    )?;
    println!("cluster-worker listening on {}", node.local_addr());
    opts.hold_sampling(|now_ms| {
        let input = node.server().slo_input();
        slo.observe(now_ms, &input);
        // Brownout: the SLO engine's level drives the admission caps
        // (`rust/docs/robustness.md`); applying it here keeps the
        // policy on the sampler's cadence.
        node.server().set_brownout(slo.brownout_level());
    });
    println!("cluster-worker metrics: {}", node.metrics().summary());
    print!(
        "{}",
        node.telemetry().snapshot().report(Some("serve.batch"))
    );
    node.shutdown();
    Ok(())
}

/// `zebra cluster-router`: shard requests across `--workers`.
pub fn run_router(args: &Args) -> Result<()> {
    let opts = ServeOpts::from_args(args)?;
    let workers: Vec<String> = args
        .get("workers")
        .context(
            "cluster-router needs --workers HOST:PORT[,HOST:PORT...]",
        )?
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    anyhow::ensure!(
        !workers.is_empty(),
        "--workers lists no usable addresses"
    );
    let mut cfg = RouterConfig::new(workers);
    cfg.mode = ShardMode::parse(&args.get_or("mode", "rr"))?;
    cfg.max_outstanding = args.get_usize("max-outstanding", 256)?;
    cfg.max_attempts =
        args.get_usize("max-attempts", cfg.max_attempts)?;
    cfg.heartbeat_every = Duration::from_millis(
        args.get_usize("heartbeat-ms", 250)? as u64,
    );
    // Self-healing knobs (router-only; see `rust/docs/robustness.md`).
    cfg.breaker.threshold = args
        .get_usize("breaker-threshold", cfg.breaker.threshold as usize)?
        as u32;
    cfg.breaker.probe_ms =
        args.get_usize("breaker-probe-ms", cfg.breaker.probe_ms as usize)?
            as u64;
    let rt_ms = args.get_usize("request-timeout-ms", 10_000)?;
    cfg.request_timeout =
        (rt_ms > 0).then(|| Duration::from_millis(rt_ms as u64));
    cfg.io_timeout = opts.io_timeout;
    cfg.faults = opts.faults.clone();
    if let Some(fi) = &opts.faults {
        println!("cluster-router chaos: {}", fi.plan().summary());
    }
    let flight = opts.flight_recorder("router");
    cfg.flight = flight.clone();
    cfg.ledger = Some(Ledger::new());
    let slo = SloEngine::new(opts.slo.clone(), flight);
    cfg.slo = Some(slo.clone());
    let n_workers = cfg.workers.len();
    let mode = cfg.mode;
    let router = Router::start(cfg, &opts.listen_addr())?;
    println!(
        "cluster-router listening on {} ({} workers, mode {}, {} alive)",
        router.local_addr(),
        n_workers,
        mode.name(),
        router.workers_alive()
    );
    opts.hold_sampling(|now_ms| {
        let input = router.slo_input();
        slo.observe(now_ms, &input);
        // Brownout level -> admission caps + trace thinning on the
        // dispatch path (`rust/docs/robustness.md`).
        router.set_brownout(slo.brownout_level());
    });
    println!("cluster-router stats: {}", router.stats().summary());
    print!("{}", router.telemetry().snapshot().report(None));
    // Exit-time dump so `--flight-dir` always leaves a post-mortem
    // file, even when nothing terminal happened during the run.
    if let Some(f) = router.flight() {
        if let Some(Err(e)) = f.dump() {
            eprintln!("flight dump failed: {e}");
        }
    }
    router.shutdown();
    Ok(())
}
