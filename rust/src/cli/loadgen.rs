//! `zebra loadgen` — drive a cluster router (or a bare worker / a
//! `serve --port` node) from `--conns` concurrent connections at a
//! target request rate and report latency percentiles, per-class
//! ok/shed/failed accounting, and the cluster's achieved zero-block
//! bandwidth savings.
//!
//! Latency is measured client-side: each [`ClusterClient`]'s reader
//! stamps responses the moment their frame arrives, and the samples
//! land in the same fixed-bucket histogram
//! ([`coordinator::Metrics`](crate::coordinator::Metrics)) the server
//! and router use, so p50/p95/p99 mean the same thing at every tier.
//!
//! Loadgen is also the trace edge: with `--trace-sample N` it assigns
//! the deterministic trace id for every request, the cluster assembles
//! spans hop by hop, and the edge closes each returned record with a
//! `client.rtt` span — the span envelope over the client-observed wall
//! is reported as trace coverage. `--scrape-ms M` polls the unified
//! observability report ([`ObsReport`]) on a side connection while the
//! run is in flight (the poller joins on every exit path, including
//! errors), and `--bench-json` (or a non-empty `ZEBRA_BENCH_OUT`)
//! writes the whole run as machine-readable `BENCH_PR9.json` — run
//! stats plus the per-layer bandwidth ledger and SLO breach counts
//! (see `rust/docs/observability.md`).
//!
//! Admission-control sheds are first-class outcomes, not faults:
//! every submitted request ends as exactly one of ok / shed / failed
//! (the run errors out if that accounting ever leaves a gap), and
//! `--fail-on-error` only rejects faults. `--expect-sheds` inverts
//! the check for overload smoke tests: the run fails unless the
//! cluster shed at least one request.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::Args;
use crate::backend::synth_images;
use crate::cluster::{ClusterClient, ClusterError};
use crate::coordinator::Metrics;
use crate::obs::{
    now_ns, parse_slo, render_waterfall, sampled, trace_id_for,
    LedgerSnapshot, ObsReport, TraceRecord,
};
use crate::telemetry::Telemetry;
use crate::tensor::{read_zten, Tensor};
use crate::util::json::{self, Value};

/// Per-class outcome counts, indexed by `Priority::as_u8`.
#[derive(Debug, Default, Clone)]
struct Tally {
    ok: [usize; 3],
    shed: [usize; 3],
    failed: usize,
}

impl Tally {
    fn absorb(&mut self, other: &Tally) {
        for i in 0..3 {
            self.ok[i] += other.ok[i];
            self.shed[i] += other.shed[i];
        }
        self.failed += other.failed;
    }

    fn ok_total(&self) -> usize {
        self.ok.iter().sum()
    }

    fn shed_total(&self) -> usize {
        self.shed.iter().sum()
    }
}

/// Everything one loadgen connection thread learned: outcome counts
/// plus the trace side (coverage sum over sampled responses and the
/// first full record, kept for the waterfall print).
#[derive(Default)]
struct ThreadOut {
    tally: Tally,
    traced: usize,
    coverage_sum: f64,
    first_trace: Option<TraceRecord>,
}

/// One `--scrape-ms` poll of the cluster's live report.
struct Scrape {
    t_ms: u64,
    responses: u64,
    shed: u64,
    routed: u64,
}

pub fn run(args: &Args) -> Result<()> {
    // Flag validation happens before any socket is touched.
    let opts = super::opts::ServeOpts::from_args(args)?;
    let addr = args
        .get("addr")
        .context("loadgen needs --addr HOST:PORT (a router or worker)")?
        .to_string();
    let smoke = crate::bench::smoke();
    let n = args.get_usize("requests", if smoke { 32 } else { 256 })?;
    anyhow::ensure!(n > 0, "--requests must be positive");
    let qps = args.get_f32("qps", 0.0)?;
    anyhow::ensure!(qps >= 0.0, "--qps must be >= 0 (0 = closed loop)");
    let conns = args.get_usize("conns", 1)?.max(1).min(n);
    // --keys N spreads requests over N shard keys (consistent-hash
    // affinity); 0 keeps the old default of one key per request.
    let keys = args.get_usize("keys", 0)?;
    let deadline = match args.get_usize("deadline-us", 0)? {
        0 => None,
        us => Some(Duration::from_micros(us as u64)),
    };
    let hw = args.get_usize("hw", 8)?;
    let seed = args.get_usize("seed", 0xC1A5)? as u64;
    let strict = args.get("fail-on-error").is_some();
    let expect_sheds = args.get("expect-sheds").is_some();
    let scrape_ms = args.get_usize("scrape-ms", 0)?;
    let bench_env = std::env::var_os("ZEBRA_BENCH_OUT")
        .is_some_and(|p| !p.is_empty());
    let bench_json = args.get("bench-json").is_some() || bench_env;
    let trace_every = opts.trace_sample;
    let mix = opts.priority;

    // Test set: a `.zten` export (--images F.zten) or deterministic
    // synthetic noise at the cluster's image size.
    let images = match args.get("images") {
        Some(path) => {
            let t = read_zten(path).with_context(|| {
                format!("loadgen --images {path:?}")
            })?;
            let s = t.shape().to_vec();
            anyhow::ensure!(
                s.len() == 4 && s[0] > 0 && s[1] == 3 && s[2] == s[3],
                "--images wants (N, 3, H, H) images, got {s:?}"
            );
            t
        }
        None => synth_images(hw, 16.min(n), seed),
    };
    let hw = images.shape()[2];
    let pool = images.shape()[0];
    let per = 3 * hw * hw;

    let hist = Metrics::new();
    println!(
        "loadgen: {n} requests of {hw}px images -> {addr} \
         ({} target, {conns} conns, {} priority{})",
        if qps > 0.0 {
            format!("{qps:.0} req/s")
        } else {
            "closed-loop".to_string()
        },
        mix.name(),
        if trace_every > 0 {
            format!(", tracing 1-in-{trace_every}")
        } else {
            String::new()
        }
    );

    // Client-side telemetry: time spent building+submitting requests
    // vs waiting on responses (pacing sleeps land in neither stage).
    let telemetry = Telemetry::new();
    let printed = AtomicUsize::new(0);

    // --scrape-ms: a side connection polls the unified report while
    // the run is live, so the time series captures the cluster *under*
    // load, not just the exit-time aggregate.
    let done = Arc::new(AtomicBool::new(false));
    let scraper = if scrape_ms > 0 {
        let addr = addr.clone();
        let done = Arc::clone(&done);
        let t0 = Instant::now();
        Some(std::thread::spawn(move || -> Vec<Scrape> {
            let mut out = Vec::new();
            let client = match ClusterClient::connect(&addr) {
                Ok(c) => c,
                Err(_) => return out,
            };
            while !done.load(Ordering::Relaxed) {
                // Sleep in short slices so the join at exit never
                // waits out a long --scrape-ms interval.
                let mut left = scrape_ms as u64;
                while left > 0 && !done.load(Ordering::Relaxed) {
                    let step = left.min(25);
                    std::thread::sleep(Duration::from_millis(step));
                    left -= step;
                }
                if done.load(Ordering::Relaxed) {
                    break;
                }
                if let Ok(r) = client.obs_report() {
                    out.push(Scrape {
                        t_ms: t0.elapsed().as_millis() as u64,
                        responses: r.stats.aggregate.responses,
                        shed: r.stats.aggregate.shed_low
                            + r.stats.aggregate.shed_normal
                            + r.stats.aggregate.shed_high
                            + r.stats.shed_low
                            + r.stats.shed_normal
                            + r.stats.shed_high,
                        routed: r.stats.routed,
                    });
                }
            }
            client.shutdown();
            out
        }))
    } else {
        None
    };

    let t0 = Instant::now();
    let run = std::thread::scope(|scope| -> Result<ThreadOut> {
        let mut handles = Vec::with_capacity(conns);
        for c in 0..conns {
            // Request indices are striped across connections so the
            // priority cycle, key spread, and trace-id assignment stay
            // deterministic regardless of --conns.
            let addr = &addr;
            let images = &images;
            let hist = &hist;
            let telemetry = &telemetry;
            let printed = &printed;
            handles.push(scope.spawn(move || -> Result<ThreadOut> {
                let client = ClusterClient::connect(addr)?;
                let st_submit = telemetry.stage("loadgen.submit");
                let st_wait = telemetry.stage("loadgen.wait");
                let mine: Vec<usize> =
                    (c..n).step_by(conns).collect();
                // Each connection paces its own share of --qps.
                let thread_qps = qps / conns as f32;
                let mut rxs = Vec::with_capacity(mine.len());
                for (j, &g) in mine.iter().enumerate() {
                    if thread_qps > 0.0 {
                        let due = t0
                            + Duration::from_secs_f64(
                                j as f64 / thread_qps as f64,
                            );
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                    }
                    let _t = st_submit.time();
                    let idx = g % pool;
                    let img = Tensor::from_vec(
                        &[3, hw, hw],
                        images.data()[idx * per..(idx + 1) * per]
                            .to_vec(),
                    );
                    let img_bytes = (img.data().len() * 4) as u64;
                    st_submit.add_bytes(img_bytes);
                    let prio = mix.for_request(g);
                    let key =
                        if keys > 0 { Some((g % keys) as u64) } else { None };
                    // The edge owns trace identity: id from (seed, g),
                    // sampling decided here and honored by every hop.
                    let (tid, samp) = if trace_every > 0 {
                        let tid = trace_id_for(seed, g as u64);
                        (tid, sampled(tid, trace_every))
                    } else {
                        (0, false)
                    };
                    let sub_ns = now_ns();
                    rxs.push((
                        prio,
                        samp,
                        sub_ns,
                        img_bytes,
                        client.submit_traced(
                            &img, key, prio, deadline, tid, samp,
                        )?,
                    ));
                }
                let mut out = ThreadOut::default();
                for (prio, samp, sub_ns, img_bytes, rx) in rxs {
                    let _t = st_wait.time();
                    let slot = prio.as_u8() as usize;
                    match rx.recv() {
                        Ok(Ok(resp)) => {
                            out.tally.ok[slot] += 1;
                            hist.record_latency_us(
                                resp.wall.as_micros() as u64,
                            );
                            if samp {
                                if let Some(mut rec) = resp.trace {
                                    let wall_ns = resp
                                        .wall
                                        .as_nanos()
                                        .min(u64::MAX as u128)
                                        as u64;
                                    out.coverage_sum +=
                                        envelope_coverage(&rec, wall_ns);
                                    out.traced += 1;
                                    rec.push(
                                        "client.rtt",
                                        sub_ns,
                                        sub_ns.saturating_add(wall_ns),
                                        img_bytes,
                                        0,
                                    );
                                    if out.first_trace.is_none() {
                                        out.first_trace = Some(rec);
                                    }
                                }
                            }
                        }
                        Ok(Err(e)) if e.is_overloaded() => {
                            out.tally.shed[slot] += 1;
                        }
                        Ok(Err(ClusterError::Failed(msg))) => {
                            if printed.fetch_add(1, Ordering::Relaxed) < 3 {
                                eprintln!("loadgen: request failed: {msg}");
                            }
                            out.tally.failed += 1;
                        }
                        Ok(Err(_)) | Err(_) => out.tally.failed += 1,
                    }
                }
                client.shutdown();
                Ok(out)
            }));
        }
        let mut total = ThreadOut::default();
        for h in handles {
            let got = h.join().expect("loadgen thread panicked")?;
            total.tally.absorb(&got.tally);
            total.traced += got.traced;
            total.coverage_sum += got.coverage_sum;
            if total.first_trace.is_none() {
                total.first_trace = got.first_trace;
            }
        }
        Ok(total)
    });
    let wall = t0.elapsed();
    // Reap the scraper before checking the run result: the old `?`
    // here skipped the stop flag and leaked a detached poller holding
    // its side connection open.
    done.store(true, Ordering::Relaxed);
    let scrapes = scraper
        .map(|h| h.join().unwrap_or_default())
        .unwrap_or_default();
    let run = run?;
    let tally = &run.tally;
    let (ok, shed) = (tally.ok_total(), tally.shed_total());
    println!(
        "loadgen: {ok}/{n} ok, {shed} shed \
         (low/normal/high {}/{}/{}), {} failed in {:.2}s — \
         {:.1} req/s achieved",
        tally.shed[0],
        tally.shed[1],
        tally.shed[2],
        tally.failed,
        wall.as_secs_f64(),
        ok as f64 / wall.as_secs_f64().max(1e-9)
    );
    println!(
        "latency (client-side): p50={}us p95={}us p99={}us",
        hist.latency_percentile_us(0.5),
        hist.latency_percentile_us(0.95),
        hist.latency_percentile_us(0.99)
    );
    if run.traced > 0 {
        println!(
            "traces: {} sampled responses, span envelope covers {:.1}% \
             of client-observed wall on average",
            run.traced,
            100.0 * run.coverage_sum / run.traced as f64
        );
    }
    if !scrapes.is_empty() {
        let last = scrapes.last().expect("non-empty");
        println!(
            "scrape: {} samples at {scrape_ms}ms (last: {} responses, \
             {} shed, {} routed)",
            scrapes.len(),
            last.responses,
            last.shed,
            last.routed
        );
    }

    // Cluster-wide view: the unified report (aggregated worker
    // counters + router counters + merged telemetry stages). A bare
    // worker answers with the router section zeroed.
    let report = match ClusterClient::connect(&addr).and_then(|c| {
        let r = c.obs_report();
        c.shutdown();
        r
    }) {
        Ok(report) => {
            let stats = &report.stats;
            println!("cluster: {}", stats.summary());
            println!(
                "worker compute threads: {} across {} alive workers \
                 (per-worker --threads / ZEBRA_THREADS, summed from the \
                 metrics snapshots)",
                stats.aggregate.exec_threads, stats.workers_alive
            );
            println!(
                "zero-block bandwidth savings: {:.1}% (Eq. 2-3 across \
                 {} responses)",
                stats.aggregate.reduction_pct(),
                stats.aggregate.responses
            );
            if stats.aggregate.shipped_spill_bytes > 0 {
                let shipped = stats.aggregate.shipped_spill_bytes;
                let received = stats.spill_bytes_in;
                println!(
                    "spill shipping: workers metered {shipped}B, router \
                     received {received}B{}",
                    if shipped == received {
                        " (exact match)"
                    } else {
                        " (frames still in flight)"
                    }
                );
            }
            if !report.telemetry.stages.is_empty() {
                println!("cluster telemetry (merged across nodes):");
                print!("{}", report.telemetry.report(None));
            }
            Some(report)
        }
        Err(e) => {
            println!("(no cluster stats from {addr}: {e:#})");
            None
        }
    };
    print!("{}", telemetry.snapshot().report(None));
    // One sampled request's full waterfall, rendered the same way
    // `zebra obs replay` renders flight dumps.
    if let Some(rec) = &run.first_trace {
        print!("\n{}", render_waterfall(rec));
    }

    if bench_json {
        let path = write_bench_json(
            n, conns, qps, scrape_ms, wall, &hist, &run, &scrapes,
            report.as_ref(),
        )?;
        println!("bench report written to {}", path.display());
    }

    // The no-silent-drops guarantee: every request ended as exactly
    // one of ok / shed / failed. A gap here is a protocol bug.
    anyhow::ensure!(
        ok + shed + tally.failed == n,
        "loadgen accounting gap: {ok} ok + {shed} shed + {} failed \
         != {n} submitted (a request was silently dropped)",
        tally.failed
    );
    anyhow::ensure!(
        !expect_sheds || shed > 0,
        "loadgen --expect-sheds: the cluster shed nothing (overload \
         was expected but admission control never engaged)"
    );
    anyhow::ensure!(
        !strict || tally.failed == 0,
        "loadgen --fail-on-error: {} of {n} requests failed \
         ({shed} sheds are admission control, not failures)",
        tally.failed
    );
    Ok(())
}

/// Fraction of `wall_ns` covered by the record's span envelope (min
/// start to max end across the hops' spans). Clock skew between nodes
/// can stretch the envelope past the wall, so clamp to 1.0; an empty
/// record covers nothing.
fn envelope_coverage(rec: &TraceRecord, wall_ns: u64) -> f64 {
    let lo = rec.spans.iter().map(|s| s.start_ns).min();
    let hi = rec.spans.iter().map(|s| s.end_ns).max();
    match (lo, hi) {
        (Some(lo), Some(hi)) => {
            let span = hi.saturating_sub(lo);
            (span as f64 / wall_ns.max(1) as f64).min(1.0)
        }
        _ => 0.0,
    }
}

/// Emit the machine-readable run report. `ZEBRA_BENCH_OUT` overrides
/// the path (CI artifacts, side-by-side A/B runs); the default is
/// `BENCH_PR9.json` in the working directory — generated output, never
/// committed. Schema documented in `rust/docs/observability.md`.
#[allow(clippy::too_many_arguments)]
fn write_bench_json(
    n: usize,
    conns: usize,
    qps: f32,
    scrape_ms: usize,
    wall: Duration,
    hist: &Metrics,
    run: &ThreadOut,
    scrapes: &[Scrape],
    report: Option<&ObsReport>,
) -> Result<std::path::PathBuf> {
    let num = Value::Num;
    let obj = |pairs: Vec<(&str, Value)>| {
        Value::Object(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect::<BTreeMap<_, _>>(),
        )
    };
    let tally = &run.tally;
    let class3 = |v: &[usize; 3]| {
        obj(vec![
            ("low", num(v[0] as f64)),
            ("normal", num(v[1] as f64)),
            ("high", num(v[2] as f64)),
        ])
    };
    let series = scrapes
        .iter()
        .map(|s| {
            obj(vec![
                ("t_ms", num(s.t_ms as f64)),
                ("responses", num(s.responses as f64)),
                ("shed", num(s.shed as f64)),
                ("routed", num(s.routed as f64)),
            ])
        })
        .collect();
    // Bandwidth ledger and SLO planes from the exit-time scrape, lifted
    // to top level so CI can assert on savings and breach counts
    // without digging through the full cluster report.
    let ledger = report.map_or(Value::Null, |r| {
        let snap = LedgerSnapshot::from_telemetry(&r.telemetry);
        Value::Object(
            snap.cells
                .iter()
                .map(|((layer, codec), c)| {
                    (
                        format!("{layer}/{codec}"),
                        obj(vec![
                            ("dense_bytes", num(c.dense_bytes as f64)),
                            ("encoded_bytes", num(c.encoded_bytes as f64)),
                            ("zero_permille", num(c.zero_permille() as f64)),
                            ("savings_pct", num(c.achieved_savings_pct())),
                            (
                                "analytic_savings_pct",
                                num(c.analytic_savings_pct()),
                            ),
                        ]),
                    )
                })
                .collect(),
        )
    });
    let slo = report.map_or(Value::Null, |r| {
        Value::Object(
            parse_slo(&r.telemetry)
                .iter()
                .map(|(name, v)| {
                    (
                        name.clone(),
                        obj(vec![
                            ("breaches", num(v.breaches as f64)),
                            ("active", Value::Bool(v.active)),
                            (
                                "threshold_milli",
                                num(v.threshold_milli as f64),
                            ),
                        ]),
                    )
                })
                .collect(),
        )
    });
    let root = obj(vec![
        ("bench", Value::Str("loadgen/pr9".into())),
        ("requests", num(n as f64)),
        ("conns", num(conns as f64)),
        ("target_qps", num(qps as f64)),
        ("wall_s", num(wall.as_secs_f64())),
        (
            "throughput_rps",
            num(tally.ok_total() as f64 / wall.as_secs_f64().max(1e-9)),
        ),
        (
            "latency",
            obj(vec![
                ("p50_us", num(hist.latency_percentile_us(0.5) as f64)),
                ("p95_us", num(hist.latency_percentile_us(0.95) as f64)),
                ("p99_us", num(hist.latency_percentile_us(0.99) as f64)),
            ]),
        ),
        ("ok", class3(&tally.ok)),
        ("shed", class3(&tally.shed)),
        ("failed", num(tally.failed as f64)),
        (
            "trace",
            obj(vec![
                ("sampled", num(run.traced as f64)),
                (
                    "mean_span_coverage",
                    num(if run.traced > 0 {
                        run.coverage_sum / run.traced as f64
                    } else {
                        0.0
                    }),
                ),
            ]),
        ),
        (
            "scrape",
            obj(vec![
                ("interval_ms", num(scrape_ms as f64)),
                ("samples", num(scrapes.len() as f64)),
                ("series", Value::Array(series)),
            ]),
        ),
        ("ledger", ledger),
        ("slo", slo),
        (
            "cluster",
            report.map_or(Value::Null, |r| r.to_json()),
        ),
    ]);
    let path = match std::env::var_os("ZEBRA_BENCH_OUT") {
        Some(p) if !p.is_empty() => std::path::PathBuf::from(p),
        _ => std::path::PathBuf::from("BENCH_PR9.json"),
    };
    std::fs::write(&path, json::to_string(&root) + "\n")
        .with_context(|| format!("writing bench report {path:?}"))?;
    Ok(path)
}
