//! `zebra loadgen` — drive a cluster router (or a bare worker / a
//! `serve --port` node) from `--conns` concurrent connections at a
//! target request rate and report latency percentiles, per-class
//! ok/shed/failed accounting, and the cluster's achieved zero-block
//! bandwidth savings.
//!
//! Latency is measured client-side: each [`ClusterClient`]'s reader
//! stamps responses the moment their frame arrives, and the samples
//! land in the same fixed-bucket histogram
//! ([`coordinator::Metrics`](crate::coordinator::Metrics)) the server
//! and router use, so p50/p95/p99 mean the same thing at every tier.
//!
//! Admission-control sheds are first-class outcomes, not faults:
//! every submitted request ends as exactly one of ok / shed / failed
//! (the run errors out if that accounting ever leaves a gap), and
//! `--fail-on-error` only rejects faults. `--expect-sheds` inverts
//! the check for overload smoke tests: the run fails unless the
//! cluster shed at least one request.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::Args;
use crate::backend::synth_images;
use crate::cluster::{ClusterClient, ClusterError};
use crate::coordinator::Metrics;
use crate::telemetry::Telemetry;
use crate::tensor::{read_zten, Tensor};

/// Per-class outcome counts, indexed by `Priority::as_u8`.
#[derive(Debug, Default, Clone)]
struct Tally {
    ok: [usize; 3],
    shed: [usize; 3],
    failed: usize,
}

impl Tally {
    fn absorb(&mut self, other: &Tally) {
        for i in 0..3 {
            self.ok[i] += other.ok[i];
            self.shed[i] += other.shed[i];
        }
        self.failed += other.failed;
    }

    fn ok_total(&self) -> usize {
        self.ok.iter().sum()
    }

    fn shed_total(&self) -> usize {
        self.shed.iter().sum()
    }
}

pub fn run(args: &Args) -> Result<()> {
    // Flag validation happens before any socket is touched.
    let opts = super::opts::ServeOpts::from_args(args)?;
    let addr = args
        .get("addr")
        .context("loadgen needs --addr HOST:PORT (a router or worker)")?
        .to_string();
    let smoke = crate::bench::smoke();
    let n = args.get_usize("requests", if smoke { 32 } else { 256 })?;
    anyhow::ensure!(n > 0, "--requests must be positive");
    let qps = args.get_f32("qps", 0.0)?;
    anyhow::ensure!(qps >= 0.0, "--qps must be >= 0 (0 = closed loop)");
    let conns = args.get_usize("conns", 1)?.max(1).min(n);
    // --keys N spreads requests over N shard keys (consistent-hash
    // affinity); 0 keeps the old default of one key per request.
    let keys = args.get_usize("keys", 0)?;
    let deadline = match args.get_usize("deadline-us", 0)? {
        0 => None,
        us => Some(Duration::from_micros(us as u64)),
    };
    let hw = args.get_usize("hw", 8)?;
    let seed = args.get_usize("seed", 0xC1A5)? as u64;
    let strict = args.get("fail-on-error").is_some();
    let expect_sheds = args.get("expect-sheds").is_some();
    let mix = opts.priority;

    // Test set: a `.zten` export (--images F.zten) or deterministic
    // synthetic noise at the cluster's image size.
    let images = match args.get("images") {
        Some(path) => {
            let t = read_zten(path).with_context(|| {
                format!("loadgen --images {path:?}")
            })?;
            let s = t.shape().to_vec();
            anyhow::ensure!(
                s.len() == 4 && s[0] > 0 && s[1] == 3 && s[2] == s[3],
                "--images wants (N, 3, H, H) images, got {s:?}"
            );
            t
        }
        None => synth_images(hw, 16.min(n), seed),
    };
    let hw = images.shape()[2];
    let pool = images.shape()[0];
    let per = 3 * hw * hw;

    let hist = Metrics::new();
    println!(
        "loadgen: {n} requests of {hw}px images -> {addr} \
         ({} target, {conns} conns, {} priority)",
        if qps > 0.0 {
            format!("{qps:.0} req/s")
        } else {
            "closed-loop".to_string()
        },
        mix.name()
    );

    // Client-side telemetry: time spent building+submitting requests
    // vs waiting on responses (pacing sleeps land in neither stage).
    let telemetry = Telemetry::new();
    let printed = AtomicUsize::new(0);

    let t0 = Instant::now();
    let tally = std::thread::scope(|scope| -> Result<Tally> {
        let mut handles = Vec::with_capacity(conns);
        for c in 0..conns {
            // Request indices are striped across connections so the
            // priority cycle and key spread stay deterministic
            // regardless of --conns.
            let addr = &addr;
            let images = &images;
            let hist = &hist;
            let telemetry = &telemetry;
            let printed = &printed;
            handles.push(scope.spawn(move || -> Result<Tally> {
                let client = ClusterClient::connect(addr)?;
                let st_submit = telemetry.stage("loadgen.submit");
                let st_wait = telemetry.stage("loadgen.wait");
                let mine: Vec<usize> =
                    (c..n).step_by(conns).collect();
                // Each connection paces its own share of --qps.
                let thread_qps = qps / conns as f32;
                let mut rxs = Vec::with_capacity(mine.len());
                for (j, &g) in mine.iter().enumerate() {
                    if thread_qps > 0.0 {
                        let due = t0
                            + Duration::from_secs_f64(
                                j as f64 / thread_qps as f64,
                            );
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                    }
                    let _t = st_submit.time();
                    let idx = g % pool;
                    let img = Tensor::from_vec(
                        &[3, hw, hw],
                        images.data()[idx * per..(idx + 1) * per]
                            .to_vec(),
                    );
                    st_submit.add_bytes((img.data().len() * 4) as u64);
                    let prio = mix.for_request(g);
                    let key =
                        if keys > 0 { Some((g % keys) as u64) } else { None };
                    rxs.push((
                        prio,
                        client.submit_request(&img, key, prio, deadline)?,
                    ));
                }
                let mut tally = Tally::default();
                for (prio, rx) in rxs {
                    let _t = st_wait.time();
                    let slot = prio.as_u8() as usize;
                    match rx.recv() {
                        Ok(Ok(resp)) => {
                            tally.ok[slot] += 1;
                            hist.record_latency_us(
                                resp.wall.as_micros() as u64,
                            );
                        }
                        Ok(Err(e)) if e.is_overloaded() => {
                            tally.shed[slot] += 1;
                        }
                        Ok(Err(ClusterError::Failed(msg))) => {
                            if printed.fetch_add(1, Ordering::Relaxed) < 3 {
                                eprintln!("loadgen: request failed: {msg}");
                            }
                            tally.failed += 1;
                        }
                        Ok(Err(_)) | Err(_) => tally.failed += 1,
                    }
                }
                client.shutdown();
                Ok(tally)
            }));
        }
        let mut total = Tally::default();
        for h in handles {
            total.absorb(&h.join().expect("loadgen thread panicked")?);
        }
        Ok(total)
    })?;
    let wall = t0.elapsed();
    let (ok, shed) = (tally.ok_total(), tally.shed_total());
    println!(
        "loadgen: {ok}/{n} ok, {shed} shed \
         (low/normal/high {}/{}/{}), {} failed in {:.2}s — \
         {:.1} req/s achieved",
        tally.shed[0],
        tally.shed[1],
        tally.shed[2],
        tally.failed,
        wall.as_secs_f64(),
        ok as f64 / wall.as_secs_f64().max(1e-9)
    );
    println!(
        "latency (client-side): p50={}us p95={}us p99={}us",
        hist.latency_percentile_us(0.5),
        hist.latency_percentile_us(0.95),
        hist.latency_percentile_us(0.99)
    );

    // Cluster-wide view: aggregated worker metrics + router counters.
    // A bare worker answers with a plain snapshot, which fails the
    // ClusterStats parse — report and move on.
    match ClusterClient::connect(&addr).and_then(|c| {
        let s = c.stats();
        c.shutdown();
        s
    }) {
        Ok(stats) => {
            println!("cluster: {}", stats.summary());
            println!(
                "worker compute threads: {} across {} alive workers \
                 (per-worker --threads / ZEBRA_THREADS, summed from the \
                 metrics snapshots)",
                stats.aggregate.exec_threads, stats.workers_alive
            );
            println!(
                "zero-block bandwidth savings: {:.1}% (Eq. 2-3 across \
                 {} responses)",
                stats.aggregate.reduction_pct(),
                stats.aggregate.responses
            );
            if stats.aggregate.shipped_spill_bytes > 0 {
                let shipped = stats.aggregate.shipped_spill_bytes;
                let received = stats.spill_bytes_in;
                println!(
                    "spill shipping: workers metered {shipped}B, router \
                     received {received}B{}",
                    if shipped == received {
                        " (exact match)"
                    } else {
                        " (frames still in flight)"
                    }
                );
            }
        }
        Err(e) => println!("(no cluster stats from {addr}: {e:#})"),
    }
    print!("{}", telemetry.snapshot().report(None));

    // The no-silent-drops guarantee: every request ended as exactly
    // one of ok / shed / failed. A gap here is a protocol bug.
    anyhow::ensure!(
        ok + shed + tally.failed == n,
        "loadgen accounting gap: {ok} ok + {shed} shed + {} failed \
         != {n} submitted (a request was silently dropped)",
        tally.failed
    );
    anyhow::ensure!(
        !expect_sheds || shed > 0,
        "loadgen --expect-sheds: the cluster shed nothing (overload \
         was expected but admission control never engaged)"
    );
    anyhow::ensure!(
        !strict || tally.failed == 0,
        "loadgen --fail-on-error: {} of {n} requests failed \
         ({shed} sheds are admission control, not failures)",
        tally.failed
    );
    Ok(())
}
