//! `zebra loadgen` — drive a cluster router (or a bare worker / a
//! `serve --port` node) at a target request rate and report latency
//! percentiles plus the cluster's achieved zero-block bandwidth
//! savings.
//!
//! Latency is measured client-side: the [`ClusterClient`]'s reader
//! stamps each response the moment its frame arrives, and the samples
//! land in the same fixed-bucket histogram
//! ([`coordinator::Metrics`](crate::coordinator::Metrics)) the server
//! and router use, so p50/p95/p99 mean the same thing at every tier.

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::Args;
use crate::backend::synth_images;
use crate::cluster::ClusterClient;
use crate::coordinator::Metrics;
use crate::telemetry::Telemetry;
use crate::tensor::{read_zten, Tensor};

pub fn run(args: &Args) -> Result<()> {
    let addr = args
        .get("addr")
        .context("loadgen needs --addr HOST:PORT (a router or worker)")?;
    let smoke = crate::bench::smoke();
    let n = args.get_usize("requests", if smoke { 32 } else { 256 })?;
    anyhow::ensure!(n > 0, "--requests must be positive");
    let qps = args.get_f32("qps", 0.0)?;
    anyhow::ensure!(qps >= 0.0, "--qps must be >= 0 (0 = closed loop)");
    let hw = args.get_usize("hw", 8)?;
    let seed = args.get_usize("seed", 0xC1A5)? as u64;
    let strict = args.get("fail-on-error").is_some();

    // Test set: a `.zten` export (--images F.zten) or deterministic
    // synthetic noise at the cluster's image size.
    let images = match args.get("images") {
        Some(path) => {
            let t = read_zten(path).with_context(|| {
                format!("loadgen --images {path:?}")
            })?;
            let s = t.shape().to_vec();
            anyhow::ensure!(
                s.len() == 4 && s[0] > 0 && s[1] == 3 && s[2] == s[3],
                "--images wants (N, 3, H, H) images, got {s:?}"
            );
            t
        }
        None => synth_images(hw, 16.min(n), seed),
    };
    let hw = images.shape()[2];
    let pool = images.shape()[0];
    let per = 3 * hw * hw;

    let client = ClusterClient::connect(addr)?;
    let hist = Metrics::new();
    println!(
        "loadgen: {n} requests of {hw}px images -> {addr} \
         ({} target)",
        if qps > 0.0 {
            format!("{qps:.0} req/s")
        } else {
            "closed-loop".to_string()
        }
    );

    // Client-side telemetry: time spent building+submitting requests
    // vs waiting on responses (pacing sleeps land in neither stage).
    let telemetry = Telemetry::new();
    let st_submit = telemetry.stage("loadgen.submit");
    let st_wait = telemetry.stage("loadgen.wait");

    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        if qps > 0.0 {
            let due = t0 + Duration::from_secs_f64(i as f64 / qps as f64);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        let _t = st_submit.time();
        let idx = i % pool;
        let img = Tensor::from_vec(
            &[3, hw, hw],
            images.data()[idx * per..(idx + 1) * per].to_vec(),
        );
        st_submit.add_bytes((img.data().len() * 4) as u64);
        rxs.push(client.submit(&img)?);
    }
    let mut ok = 0usize;
    let mut errors = 0usize;
    for rx in rxs {
        let _t = st_wait.time();
        match rx.recv() {
            Ok(Ok(resp)) => {
                ok += 1;
                hist.record_latency_us(resp.wall.as_micros() as u64);
            }
            Ok(Err(msg)) => {
                if errors < 3 {
                    eprintln!("loadgen: request failed: {msg}");
                }
                errors += 1;
            }
            Err(_) => errors += 1,
        }
    }
    let wall = t0.elapsed();
    println!(
        "loadgen: {ok}/{n} ok ({errors} errors) in {:.2}s — {:.1} req/s \
         achieved",
        wall.as_secs_f64(),
        ok as f64 / wall.as_secs_f64().max(1e-9)
    );
    println!(
        "latency (client-side): p50={}us p95={}us p99={}us",
        hist.latency_percentile_us(0.5),
        hist.latency_percentile_us(0.95),
        hist.latency_percentile_us(0.99)
    );

    // Cluster-wide view: aggregated worker metrics + router counters.
    // A bare worker answers with a plain snapshot, which fails the
    // ClusterStats parse — report and move on.
    match client.stats() {
        Ok(stats) => {
            println!("cluster: {}", stats.summary());
            println!(
                "worker compute threads: {} across {} alive workers \
                 (per-worker --threads / ZEBRA_THREADS, summed from the \
                 metrics snapshots)",
                stats.aggregate.exec_threads, stats.workers_alive
            );
            println!(
                "zero-block bandwidth savings: {:.1}% (Eq. 2-3 across \
                 {} responses)",
                stats.aggregate.reduction_pct(),
                stats.aggregate.responses
            );
            if stats.aggregate.shipped_spill_bytes > 0 {
                let shipped = stats.aggregate.shipped_spill_bytes;
                let received = stats.spill_bytes_in;
                println!(
                    "spill shipping: workers metered {shipped}B, router \
                     received {received}B{}",
                    if shipped == received {
                        " (exact match)"
                    } else {
                        " (frames still in flight)"
                    }
                );
            }
        }
        Err(e) => println!("(no cluster stats from {addr}: {e:#})"),
    }
    print!("{}", telemetry.snapshot().report(None));
    client.shutdown();
    anyhow::ensure!(
        !strict || errors == 0,
        "loadgen --fail-on-error: {errors} of {n} requests failed"
    );
    Ok(())
}
