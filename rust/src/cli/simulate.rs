//! `zebra simulate` — run the accelerator model over real activation
//! spills with one codec (or all of them) and print the per-layer
//! timing/traffic table.
//!
//! Spills come from either a Python-dumped trace (`--trace DIR`) or,
//! artifact-free, from natively executing the reference backend on
//! synthetic images (`--backend reference [--model KEY] [--images N]`).

use anyhow::{bail, Result};

use super::Args;
use crate::accel::{simulate_trace, AccelConfig, LayerDesc, SimReport};
use crate::backend::reference::{RefSpec, ReferenceBackend};
use crate::backend::{synth_images, BackendKind, InferenceBackend};
use crate::bench::Table;
use crate::compress::{all_codecs, from_name, DenseCodec};
use crate::tensor::Tensor;
use crate::zebra::bandwidth::fmt_bytes;

pub fn run(args: &Args) -> Result<()> {
    let (label, layers, tensors) = if let Some(dir) = args.get("trace") {
        if args.get("weights").is_some() {
            bail!("--weights only applies to --backend reference");
        }
        let tr = crate::trace::load(dir)?;
        let plan = tr.plan();
        let layers = LayerDesc::from_plan(&plan);
        let tensors: Vec<Tensor> =
            tr.spills.iter().map(|s| s.tensor.clone()).collect();
        (tr.model.clone(), layers, tensors)
    } else if args.get("backend").is_some() {
        let backend = BackendKind::parse(&args.get_or("backend", "reference"))?;
        if backend != BackendKind::Reference {
            bail!(
                "only `--backend reference` can synthesize spills; the \
                 pjrt backend simulates via `--trace DIR`"
            );
        }
        let model = args.get_or("model", "rn18-c10-t0.1");
        let n = args.get_usize("images", 8)?.max(1);
        let seed = args.get_usize("seed", 0x5EED)? as u64;
        let mut spec = RefSpec::from_key(&model)?;
        // Block-sparse engine worker threads (0 = ZEBRA_THREADS or 1;
        // spills are bitwise-identical at any setting).
        spec.threads = args.get_usize("threads", 0)?;
        // Trained leaves (e.g. from `zebra train --out DIR`): the
        // zero-block ratio below then measures the *learned* sparsity.
        if let Some(dir) = args.get("weights") {
            let dir = std::path::PathBuf::from(dir);
            anyhow::ensure!(
                dir.is_dir(),
                "--weights {dir:?} is not a directory"
            );
            // Explicit --weights must be a complete checkpoint — no
            // silent per-leaf fallback to generated weights.
            crate::backend::reference::check_complete_leaves(&spec, &dir)?;
            println!("loading reference weights from {dir:?}");
            spec.weights_dir = Some(dir);
        }
        let be = ReferenceBackend::new(spec)?;
        let x = synth_images(be.image_hw(), n, seed);
        println!(
            "executing {model} on the reference backend ({n} synthetic \
             images, seed {seed:#x}) ..."
        );
        let (_, spills) = be.run_capture(&x)?;
        print_zero_block_summary(be.spec(), &spills, n);
        let layers = LayerDesc::from_plan(&be.spec().spills);
        (model, layers, spills)
    } else {
        bail!("simulate needs --trace DIR or --backend reference");
    };

    let cfg = AccelConfig::default();
    // One codec instance encodes every layer, so its block size must
    // divide every map. Blocks are powers of two clamped to the map
    // (models::block_for), so the plan's MINIMUM block divides all
    // maps; the max would panic on plans whose deep layers shrink the
    // block (vgg16/mbnet 2x2 tails).
    let block = layers
        .iter()
        .map(|l| l.spill.block)
        .min()
        .unwrap_or(4);

    let dense = simulate_trace(&cfg, &layers, &tensors, &DenseCodec)?;
    if args.get("all").is_some() {
        let mut t = Table::new(&[
            "codec", "act bytes/img", "cycles", "latency ms", "energy uJ",
            "reduction %",
        ]);
        for codec in all_codecs(block) {
            let r = simulate_trace(&cfg, &layers, &tensors, codec.as_ref())?;
            push_summary(&mut t, &cfg, &r, &dense);
        }
        t.print(&format!("Accelerator simulation — {label} (all codecs)"));
    } else {
        let name = args.get_or("codec", "zero-block");
        // Registry-backed parsing: an unknown name errors with the full
        // list of valid codec names.
        let codec = from_name(&name, block)?;
        let r = simulate_trace(&cfg, &layers, &tensors, codec.as_ref())?;
        per_layer_table(&r).print(&format!(
            "Accelerator simulation — {label} with {name}"
        ));
        let mut t = Table::new(&[
            "codec", "act bytes/img", "cycles", "latency ms", "energy uJ",
            "reduction %",
        ]);
        push_summary(&mut t, &cfg, &dense, &dense);
        push_summary(&mut t, &cfg, &r, &dense);
        t.print("Summary vs dense");
    }
    Ok(())
}

/// Eq. 2–3 accounting of the captured spills, through the same
/// `zero_block_accounting` path `zebra train`'s per-epoch evaluation
/// uses — the quantity training optimizes, printed here so
/// trained-vs-untrained runs are directly comparable.
fn print_zero_block_summary(
    spec: &crate::backend::reference::RefSpec,
    spills: &[Tensor],
    images: usize,
) {
    let s = crate::zebra::bandwidth::zero_block_accounting(
        &spec.spills,
        spills,
    );
    // The report is already per image (kept fractions are
    // batch-invariant; shapes are per-map).
    println!(
        "zero blocks: {:.1}% ({} of {} across {} layers, {} images) | \
         Eq.2-3: required {}/img, stored {}/img, index {}/img -> \
         reduction {:.1}%",
        s.zero_pct,
        s.zero_blocks,
        s.total_blocks,
        spec.spills.len(),
        images,
        fmt_bytes(s.report.required_bytes),
        fmt_bytes(s.report.stored_bytes),
        fmt_bytes(s.report.overhead_bytes),
        s.report.reduced_pct()
    );
}

fn push_summary(
    t: &mut Table,
    cfg: &AccelConfig,
    r: &SimReport,
    dense: &SimReport,
) {
    t.row(&[
        r.codec.clone(),
        fmt_bytes(r.activation_bytes() as f64),
        r.total_cycles.to_string(),
        format!("{:.3}", r.latency_ms(cfg)),
        format!("{:.1}", r.total_energy_pj / 1e6),
        format!("{:.1}", r.reduction_vs(dense)),
    ]);
}

fn per_layer_table(r: &SimReport) -> Table {
    let mut t = Table::new(&[
        "layer", "compute cyc", "mem cyc", "bound", "act out", "util %",
    ]);
    for l in &r.layers {
        t.row(&[
            l.name.clone(),
            l.compute_cycles.to_string(),
            l.mem_cycles.to_string(),
            if l.memory_bound { "MEM" } else { "PE" }.to_string(),
            fmt_bytes(l.act_bytes_out as f64),
            format!("{:.0}", 100.0 * l.utilization),
        ]);
    }
    t
}
