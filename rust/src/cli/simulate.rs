//! `zebra simulate` — run the accelerator model over a trace with one
//! codec (or all of them) and print the per-layer timing/traffic table.

use anyhow::Result;

use super::Args;
use crate::accel::{simulate_trace, AccelConfig, LayerDesc, SimReport};
use crate::bench::Table;
use crate::compress::{all_codecs, from_name, DenseCodec};
use crate::tensor::Tensor;
use crate::zebra::bandwidth::fmt_bytes;

pub fn run(args: &Args) -> Result<()> {
    let dir = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("simulate needs --trace DIR"))?;
    let tr = crate::trace::load(dir)?;
    let cfg = AccelConfig::default();
    let plan = tr.plan();
    let layers = LayerDesc::from_plan(&plan);
    let tensors: Vec<Tensor> =
        tr.spills.iter().map(|s| s.tensor.clone()).collect();
    let block = plan.iter().map(|s| s.block).max().unwrap_or(4);

    let dense = simulate_trace(&cfg, &layers, &tensors, &DenseCodec)?;
    if args.get("all").is_some() {
        let mut t = Table::new(&[
            "codec", "act bytes/img", "cycles", "latency ms", "energy uJ",
            "reduction %",
        ]);
        for codec in all_codecs(block) {
            let r = simulate_trace(&cfg, &layers, &tensors, codec.as_ref())?;
            push_summary(&mut t, &cfg, &r, &dense);
        }
        t.print(&format!("Accelerator simulation — {} (all codecs)", tr.model));
    } else {
        let name = args.get_or("codec", "zero-block");
        // Registry-backed parsing: an unknown name errors with the full
        // list of valid codec names.
        let codec = from_name(&name, block)?;
        let r = simulate_trace(&cfg, &layers, &tensors, codec.as_ref())?;
        per_layer_table(&r).print(&format!(
            "Accelerator simulation — {} with {}",
            tr.model, name
        ));
        let mut t = Table::new(&[
            "codec", "act bytes/img", "cycles", "latency ms", "energy uJ",
            "reduction %",
        ]);
        push_summary(&mut t, &cfg, &dense, &dense);
        push_summary(&mut t, &cfg, &r, &dense);
        t.print("Summary vs dense");
    }
    Ok(())
}

fn push_summary(
    t: &mut Table,
    cfg: &AccelConfig,
    r: &SimReport,
    dense: &SimReport,
) {
    t.row(&[
        r.codec.clone(),
        fmt_bytes(r.activation_bytes() as f64),
        r.total_cycles.to_string(),
        format!("{:.3}", r.latency_ms(cfg)),
        format!("{:.1}", r.total_energy_pj / 1e6),
        format!("{:.1}", r.reduction_vs(dense)),
    ]);
}

fn per_layer_table(r: &SimReport) -> Table {
    let mut t = Table::new(&[
        "layer", "compute cyc", "mem cyc", "bound", "act out", "util %",
    ]);
    for l in &r.layers {
        t.row(&[
            l.name.clone(),
            l.compute_cycles.to_string(),
            l.mem_cycles.to_string(),
            if l.memory_bound { "MEM" } else { "PE" }.to_string(),
            fmt_bytes(l.act_bytes_out as f64),
            format!("{:.0}", 100.0 * l.utilization),
        ]);
    }
    t
}
