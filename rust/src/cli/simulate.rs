//! `zebra simulate` — run the accelerator model over real activation
//! spills with one codec (or all of them) and print the per-layer
//! timing/traffic table, and `zebra targets` — sweep one model across
//! every committed hardware profile in `rust/targets/`.
//!
//! Spills come from either a Python-dumped trace (`--trace DIR`) or,
//! artifact-free, from natively executing the reference backend on
//! synthetic images (`--backend reference [--model KEY] [--images N]`).
//! The hardware envelope comes from a target manifest
//! (`--target <file|name>`, default `default` — see
//! `rust/docs/targets.md`); `--json` swaps the tables for one
//! machine-readable document on stdout.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::Args;
use crate::accel::{
    simulate_trace_on, AccelConfig, LayerDesc, SimReport,
};
use crate::backend::reference::{RefSpec, ReferenceBackend};
use crate::backend::{synth_images, BackendKind, InferenceBackend};
use crate::bench::Table;
use crate::compress::{all_codecs, from_name, DenseCodec, ZeroBlockCodec};
use crate::hal::{builtin_targets, resolve_target, TargetManifest};
use crate::telemetry::Telemetry;
use crate::tensor::Tensor;
use crate::util::json::{self, Value};
use crate::zebra::bandwidth::fmt_bytes;

/// The model + its captured spills, ready to simulate on any target.
struct SimInputs {
    label: String,
    layers: Vec<LayerDesc>,
    tensors: Vec<Tensor>,
}

/// Load simulation inputs the way `zebra simulate` always has. With
/// `quiet` (JSON mode) the progress/summary lines go to stderr so
/// stdout stays machine-readable.
fn load_inputs(args: &Args, quiet: bool) -> Result<SimInputs> {
    let say = |line: String| {
        if quiet {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    };
    if let Some(dir) = args.get("trace") {
        if args.get("weights").is_some() {
            bail!("--weights only applies to --backend reference");
        }
        let tr = crate::trace::load(dir)?;
        let plan = tr.plan();
        let layers = LayerDesc::from_plan(&plan);
        let tensors: Vec<Tensor> =
            tr.spills.iter().map(|s| s.tensor.clone()).collect();
        Ok(SimInputs { label: tr.model.clone(), layers, tensors })
    } else if args.get("backend").is_some() {
        let backend = BackendKind::parse(&args.get_or("backend", "reference"))?;
        if backend != BackendKind::Reference {
            bail!(
                "only `--backend reference` can synthesize spills; the \
                 pjrt backend simulates via `--trace DIR`"
            );
        }
        let model = args.get_or("model", "rn18-c10-t0.1");
        let n = args.get_usize("images", 8)?.max(1);
        let seed = args.get_usize("seed", 0x5EED)? as u64;
        let mut spec = RefSpec::from_key(&model)?;
        // Block-sparse engine worker threads (0 = ZEBRA_THREADS or 1;
        // spills are bitwise-identical at any setting).
        spec.threads = args.get_usize("threads", 0)?;
        // Trained leaves (e.g. from `zebra train --out DIR`): the
        // zero-block ratio below then measures the *learned* sparsity.
        if let Some(dir) = args.get("weights") {
            let dir = std::path::PathBuf::from(dir);
            anyhow::ensure!(
                dir.is_dir(),
                "--weights {dir:?} is not a directory"
            );
            // Explicit --weights must be a complete checkpoint — no
            // silent per-leaf fallback to generated weights.
            crate::backend::reference::check_complete_leaves(&spec, &dir)?;
            say(format!("loading reference weights from {dir:?}"));
            spec.weights_dir = Some(dir);
        }
        let be = ReferenceBackend::new(spec)?;
        let x = synth_images(be.image_hw(), n, seed);
        say(format!(
            "executing {model} on the reference backend ({n} synthetic \
             images, seed {seed:#x}) ..."
        ));
        let (_, spills) = be.run_capture(&x)?;
        say(zero_block_summary(be.spec(), &spills, n));
        let layers = LayerDesc::from_plan(&be.spec().spills);
        Ok(SimInputs { label: model, layers, tensors: spills })
    } else {
        bail!("simulate needs --trace DIR or --backend reference");
    }
}

/// One codec instance encodes every layer, so its block size must
/// divide every map. Blocks are powers of two clamped to the map
/// (models::block_for), so the plan's MINIMUM block divides all maps;
/// the max would panic on plans whose deep layers shrink the block
/// (vgg16/mbnet 2x2 tails).
fn common_block(layers: &[LayerDesc]) -> usize {
    layers.iter().map(|l| l.spill.block).min().unwrap_or(4)
}

pub fn run(args: &Args) -> Result<()> {
    // Resolve the hardware envelope before any heavy work: a bad
    // --target must fail fast, not after a model execution.
    let target = resolve_target(&args.get_or("target", "default"))?;
    let json_mode = args.get("json").is_some();
    let inputs = load_inputs(args, json_mode)?;
    let SimInputs { label, layers, tensors } = &inputs;
    let telemetry = Telemetry::new();
    let block = common_block(layers);

    let dense =
        simulate_trace_on(&target, layers, tensors, &DenseCodec, &telemetry)?;
    let cfg = target.accel_config();
    let mut reports: Vec<SimReport> = Vec::new();
    if args.get("all").is_some() {
        for codec in all_codecs(block) {
            reports.push(simulate_trace_on(
                &target,
                layers,
                tensors,
                codec.as_ref(),
                &telemetry,
            )?);
        }
    } else {
        let name = args.get_or("codec", "zero-block");
        // Registry-backed parsing: an unknown name errors with the full
        // list of valid codec names.
        let codec = from_name(&name, block)?;
        reports.push(dense.clone());
        reports.push(simulate_trace_on(
            &target,
            layers,
            tensors,
            codec.as_ref(),
            &telemetry,
        )?);
    }

    if json_mode {
        let doc = obj(vec![
            ("model", Value::Str(label.clone())),
            ("target", target_json(&target)),
            (
                "codecs",
                Value::Array(
                    reports
                        .iter()
                        .map(|r| report_json(r, &cfg, &dense))
                        .collect(),
                ),
            ),
        ]);
        println!("{}", json::to_string(&doc));
        return Ok(());
    }

    println!("target {}", target.describe());
    if args.get("all").is_some() {
        let mut t = summary_table();
        for r in &reports {
            push_summary(&mut t, &cfg, r, &dense);
        }
        t.print(&format!(
            "Accelerator simulation — {label} on {} (all codecs)",
            target.name
        ));
    } else {
        let r = reports.last().expect("dense + one codec");
        per_layer_table(r).print(&format!(
            "Accelerator simulation — {label} with {} on {}",
            r.codec, target.name
        ));
        let mut t = summary_table();
        for r in &reports {
            push_summary(&mut t, &cfg, r, &dense);
        }
        t.print("Summary vs dense");
    }
    print!("{}", telemetry.snapshot().report(Some("sim.model")));
    Ok(())
}

/// `zebra targets` — run one model's spills across every committed
/// hardware profile and print the per-target dense-vs-Zebra Eq. 2–3
/// bandwidth/latency table.
pub fn targets(args: &Args) -> Result<()> {
    let json_mode = args.get("json").is_some();
    if args.get("target").is_some() {
        bail!("`zebra targets` sweeps ALL profiles; use `zebra simulate \
               --target` for one");
    }
    let profiles = builtin_targets()?;
    let inputs = load_inputs(args, json_mode)?;
    let SimInputs { label, layers, tensors } = &inputs;
    let telemetry = Telemetry::new();
    let block = common_block(layers);
    let zb = ZeroBlockCodec::new(block);

    let mut rows = Vec::new();
    for target in &profiles {
        let dense = simulate_trace_on(
            target, layers, tensors, &DenseCodec, &telemetry,
        )?;
        let zebra =
            simulate_trace_on(target, layers, tensors, &zb, &telemetry)?;
        rows.push((target, dense, zebra));
    }

    if json_mode {
        let doc = obj(vec![
            ("model", Value::Str(label.clone())),
            (
                "targets",
                Value::Array(
                    rows.iter()
                        .map(|(t, dense, zebra)| {
                            let cfg = t.accel_config();
                            obj(vec![
                                ("target", target_json(t)),
                                ("dense", report_json(dense, &cfg, dense)),
                                ("zebra", report_json(zebra, &cfg, dense)),
                                (
                                    "speedup",
                                    num(speedup(dense, zebra)),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        println!("{}", json::to_string(&doc));
        return Ok(());
    }

    for t in &profiles {
        println!("target {}", t.describe());
    }
    let mut table = Table::new(&[
        "target",
        "DRAM GB/s",
        "dense ms",
        "zebra ms",
        "speedup",
        "dense act",
        "zebra act",
        "reduction %",
        "mem-bound",
    ]);
    for (t, dense, zebra) in &rows {
        let cfg = t.accel_config();
        let bound =
            zebra.layers.iter().filter(|l| l.memory_bound).count();
        table.row(&[
            t.name.clone(),
            format!("{:.1}", t.dram_gbps),
            format!("{:.3}", dense.latency_ms(&cfg)),
            format!("{:.3}", zebra.latency_ms(&cfg)),
            format!("{:.2}x", speedup(dense, zebra)),
            fmt_bytes(dense.activation_bytes() as f64),
            fmt_bytes(zebra.activation_bytes() as f64),
            format!("{:.1}", zebra.reduction_vs(dense)),
            format!("{}/{}", bound, zebra.layers.len()),
        ]);
    }
    table.print(&format!(
        "Eq. 2-3 dense vs zero-block({block}) — {label} across {} targets",
        rows.len()
    ));
    print!("{}", telemetry.snapshot().report(Some("sim.model")));
    Ok(())
}

fn speedup(dense: &SimReport, zebra: &SimReport) -> f64 {
    dense.total_cycles as f64 / zebra.total_cycles.max(1) as f64
}

fn num(v: f64) -> Value {
    Value::Num(v)
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

/// The manifest, field for field (what `--json` consumers key off).
fn target_json(t: &TargetManifest) -> Value {
    obj(vec![
        ("name", Value::Str(t.name.clone())),
        ("description", Value::Str(t.description.clone())),
        ("dram_gbps", num(t.dram_gbps)),
        ("burst_bytes", num(t.burst_bytes as f64)),
        ("local_buffer_kib", num(t.local_buffer_kib as f64)),
        ("pe_rows", num(t.pe_rows as f64)),
        ("pe_cols", num(t.pe_cols as f64)),
        ("clock_mhz", num(t.clock_mhz)),
        (
            "int8_tops",
            t.int8_tops.map(Value::Num).unwrap_or(Value::Null),
        ),
        ("pj_per_mac", num(t.pj_per_mac)),
        ("pj_per_byte_dram", num(t.pj_per_byte_dram)),
        ("sustained_fraction", num(t.sustained_fraction)),
    ])
}

/// One codec's simulation outcome — the same fields as the printed
/// summary table.
fn report_json(r: &SimReport, cfg: &AccelConfig, dense: &SimReport) -> Value {
    let bound = r.layers.iter().filter(|l| l.memory_bound).count();
    obj(vec![
        ("codec", Value::Str(r.codec.clone())),
        ("act_bytes_per_img", num(r.activation_bytes() as f64)),
        ("cycles", num(r.total_cycles as f64)),
        ("latency_ms", num(r.latency_ms(cfg))),
        ("energy_uj", num(r.total_energy_pj / 1e6)),
        ("reduction_pct", num(r.reduction_vs(dense))),
        ("memory_bound_layers", num(bound as f64)),
        ("layers", num(r.layers.len() as f64)),
    ])
}

/// Eq. 2–3 accounting of the captured spills, through the same
/// `zero_block_accounting` path `zebra train`'s per-epoch evaluation
/// uses — the quantity training optimizes, printed here so
/// trained-vs-untrained runs are directly comparable.
fn zero_block_summary(
    spec: &crate::backend::reference::RefSpec,
    spills: &[Tensor],
    images: usize,
) -> String {
    let s = crate::zebra::bandwidth::zero_block_accounting(
        &spec.spills,
        spills,
    );
    // The report is already per image (kept fractions are
    // batch-invariant; shapes are per-map).
    format!(
        "zero blocks: {:.1}% ({} of {} across {} layers, {} images) | \
         Eq.2-3: required {}/img, stored {}/img, index {}/img -> \
         reduction {:.1}%",
        s.zero_pct,
        s.zero_blocks,
        s.total_blocks,
        spec.spills.len(),
        images,
        fmt_bytes(s.report.required_bytes),
        fmt_bytes(s.report.stored_bytes),
        fmt_bytes(s.report.overhead_bytes),
        s.report.reduced_pct()
    )
}

fn summary_table() -> Table {
    Table::new(&[
        "codec", "act bytes/img", "cycles", "latency ms", "energy uJ",
        "reduction %",
    ])
}

fn push_summary(
    t: &mut Table,
    cfg: &AccelConfig,
    r: &SimReport,
    dense: &SimReport,
) {
    t.row(&[
        r.codec.clone(),
        fmt_bytes(r.activation_bytes() as f64),
        r.total_cycles.to_string(),
        format!("{:.3}", r.latency_ms(cfg)),
        format!("{:.1}", r.total_energy_pj / 1e6),
        format!("{:.1}", r.reduction_vs(dense)),
    ]);
}

fn per_layer_table(r: &SimReport) -> Table {
    let mut t = Table::new(&[
        "layer", "compute cyc", "mem cyc", "bound", "act out", "util %",
    ]);
    for l in &r.layers {
        t.row(&[
            l.name.clone(),
            l.compute_cycles.to_string(),
            l.mem_cycles.to_string(),
            if l.memory_bound { "MEM" } else { "PE" }.to_string(),
            fmt_bytes(l.act_bytes_out as f64),
            format!("{:.0}", 100.0 * l.utilization),
        ]);
    }
    t
}
