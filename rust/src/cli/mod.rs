//! Hand-rolled CLI (clap is not in the offline vendor set).
//!
//! ```text
//! zebra version
//! zebra serve    --model rn18-c10-t0.1 --requests 64 [--wait-ms 2]
//! zebra simulate --trace artifacts/traces/rn18-c10-t0.2 [--codec zero-block]
//! zebra analyze  --trace artifacts/traces/rn18-c10-off
//! zebra table5   [--dataset cifar10|tiny]
//! ```

mod analyze;
mod cluster;
mod loadgen;
mod obs;
pub mod opts;
pub mod serve;
mod simulate;
mod top;
mod train;

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: subcommand + `--key value` flags (`--flag` with
/// no value stores "true").
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            args.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {a:?}"))?;
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    it.next().unwrap().clone()
                }
                _ => "true".to_string(),
            };
            args.flags.insert(key.to_string(), val);
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} wants an integer, got {v}")),
        }
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} wants a number, got {v}")),
        }
    }
}

const USAGE: &str = "zebra <command> [--flags]
commands:
  version                     print version
  train     --model KEY       native Zebra training (pure Rust): learn
                              block-prunable activations with
                              CE + lambda*sum||block|| and checkpoint
                              .zten leaves the reference backend serves
            [--lambda L] [--block B] [--t-obj T] [--steps N] [--batch N]
            [--lr LR] [--momentum M] [--weight-decay WD] [--seed S]
            [--train-n N] [--holdout N] [--eval-every N]
            [--threads N]     eval-backend conv threads (ZEBRA_THREADS)
            [--images F.zten --labels F.zten]  train on exported data
            [--out DIR]                        write w%05d.zten leaves
  serve     --model KEY       run the serving pipeline over the test set
            [--backend reference|pjrt]  execution engine (default: pjrt
                                        when built with --features pjrt,
                                        else reference)
            [--weights DIR]   reference weights dir (trained leaves)
            [--threads N]     conv worker threads for the block-sparse
                              engine (default: ZEBRA_THREADS or 1;
                              results are bitwise-identical)
            [--seed S]        synthetic test-set seed
            [--requests N] [--queue N] [--priority low|normal|high|mixed]
            [--flush-us US]   batch flush window (legacy: --wait-ms MS)
            [--max-batch N]   per-batch real-item cap (0 = backend max;
                              shrinks further under observed load)
            [--ship-codec NAME [--ship-block B]]  frame batches as .zspill
            [--trace-sample N]  trace 1-in-N requests (deterministic
                                from the trace id; 1 = every request)
            [--flight-dir DIR]  dump the flight-recorder ring as
                                JSON-lines on terminal events + exit
            [--slo NAME=T,..]  SLO objectives for the burn-rate engine
                              (shed-rate / deadline-miss fractions,
                              p99-latency us, savings-floor fraction);
                              breaches hit the flight recorder and
                              export as zebra_slo_breach
            [--brownout max=L,raise=N,lower=M]  let sustained SLO burn
                              shed load: each level shrinks low/normal
                              admission caps and thins trace sampling
            [--chaos SPEC]    deterministic fault injection, replayable
                              by seed (ZEBRA_CHAOS also works; the flag
                              wins): seed=N, wire.drop=P,
                              wire.delay=US@P, wire.corrupt=K@P,
                              wire.truncate=P, worker.stall=US@P,
                              worker.slow=M@P, worker.crash_after=N,
                              spill.corrupt=P
                              (see rust/docs/robustness.md)
            [--io-timeout-ms MS]  read/connect bound on every cluster
                              socket (default 30000; 0 = unbounded)
            [--port P]        expose the server over TCP instead of
                              replaying (0 = ephemeral; prints the
                              bound address) [--host H] [--run-s N]
  cluster-worker              serve as a cluster worker node (same
                              backend/model/ship/batching/--threads
                              flags as serve; thread counts surface in
                              the cluster metrics snapshot)
            [--flush-us US] [--max-batch N] [--queue N]
            [--port P] [--host H] [--run-s N]
            [--ship-upstream HOST:PORT]  ship .zspill batch frames to
                                         the router
            [--flight-dir DIR] [--slo NAME=T,...]
            [--brownout max=L,raise=N,lower=M] [--chaos SPEC]
            [--io-timeout-ms MS]
  cluster-router --workers HOST:P1,HOST:P2[,...]
            [--mode rr|hash]  round-robin or consistent-hash-by-key
            [--max-outstanding N] [--max-attempts N] [--heartbeat-ms MS]
            [--flight-dir DIR] [--slo NAME=T,...]
            [--brownout max=L,raise=N,lower=M] [--chaos SPEC]
            [--io-timeout-ms MS]
            [--breaker-threshold N]  consecutive worker failures before
                              the per-worker circuit breaker opens
                              (default 3)
            [--breaker-probe-ms MS]  open-state probe interval before a
                              half-open redial (default 1000; backoff
                              doubles it per reopen)
            [--request-timeout-ms MS]  re-dispatch in-flight requests
                              stuck on a worker longer than this
                              (default 10000; 0 = never)
            [--port P] [--host H] [--run-s N]
  loadgen   --addr HOST:PORT  drive a router at a target rate; prints
                              p50/p95/p99 latency + per-class
                              ok/shed/failed + cluster zero-block
                              bandwidth savings
            [--requests N] [--qps Q] [--hw H] [--seed S]
            [--conns N]       concurrent client connections
            [--priority low|normal|high|mixed]  request class (mixed
                              cycles all three)
            [--keys N]        spread requests over N shard keys
                              (0 = one key per request)
            [--deadline-us US]  per-request completion deadline
            [--images F.zten]
            [--expect-sheds]  error unless admission control shed >= 1
                              request (overload smoke tests)
            [--fail-on-error] error on faults (sheds are not faults)
            [--trace-sample N]  assign trace ids at the edge, sample
                                1-in-N, report span coverage of the
                                client-observed wall
            [--scrape-ms MS]  poll the live obs report on a side
                              connection while the run is in flight
            [--bench-json]    write BENCH_PR9.json (machine-readable
                              run report + per-layer bandwidth ledger
                              + SLO breach counts; ZEBRA_BENCH_OUT
                              overrides the path and also enables this)
  obs       --addr HOST:PORT  scrape one unified observability report
                              (cluster counters + latency + Eq. 2-3
                              bandwidth + merged telemetry stages) as
                              Prometheus text [--json for JSON]
  obs replay FILE.jsonl       render a flight-recorder dump: one
                              waterfall per sampled trace + terminal
                              events (shed / deadline-miss / ...)
  top       --addr HOST:PORT  refresh-in-place live dashboard over the
                              obs scrape: cluster summary, SLO breach
                              banners, per-worker queue/shed table,
                              bandwidth ledger with zero-block trend
                              sparklines
            [--interval-ms MS]  redraw period (default 500)
            [--frames N]      exit after N redraws (0 = run forever)
            [--json]          one scrape as JSON, then exit
  simulate  --trace DIR       accelerator simulation of a trace
            | --backend reference [--model KEY] [--images N]
                                  [--weights DIR] [--seed S]
                                  [--threads N]
                                  simulate natively-executed spills
            [--codec dense|whole-map|rle-zero|zero-block] [--all]
            [--target FILE|NAME]  hardware profile (.target manifest or
                                  a builtin name; default: default —
                                  see rust/docs/targets.md)
            [--json]          machine-readable report on stdout
  targets                     sweep ONE model across every builtin
                              hardware profile: per-target dense vs
                              zero-block Eq.2-3 bandwidth/latency table
                              (same input flags as simulate, plus
                              [--json])
  analyze   --trace DIR       sparsity + Eq.2-3 bandwidth analysis
  table5    [--dataset cifar10|tiny]   static Table V arithmetic
";

/// CLI entry point (called by `main`).
pub fn run(argv: &[String]) -> Result<()> {
    // `obs` owns its argv: `obs replay FILE` is the CLI's one
    // positional form, which the standard parser rejects.
    if argv.first().map(String::as_str) == Some("obs") {
        return obs::run(argv);
    }
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "" | "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        "version" => {
            println!("zebra {}", crate::version());
            Ok(())
        }
        "train" => train::run(&args),
        "serve" => serve::run(&args),
        "cluster-worker" => cluster::run_worker(&args),
        "cluster-router" => cluster::run_router(&args),
        "loadgen" => loadgen::run(&args),
        "top" => top::run(&args),
        "simulate" => simulate::run(&args),
        "targets" => simulate::targets(&args),
        "analyze" => analyze::run(&args),
        "table5" => analyze::table5(&args),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_bare_switches() {
        let a =
            Args::parse(&v(&["serve", "--model", "rn18", "--fast"])).unwrap();
        assert_eq!(a.command, "serve");
        assert_eq!(a.get("model"), Some("rn18"));
        assert_eq!(a.get("fast"), Some("true"));
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn rejects_positional_garbage() {
        assert!(Args::parse(&v(&["serve", "oops"])).is_err());
    }

    #[test]
    fn numeric_flags_validate() {
        let a = Args::parse(&v(&["serve", "--requests", "12"])).unwrap();
        assert_eq!(a.get_usize("requests", 1).unwrap(), 12);
        let b = Args::parse(&v(&["serve", "--requests", "xy"])).unwrap();
        assert!(b.get_usize("requests", 1).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&v(&["frobnicate"])).is_err());
        assert!(run(&v(&["version"])).is_ok());
    }

    #[test]
    fn float_flags_validate() {
        let a = Args::parse(&v(&["train", "--lambda", "1e-4"])).unwrap();
        assert!((a.get_f32("lambda", 0.0).unwrap() - 1e-4).abs() < 1e-10);
        assert_eq!(a.get_f32("missing", 0.5).unwrap(), 0.5);
        let b = Args::parse(&v(&["train", "--lambda", "much"])).unwrap();
        assert!(b.get_f32("lambda", 0.0).is_err());
    }

    #[test]
    fn train_rejects_half_specified_datasets_and_bad_models() {
        let e = run(&v(&["train", "--images", "x.zten"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--labels"), "{e}");
        assert!(run(&v(&["train", "--model", "nope-c10-t0.1"])).is_err());
        // A non-dividing block override fails loudly before training.
        assert!(run(&v(&[
            "train", "--model", "ref-tiny", "--block", "3", "--steps", "1"
        ]))
        .is_err());
    }

    #[test]
    fn backend_flag_parses_through_args() {
        use crate::backend::BackendKind;
        let a = Args::parse(&v(&["serve", "--backend", "reference"])).unwrap();
        assert_eq!(
            BackendKind::parse(a.get("backend").unwrap()).unwrap(),
            BackendKind::Reference
        );
        let a = Args::parse(&v(&["serve", "--backend", "pjrt"])).unwrap();
        assert_eq!(
            BackendKind::parse(a.get("backend").unwrap()).unwrap(),
            BackendKind::Pjrt
        );
        // Default (flag absent) resolves to this build's default.
        let a = Args::parse(&v(&["serve"])).unwrap();
        let d = a.get_or("backend", BackendKind::default_name());
        assert!(BackendKind::parse(&d).is_ok());
        // Bad values error with the valid list.
        let a = Args::parse(&v(&["serve", "--backend", "tpu"])).unwrap();
        assert!(BackendKind::parse(a.get("backend").unwrap()).is_err());
    }

    #[test]
    fn simulate_without_inputs_is_an_error() {
        let e = run(&v(&["simulate"])).unwrap_err().to_string();
        assert!(e.contains("--trace") && e.contains("--backend"), "{e}");
    }

    #[test]
    fn simulate_rejects_unknown_targets_before_running_anything() {
        // Fail-fast: a bad --target errors (listing the builtin names)
        // even though the input flags are also missing — the target is
        // resolved first, before any model execution.
        let e = run(&v(&["simulate", "--target", "warp-core"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("warp-core"), "{e}");
        assert!(e.contains("edge-npu") && e.contains("datacenter-hbm"), "{e}");
    }

    #[test]
    fn targets_sweep_rejects_a_single_target_flag() {
        let e = run(&v(&["targets", "--target", "edge-npu"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("simulate"), "{e}");
        // And without inputs it reports the same missing-input error
        // simulate does (profiles load fine; inputs are the gap).
        let e = run(&v(&["targets"])).unwrap_err().to_string();
        assert!(e.contains("--trace") && e.contains("--backend"), "{e}");
    }

    #[test]
    fn cluster_router_validates_its_flags() {
        // --workers is mandatory and must list addresses.
        let e = run(&v(&["cluster-router"])).unwrap_err().to_string();
        assert!(e.contains("--workers"), "{e}");
        let e = run(&v(&["cluster-router", "--workers", " , "]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("no usable addresses"), "{e}");
        // Bad shard modes error with the valid list before binding.
        let e = run(&v(&[
            "cluster-router",
            "--workers",
            "127.0.0.1:1",
            "--mode",
            "zigzag",
        ]))
        .unwrap_err()
        .to_string();
        assert!(e.contains("rr") && e.contains("hash"), "{e}");
    }

    #[test]
    fn cluster_worker_validates_its_flags() {
        // Upstream shipping without a ship codec is a config error
        // (run-s 1 would exit immediately even if it started).
        let e = run(&v(&[
            "cluster-worker",
            "--backend",
            "reference",
            "--model",
            "ref-tiny",
            "--ship-upstream",
            "127.0.0.1:1",
            "--run-s",
            "1",
        ]))
        .unwrap_err()
        .to_string();
        assert!(e.contains("ship"), "{e}");
        // A ship block that does not divide the image errors.
        let e = run(&v(&[
            "cluster-worker",
            "--backend",
            "reference",
            "--model",
            "ref-tiny",
            "--ship-codec",
            "zero-block",
            "--ship-block",
            "3",
            "--run-s",
            "1",
        ]))
        .unwrap_err()
        .to_string();
        assert!(e.contains("divide"), "{e}");
        // Bad model keys fail before any listener binds.
        assert!(run(&v(&[
            "cluster-worker",
            "--backend",
            "reference",
            "--model",
            "nope",
        ]))
        .is_err());
    }

    #[test]
    fn serving_flags_validate_in_one_place() {
        // ServeOpts is the one shared flag surface: the same
        // conflict/value checks fire for every serving entry point,
        // before any executor is built or socket touched.
        let e = run(&v(&["serve", "--flush-us", "5", "--wait-ms", "2"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("same knob"), "{e}");
        let e = run(&v(&["loadgen", "--addr", "x", "--priority", "urgent"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("mixed"), "{e}");
        let e = run(&v(&["serve", "--queue", "0"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--queue"), "{e}");
        let e = run(&v(&["serve", "--flush-us", "0"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--flush-us"), "{e}");
    }

    #[test]
    fn chaos_and_brownout_flags_validate_before_serving() {
        // The shared flag surface rejects malformed chaos specs for
        // every serving entry point, before any executor or socket.
        let e = run(&v(&["serve", "--chaos", "wire.drop=nope"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("wire.drop"), "{e}");
        let e = run(&v(&["cluster-worker", "--chaos", "frob=1"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("frob"), "{e}");
        let e = run(&v(&["cluster-router", "--brownout", "max=0"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--brownout"), "{e}");
        let e = run(&v(&["serve", "--io-timeout-ms", "soon"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("io-timeout-ms"), "{e}");
    }

    #[test]
    fn obs_validates_its_forms() {
        // Live scrape needs an address (and suggests the replay form).
        let e = run(&v(&["obs"])).unwrap_err().to_string();
        assert!(e.contains("--addr") && e.contains("replay"), "{e}");
        // Replay wants exactly one file operand.
        let e = run(&v(&["obs", "replay"])).unwrap_err().to_string();
        assert!(e.contains("usage"), "{e}");
        let e = run(&v(&["obs", "replay", "a", "b"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("usage"), "{e}");
        // A missing dump file errors with its path.
        let e = run(&v(&["obs", "replay", "/no/such/flight.jsonl"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("flight.jsonl"), "{e}");
        // A valid dump replays: one trace waterfall + one event line.
        let dir = std::env::temp_dir()
            .join(format!("zebra-obs-cli-{}", std::process::id()));
        let f = crate::obs::FlightRecorder::new(
            "cli",
            8,
            Some(dir.clone()),
        );
        let mut rec = crate::obs::TraceRecord::new(77);
        rec.push("serve.execute", 100, 900, 0, 2);
        f.record_trace(rec);
        f.record_event(
            77,
            crate::obs::TerminalKind::ShedLow,
            "over cap",
        );
        let path = f.dump().unwrap().unwrap();
        run(&v(&["obs", "replay", path.to_str().unwrap()])).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn top_validates_its_flags() {
        let e = run(&v(&["top"])).unwrap_err().to_string();
        assert!(e.contains("--addr"), "{e}");
        // Interval validation fires before any socket is touched (and
        // before the redraw loop could spin).
        let e = run(&v(&["top", "--addr", "x", "--interval-ms", "0"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--interval-ms"), "{e}");
    }

    #[test]
    fn loadgen_requires_an_address() {
        let e = run(&v(&["loadgen"])).unwrap_err().to_string();
        assert!(e.contains("--addr"), "{e}");
        let e = run(&v(&["loadgen", "--addr", "x", "--requests", "0"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--requests"), "{e}");
    }
}
