//! Shared serving-flag surface.
//!
//! Every serving entry point — `zebra serve`, `zebra cluster-worker`,
//! `zebra cluster-router`, `zebra loadgen` — parses the same knobs
//! through [`ServeOpts`], so a new flag (`--max-batch`, `--flush-us`,
//! `--priority`, ...) lands in exactly one place and is covered by one
//! test instead of a copy per subcommand. Backend selection
//! (`--backend`/`--model`/`--weights`/`--threads`) stays in
//! `serve::build_executor`, which is already the one shared builder
//! for it.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Result};

use super::Args;
use crate::compress;
use crate::coordinator::{Priority, ServerConfig, ShipSpills};
use crate::faults::{FaultInjector, FaultPlan};
use crate::obs::flight::FLIGHT_CAPACITY;
use crate::obs::{BrownoutConfig, FlightRecorder, SloConfig};

/// `--priority low|normal|high|mixed`: one fixed class for every
/// request, or (loadgen) a deterministic low/normal/high cycle that
/// exercises all three admission tiers in one run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PriorityMix {
    Fixed(Priority),
    Mixed,
}

impl PriorityMix {
    pub fn parse(s: &str) -> Result<PriorityMix> {
        if s == "mixed" {
            return Ok(PriorityMix::Mixed);
        }
        // Priority::parse's error lists low|normal|high; point at the
        // extra loadgen-only value too.
        Priority::parse(s)
            .map(PriorityMix::Fixed)
            .map_err(|_| {
                anyhow::anyhow!(
                    "unknown priority {s:?} (low|normal|high|mixed)"
                )
            })
    }

    /// Class of the i-th request under this mix.
    pub fn for_request(&self, i: usize) -> Priority {
        match self {
            PriorityMix::Fixed(p) => *p,
            PriorityMix::Mixed => Priority::ALL[i % Priority::ALL.len()],
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PriorityMix::Fixed(p) => p.name(),
            PriorityMix::Mixed => "mixed",
        }
    }
}

/// The parsed serving knobs (defaults match the flags' documented
/// defaults; see `zebra help`).
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Batch flush window (`--flush-us`, or the legacy `--wait-ms`).
    pub flush: Duration,
    /// Admission queue capacity (`--queue`); the per-class caps are
    /// cut from this.
    pub queue: usize,
    /// Per-batch real-item cap (`--max-batch`; 0 = backend's largest
    /// exported batch size).
    pub max_batch: usize,
    /// `--ship-codec NAME`: frame executed batches as `.zspill`.
    pub ship_codec: Option<String>,
    /// `--ship-block B`: block geometry for the ship codec.
    pub ship_block: usize,
    /// `--host H` bind host.
    pub host: String,
    /// `--port P`; `Some(0)` = ephemeral. `None` means the flag was
    /// absent — `zebra serve` then replays instead of listening.
    pub port: Option<u16>,
    /// `--run-s N`: exit after N seconds (0 = run until killed).
    pub run_s: u64,
    /// `--priority low|normal|high|mixed` (client-side class choice).
    pub priority: PriorityMix,
    /// `--trace-sample N`: trace 1-in-N requests (0 = tracing off,
    /// 1 = every request). Sampling is deterministic from the trace id
    /// ([`crate::obs::sampled`]), so every node agrees.
    pub trace_sample: usize,
    /// `--flight-dir DIR`: terminal events (sheds, deadline misses,
    /// worker deaths) dump the node's flight ring here as JSON-lines.
    pub flight_dir: Option<PathBuf>,
    /// `--slo name=threshold,...`: overrides on the default objective
    /// set (shed-rate, deadline-miss, p99-latency-us, savings-floor).
    /// The engine always runs; the defaults are lenient enough to stay
    /// silent on a healthy node. `--brownout max=L,raise=N,lower=M`
    /// lands in `slo.brownout` (sustained burn then sheds load).
    pub slo: SloConfig,
    /// `--chaos SPEC` (or `ZEBRA_CHAOS`; the flag wins): deterministic
    /// fault injector shared by every site on this node. `None` when
    /// no chaos is requested or the plan has no active faults.
    pub faults: Option<Arc<FaultInjector>>,
    /// `--io-timeout-ms N`: read/connect bound on every cluster
    /// socket (0 = no bound, the pre-PR-10 behaviour).
    pub io_timeout: Option<Duration>,
}

impl ServeOpts {
    pub fn from_args(args: &Args) -> Result<ServeOpts> {
        let flush = match (args.get("flush-us"), args.get("wait-ms")) {
            (Some(_), Some(_)) => bail!(
                "--flush-us and --wait-ms are the same knob (batch \
                 flush window); pass one"
            ),
            (Some(_), None) => {
                let us = args.get_usize("flush-us", 2000)?;
                ensure!(us > 0, "--flush-us must be positive");
                Duration::from_micros(us as u64)
            }
            (None, _) => {
                Duration::from_millis(args.get_usize("wait-ms", 2)? as u64)
            }
        };
        let queue = args.get_usize("queue", 1024)?;
        ensure!(queue > 0, "--queue must be positive");
        let max_batch = args.get_usize("max-batch", 0)?;
        let ship_codec = args.get("ship-codec").map(String::from);
        let ship_block = args.get_usize("ship-block", 4)?;
        ensure!(
            ship_block <= u16::MAX as usize,
            "--ship-block {ship_block} is out of range"
        );
        let host = args.get_or("host", "127.0.0.1");
        let port = match args.get("port") {
            None => None,
            Some(_) => {
                let p = args.get_usize("port", 0)?;
                ensure!(
                    p <= u16::MAX as usize,
                    "--port {p} out of range"
                );
                Some(p as u16)
            }
        };
        let run_s = args.get_usize("run-s", 0)? as u64;
        let priority =
            PriorityMix::parse(&args.get_or("priority", "normal"))?;
        let trace_sample = args.get_usize("trace-sample", 0)?;
        let flight_dir = args.get("flight-dir").map(PathBuf::from);
        let mut slo = SloConfig::parse_overrides(&args.get_or("slo", ""))?;
        if let Some(spec) = args.get("brownout") {
            slo.brownout = Some(BrownoutConfig::parse(spec)?);
        }
        let plan = match args.get("chaos") {
            Some(spec) => Some(FaultPlan::parse(spec)?),
            None => FaultPlan::from_env()?,
        };
        let faults = plan.filter(FaultPlan::is_active).map(FaultInjector::new);
        let io_ms = args.get_usize("io-timeout-ms", 30_000)?;
        let io_timeout =
            (io_ms > 0).then(|| Duration::from_millis(io_ms as u64));
        Ok(ServeOpts {
            flush,
            queue,
            max_batch,
            ship_codec,
            ship_block,
            host,
            port,
            run_s,
            priority,
            trace_sample,
            flight_dir,
            slo,
            faults,
            io_timeout,
        })
    }

    /// The node's flight recorder: present whenever tracing or a dump
    /// directory is on (an in-memory ring is still useful for tests
    /// and the exit-time view; it only writes when `--flight-dir` is
    /// set). `node` names the dump file (`flight-<node>.jsonl`).
    pub fn flight_recorder(&self, node: &str) -> Option<Arc<FlightRecorder>> {
        if self.flight_dir.is_none() && self.trace_sample == 0 {
            return None;
        }
        Some(Arc::new(FlightRecorder::new(
            node,
            FLIGHT_CAPACITY,
            self.flight_dir.clone(),
        )))
    }

    /// The coordinator config these flags describe. `image_hw` is the
    /// executor's image size (the ship codec's block must divide it).
    pub fn server_config(&self, image_hw: usize) -> Result<ServerConfig> {
        Ok(ServerConfig {
            max_wait: self.flush,
            workers: 1,
            max_queue: self.queue,
            max_batch: self.max_batch,
            ship_spills: self.ship_spills(image_hw)?,
            spill_sink: None,
            flight: None,
            // The observability planes are attached by the entry
            // points: the ledger must be the one the executor was
            // built with, and the SLO engine wants the node's flight
            // recorder.
            ledger: None,
            slo: None,
            faults: self.faults.clone(),
            io_timeout: self.io_timeout,
        })
    }

    /// Resolve `--ship-codec`/`--ship-block` against the codec
    /// registry and the model's image geometry (CLI error instead of
    /// a `Server::start` assert).
    pub fn ship_spills(&self, image_hw: usize) -> Result<Option<ShipSpills>> {
        let Some(name) = &self.ship_codec else {
            return Ok(None);
        };
        let spec = compress::spec_or_err(name)?;
        if spec.needs_block {
            ensure!(
                self.ship_block > 0 && image_hw % self.ship_block == 0,
                "--ship-block {} must be positive and divide the \
                 {image_hw}px image",
                self.ship_block
            );
        }
        Ok(Some(ShipSpills {
            codec: spec.id,
            block: self.ship_block as u16,
        }))
    }

    /// `--host`/`--port` as a bind address (`--port 0` or no port =
    /// ask the OS; the node prints what it got).
    pub fn listen_addr(&self) -> String {
        format!("{}:{}", self.host, self.port.unwrap_or(0))
    }

    /// Block for `--run-s` seconds (0 = until the process is killed).
    pub fn hold(&self) {
        if self.run_s == 0 {
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        std::thread::sleep(Duration::from_secs(self.run_s));
    }

    /// [`ServeOpts::hold`] that doubles as the node's SLO sampling
    /// loop: `tick` runs about once a second with milliseconds since
    /// the hold began (a monotonic origin — the SLO engine never sees
    /// the wall clock).
    pub fn hold_sampling(&self, mut tick: impl FnMut(u64)) {
        let t0 = std::time::Instant::now();
        loop {
            std::thread::sleep(Duration::from_millis(1000));
            let elapsed = t0.elapsed();
            tick(elapsed.as_millis() as u64);
            if self.run_s > 0 && elapsed >= Duration::from_secs(self.run_s) {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        let mut v = vec!["serve".to_string()];
        v.extend(s.iter().map(|x| x.to_string()));
        Args::parse(&v).unwrap()
    }

    #[test]
    fn defaults_match_the_documented_flags() {
        let o = ServeOpts::from_args(&parse(&[])).unwrap();
        assert_eq!(o.flush, Duration::from_millis(2));
        assert_eq!(o.queue, 1024);
        assert_eq!(o.max_batch, 0);
        assert_eq!(o.ship_codec, None);
        assert_eq!(o.ship_block, 4);
        assert_eq!(o.port, None);
        assert_eq!(o.run_s, 0);
        assert_eq!(o.priority, PriorityMix::Fixed(Priority::Normal));
        assert_eq!(o.trace_sample, 0);
        assert_eq!(o.flight_dir, None);
        assert_eq!(o.slo, SloConfig::default());
        assert!(o.faults.is_none());
        assert_eq!(o.io_timeout, Some(Duration::from_secs(30)));
        assert!(o.flight_recorder("node").is_none());
        assert_eq!(o.listen_addr(), "127.0.0.1:0");
        let cfg = o.server_config(8).unwrap();
        assert_eq!(cfg.max_queue, 1024);
        assert_eq!(cfg.max_batch, 0);
        assert!(cfg.ship_spills.is_none());
        assert!(cfg.faults.is_none());
        assert_eq!(cfg.io_timeout, Some(Duration::from_secs(30)));
    }

    #[test]
    fn every_flag_lands_in_one_place() {
        let o = ServeOpts::from_args(&parse(&[
            "--flush-us", "750", "--queue", "64", "--max-batch", "4",
            "--ship-codec", "zero-block", "--ship-block", "8",
            "--host", "0.0.0.0", "--port", "9000", "--run-s", "3",
            "--priority", "high", "--trace-sample", "4",
            "--flight-dir", "/tmp/zebra-flight",
            "--chaos", "seed=7,wire.drop=0.25",
            "--io-timeout-ms", "5000",
            "--brownout", "max=2,raise=2,lower=4",
        ]))
        .unwrap();
        assert_eq!(o.flush, Duration::from_micros(750));
        assert_eq!(o.queue, 64);
        assert_eq!(o.max_batch, 4);
        assert_eq!(o.ship_block, 8);
        assert_eq!(o.port, Some(9000));
        assert_eq!(o.run_s, 3);
        assert_eq!(o.listen_addr(), "0.0.0.0:9000");
        assert_eq!(o.priority, PriorityMix::Fixed(Priority::High));
        assert_eq!(o.trace_sample, 4);
        assert_eq!(
            o.flight_dir.as_deref(),
            Some(std::path::Path::new("/tmp/zebra-flight"))
        );
        let fi = o.faults.as_ref().expect("chaos plan parsed");
        assert_eq!(fi.plan().seed, 7);
        assert!(fi.active());
        assert_eq!(o.io_timeout, Some(Duration::from_millis(5000)));
        let bo = o.slo.brownout.as_ref().expect("brownout policy parsed");
        assert_eq!((bo.max_level, bo.raise_after, bo.lower_after), (2, 2, 4));
        // A recorder exists (tracing on) but only writes when dumped.
        assert!(o.flight_recorder("node").is_some());
        let cfg = o.server_config(8).unwrap();
        assert_eq!(cfg.max_wait, Duration::from_micros(750));
        assert_eq!(cfg.max_batch, 4);
        let ship = cfg.ship_spills.expect("ship codec resolved");
        assert_eq!(ship.block, 8);
    }

    #[test]
    fn legacy_wait_ms_still_works_but_not_both() {
        let o = ServeOpts::from_args(&parse(&["--wait-ms", "5"])).unwrap();
        assert_eq!(o.flush, Duration::from_millis(5));
        let e = ServeOpts::from_args(&parse(&[
            "--wait-ms", "5", "--flush-us", "100",
        ]))
        .unwrap_err()
        .to_string();
        assert!(e.contains("same knob"), "{e}");
    }

    #[test]
    fn invalid_values_error_loudly() {
        assert!(ServeOpts::from_args(&parse(&["--flush-us", "0"])).is_err());
        assert!(ServeOpts::from_args(&parse(&["--queue", "0"])).is_err());
        assert!(ServeOpts::from_args(&parse(&["--port", "70000"])).is_err());
        let e = ServeOpts::from_args(&parse(&["--priority", "urgent"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("mixed"), "{e}");
        // Ship geometry that cannot tile the image errors at parse
        // time, not inside Server::start.
        let o = ServeOpts::from_args(&parse(&[
            "--ship-codec", "zero-block", "--ship-block", "3",
        ]))
        .unwrap();
        let e = o.ship_spills(8).unwrap_err().to_string();
        assert!(e.contains("divide"), "{e}");
        // Unknown ship codecs list the registry.
        let o = ServeOpts::from_args(&parse(&["--ship-codec", "nope"]))
            .unwrap();
        assert!(o.ship_spills(8).is_err());
    }

    #[test]
    fn slo_overrides_parse_through_the_shared_surface() {
        let o = ServeOpts::from_args(&parse(&["--slo", "shed-rate=0.1"]))
            .unwrap();
        let obj = o
            .slo
            .objectives
            .iter()
            .find(|x| x.name == "shed-rate")
            .unwrap();
        assert!((obj.threshold - 0.1).abs() < 1e-12);
        // Unknown objective names fail the whole flag parse, loudly.
        let e = ServeOpts::from_args(&parse(&["--slo", "nope=1"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("shed-rate"), "{e}");
    }

    #[test]
    fn mixed_priority_cycles_all_three_classes() {
        let m = PriorityMix::parse("mixed").unwrap();
        assert_eq!(m.name(), "mixed");
        assert_eq!(m.for_request(0), Priority::Low);
        assert_eq!(m.for_request(1), Priority::Normal);
        assert_eq!(m.for_request(2), Priority::High);
        assert_eq!(m.for_request(3), Priority::Low);
        let f = PriorityMix::parse("low").unwrap();
        assert_eq!(f.for_request(7), Priority::Low);
    }
}
