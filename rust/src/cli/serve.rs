//! `zebra serve` — run the full serving pipeline: start the
//! coordinator over the selected backend (`--backend reference|pjrt`),
//! replay the exported test set (or a synthetic one when no artifacts
//! exist) as requests, and print latency/throughput/bandwidth metrics.
//!
//! With `--port` the same server is exposed over TCP instead of
//! replayed against: `zebra serve --port 0` is a single-node network
//! front (it prints the bound address), and `zebra cluster-worker` is
//! this plus upstream spill shipping.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::Args;
use crate::backend::reference::RefSpec;
use crate::backend::{synth_images, synth_labels, testset_matches, BackendKind};
use crate::compress;
use crate::coordinator::server::BatchExecutor;
use crate::coordinator::{reference_executor, Server, ServerConfig, ShipSpills};
use crate::tensor::{read_zten, read_zten_i32, Tensor};

pub fn run(args: &Args) -> Result<()> {
    run_with(args, crate::artifacts_dir())
}

/// Build the `--backend`/`--model`/`--weights` executor the way every
/// serving entry point (serve, cluster-worker) does. Returns the
/// executor, the class count when known statically (reference backend
/// only — it gates the synthetic-test-set fallback), and the resolved
/// backend kind.
pub(crate) fn build_executor(
    args: &Args,
    artifacts: &std::path::Path,
) -> Result<(Arc<dyn BatchExecutor>, Option<usize>, BackendKind)> {
    let backend = BackendKind::parse(
        &args.get_or("backend", BackendKind::default_name()),
    )?;
    let model = args.get_or("model", "rn18-c10-t0.1");
    let weights = args.get("weights").map(std::path::PathBuf::from);
    if weights.is_some() && backend != BackendKind::Reference {
        anyhow::bail!("--weights only applies to --backend reference");
    }
    // Conv worker threads for the block-sparse engine (0 = leave it to
    // ZEBRA_THREADS / single-threaded; results are identical either way).
    let threads = args.get_usize("threads", 0)?;
    if threads > 0 && backend != BackendKind::Reference {
        anyhow::bail!("--threads only applies to --backend reference");
    }
    let (exec, classes): (Arc<dyn BatchExecutor>, Option<usize>) = match backend
    {
        BackendKind::Reference => {
            let mut spec = RefSpec::from_key(&model)?;
            spec.threads = threads;
            // Trained `.zten` leaves override the deterministic
            // weights: an explicit --weights DIR (e.g. fresh out of
            // `zebra train --out DIR`) wins over the artifacts probe.
            if let Some(dir) = weights {
                anyhow::ensure!(
                    dir.is_dir(),
                    "--weights {dir:?} is not a directory"
                );
                // Explicit --weights must be a complete checkpoint —
                // no silent per-leaf fallback to generated weights.
                crate::backend::reference::check_complete_leaves(
                    &spec, &dir,
                )?;
                println!("loading reference weights from {dir:?}");
                spec.weights_dir = Some(dir);
            } else {
                let wdir = artifacts.join("ref-weights").join(&model);
                if wdir.is_dir() {
                    println!("loading reference weights from {wdir:?}");
                    spec.weights_dir = Some(wdir);
                }
            }
            let classes = spec.classes;
            (Arc::new(reference_executor(spec)?), Some(classes))
        }
        BackendKind::Pjrt => {
            #[cfg(feature = "pjrt")]
            {
                println!("loading PJRT runtime from {artifacts:?} ...");
                let e = crate::coordinator::pjrt_executor(
                    artifacts.to_path_buf(),
                    &model,
                )?;
                (Arc::new(e), None)
            }
            #[cfg(not(feature = "pjrt"))]
            {
                anyhow::bail!(
                    "this zebra binary was built without the `pjrt` \
                     feature; rebuild with `cargo build --features pjrt` \
                     or use --backend reference"
                );
            }
        }
    };
    Ok((exec, classes, backend))
}

/// Resolve `--ship-codec`/`--ship-block` against the registry and the
/// model's image geometry (shared by serve and the cluster worker).
pub(crate) fn ship_config(
    args: &Args,
    image_hw: usize,
) -> Result<Option<ShipSpills>> {
    let Some(name) = args.get("ship-codec") else {
        return Ok(None);
    };
    let spec = compress::spec_or_err(name)?;
    let block = args.get_usize("ship-block", 4)?;
    anyhow::ensure!(
        block <= u16::MAX as usize,
        "--ship-block {block} is out of range"
    );
    if spec.needs_block {
        anyhow::ensure!(
            block > 0 && image_hw % block == 0,
            "--ship-block {block} must be positive and divide the \
             {image_hw}px image"
        );
    }
    Ok(Some(ShipSpills { codec: spec.id, block: block as u16 }))
}

/// `serve` with an explicit artifacts directory (tests inject a temp
/// dir here instead of mutating `ZEBRA_ARTIFACTS`).
pub fn run_with(args: &Args, artifacts: std::path::PathBuf) -> Result<()> {
    let model = args.get_or("model", "rn18-c10-t0.1");
    let n_requests = args.get_usize("requests", 64)?;
    let wait_ms = args.get_usize("wait-ms", 2)? as u64;
    let queue = args.get_usize("queue", 1024)?;
    // Synthetic-test-set seed: reproducible by default, varied on
    // demand (`--seed`).
    let synth_seed = args.get_usize("seed", 0xB1A5)? as u64;

    let t0 = Instant::now();
    let (exec, classes, backend) = build_executor(args, &artifacts)?;
    println!(
        "backend {} | model {} | batches {:?} | threads {} | ready in {:.1}s",
        backend.name(),
        model,
        exec.batch_sizes(),
        exec.exec_threads(),
        t0.elapsed().as_secs_f64()
    );

    // --port: expose this server on TCP instead of replaying a test
    // set against it (`--port 0` binds an ephemeral port and prints
    // the bound address, so scripts never race on fixed ports).
    if args.get("port").is_some() {
        return super::cluster::expose_worker(args, exec);
    }

    // Test set: prefer the exported one when it matches this model's
    // resolution; on the reference backend fall back to a synthetic
    // one (missing artifacts OR a mismatched export — e.g. a 32px
    // CIFAR export on disk while serving an 8px/64px model).
    let hw_want = exec.image_hw();
    let (images, labels, synthetic) = match (load_testset(&artifacts), classes) {
        (Ok((im, lb)), _)
            if testset_matches(&im, hw_want) && lb.len() >= im.shape()[0] =>
        {
            (im, lb, false)
        }
        (Ok(_), Some(classes)) => {
            println!("(exported test set is not {hw_want}px; serving synthetic images)");
            (synth_images(hw_want, 64, synth_seed), synth_labels(64, classes, synth_seed), true)
        }
        (Err(e), Some(classes)) => {
            println!("no exported test set ({e:#}); serving synthetic images");
            (synth_images(hw_want, 64, synth_seed), synth_labels(64, classes, synth_seed), true)
        }
        (Ok((im, _)), None) => anyhow::bail!(
            "test set is {}px but model {model} wants {hw_want}px",
            im.shape().get(2).copied().unwrap_or(0)
        ),
        (Err(e), None) => return Err(e),
    };
    let hw = images.shape()[2];
    let per = 3 * hw * hw;

    // Optional cross-node spill shipping (registry + block geometry
    // validated with a CLI error instead of a Server::start assert).
    let ship_spills = ship_config(args, exec.image_hw())?;

    let server = Server::start(
        exec,
        ServerConfig {
            max_wait: Duration::from_millis(wait_ms),
            workers: 1,
            max_queue: queue,
            ship_spills,
            spill_sink: None,
        },
    );

    let n_avail = images.shape()[0];
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let idx = i % n_avail;
        let img = Tensor::from_vec(
            &[3, hw, hw],
            images.data()[idx * per..(idx + 1) * per].to_vec(),
        );
        pending.push((idx, server.submit(img)?));
    }
    let mut correct = 0usize;
    for (idx, rx) in pending {
        let resp = rx.recv().context("request dropped")?;
        if resp.predicted as i32 == labels[idx] {
            correct += 1;
        }
    }
    let wall = t0.elapsed();
    println!(
        "\nserved {n_requests} requests in {:.2}s ({:.1} req/s), top-1 {:.1}%{}",
        wall.as_secs_f64(),
        n_requests as f64 / wall.as_secs_f64(),
        100.0 * correct as f64 / n_requests as f64,
        if synthetic { " (synthetic labels — accuracy is chance)" } else { "" }
    );
    println!("metrics: {}", server.metrics.summary());
    print!("{}", server.telemetry.snapshot().report(Some("serve.batch")));
    server.shutdown();
    Ok(())
}

pub fn load_testset(
    artifacts: &std::path::Path,
) -> Result<(Tensor, Vec<i32>)> {
    let images = read_zten(artifacts.join("testset_images.zten"))
        .context("testset images (run `make artifacts`)")?;
    let (_, labels) = read_zten_i32(artifacts.join("testset_labels.zten"))?;
    Ok((images, labels))
}
