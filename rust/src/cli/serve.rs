//! `zebra serve` — run the full serving pipeline: load AOT artifacts,
//! start the coordinator, replay the exported test set as requests, and
//! print latency/throughput/bandwidth metrics.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::Args;
use crate::compress;
use crate::coordinator::server::BatchExecutor;
use crate::coordinator::{PjrtExecutor, Server, ServerConfig, ShipSpills};
use crate::tensor::{read_zten, read_zten_i32, Tensor};

pub fn run(args: &Args) -> Result<()> {
    let artifacts = crate::artifacts_dir();
    let model = args.get_or("model", "rn18-c10-t0.1");
    let n_requests = args.get_usize("requests", 64)?;
    let wait_ms = args.get_usize("wait-ms", 2)? as u64;
    let queue = args.get_usize("queue", 1024)?;
    // Optional cross-node spill shipping: resolve the codec through the
    // registry so an unknown name errors with the valid list.
    let ship = match args.get("ship-codec") {
        Some(name) => {
            let spec = compress::spec_or_err(name)?;
            let block = args.get_usize("ship-block", 4)?;
            anyhow::ensure!(
                block <= u16::MAX as usize,
                "--ship-block {block} is out of range"
            );
            Some((spec, block as u16))
        }
        None => None,
    };

    println!("loading runtime from {artifacts:?} ...");
    let t0 = Instant::now();
    let exec = Arc::new(PjrtExecutor::new(artifacts.clone(), &model)?);
    println!(
        "model {} | batches {:?} | compiled in {:.1}s",
        model,
        exec.batch_sizes(),
        t0.elapsed().as_secs_f64()
    );

    let (images, labels) = load_testset(&artifacts)?;
    let hw = images.shape()[2];
    let per = 3 * hw * hw;

    // Block geometry is only checkable once the image size is known;
    // reject bad --ship-block values here with a CLI error instead of
    // letting Server::start assert.
    let ship_spills = match ship {
        Some((spec, block)) => {
            if spec.needs_block {
                anyhow::ensure!(
                    block > 0 && exec.image_hw() % block as usize == 0,
                    "--ship-block {} must be positive and divide the \
                     {}px image",
                    block,
                    exec.image_hw()
                );
            }
            Some(ShipSpills { codec: spec.id, block })
        }
        None => None,
    };

    let server = Server::start(
        exec,
        ServerConfig {
            max_wait: Duration::from_millis(wait_ms),
            workers: 1,
            max_queue: queue,
            ship_spills,
        },
    );

    let n_avail = images.shape()[0];
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let idx = i % n_avail;
        let img = Tensor::from_vec(
            &[3, hw, hw],
            images.data()[idx * per..(idx + 1) * per].to_vec(),
        );
        pending.push((idx, server.submit(img)?));
    }
    let mut correct = 0usize;
    for (idx, rx) in pending {
        let resp = rx.recv().context("request dropped")?;
        if resp.predicted as i32 == labels[idx] {
            correct += 1;
        }
    }
    let wall = t0.elapsed();
    println!(
        "\nserved {n_requests} requests in {:.2}s ({:.1} req/s), top-1 {:.1}%",
        wall.as_secs_f64(),
        n_requests as f64 / wall.as_secs_f64(),
        100.0 * correct as f64 / n_requests as f64
    );
    println!("metrics: {}", server.metrics.summary());
    server.shutdown();
    Ok(())
}

pub fn load_testset(
    artifacts: &std::path::Path,
) -> Result<(Tensor, Vec<i32>)> {
    let images = read_zten(artifacts.join("testset_images.zten"))
        .context("testset images (run `make artifacts`)")?;
    let (_, labels) = read_zten_i32(artifacts.join("testset_labels.zten"))?;
    Ok((images, labels))
}
