//! `zebra serve` — run the full serving pipeline: start the
//! coordinator over the selected backend (`--backend reference|pjrt`),
//! replay the exported test set (or a synthetic one when no artifacts
//! exist) as requests, and print latency/throughput/bandwidth metrics.
//!
//! With `--port` the same server is exposed over TCP instead of
//! replayed against: `zebra serve --port 0` is a single-node network
//! front (it prints the bound address), and `zebra cluster-worker` is
//! this plus upstream spill shipping.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::opts::ServeOpts;
use super::Args;
use crate::backend::reference::RefSpec;
use crate::backend::{synth_images, synth_labels, testset_matches, BackendKind};
use crate::coordinator::server::BatchExecutor;
use crate::coordinator::{
    reference_executor_with_ledger, Server, SubmitOutcome, SubmitRequest,
};
use crate::obs::{render_waterfall, sampled, trace_id_for, Ledger, SloEngine};
use crate::tensor::{read_zten, read_zten_i32, Tensor};

pub fn run(args: &Args) -> Result<()> {
    run_with(args, crate::artifacts_dir())
}

/// Build the `--backend`/`--model`/`--weights` executor the way every
/// serving entry point (serve, cluster-worker) does. Returns the
/// executor, the class count when known statically (reference backend
/// only — it gates the synthetic-test-set fallback), the resolved
/// backend kind, and the node's bandwidth [`Ledger`] — attached to the
/// reference backend's per-layer sweep (the PJRT runtime doesn't
/// capture masks yet, so its ledger only ever carries the spill cell)
/// and meant to land in `ServerConfig::ledger` so the same registry
/// also records shipped batches.
pub(crate) fn build_executor(
    args: &Args,
    artifacts: &std::path::Path,
) -> Result<(Arc<dyn BatchExecutor>, Option<usize>, BackendKind, Arc<Ledger>)>
{
    let backend = BackendKind::parse(
        &args.get_or("backend", BackendKind::default_name()),
    )?;
    let model = args.get_or("model", "rn18-c10-t0.1");
    let weights = args.get("weights").map(std::path::PathBuf::from);
    if weights.is_some() && backend != BackendKind::Reference {
        anyhow::bail!("--weights only applies to --backend reference");
    }
    // Conv worker threads for the block-sparse engine (0 = leave it to
    // ZEBRA_THREADS / single-threaded; results are identical either way).
    let threads = args.get_usize("threads", 0)?;
    if threads > 0 && backend != BackendKind::Reference {
        anyhow::bail!("--threads only applies to --backend reference");
    }
    let ledger = Ledger::new();
    let (exec, classes): (Arc<dyn BatchExecutor>, Option<usize>) = match backend
    {
        BackendKind::Reference => {
            let mut spec = RefSpec::from_key(&model)?;
            spec.threads = threads;
            // Trained `.zten` leaves override the deterministic
            // weights: an explicit --weights DIR (e.g. fresh out of
            // `zebra train --out DIR`) wins over the artifacts probe.
            if let Some(dir) = weights {
                anyhow::ensure!(
                    dir.is_dir(),
                    "--weights {dir:?} is not a directory"
                );
                // Explicit --weights must be a complete checkpoint —
                // no silent per-leaf fallback to generated weights.
                crate::backend::reference::check_complete_leaves(
                    &spec, &dir,
                )?;
                println!("loading reference weights from {dir:?}");
                spec.weights_dir = Some(dir);
            } else {
                let wdir = artifacts.join("ref-weights").join(&model);
                if wdir.is_dir() {
                    println!("loading reference weights from {wdir:?}");
                    spec.weights_dir = Some(wdir);
                }
            }
            let classes = spec.classes;
            (
                Arc::new(reference_executor_with_ledger(
                    spec,
                    ledger.clone(),
                )?),
                Some(classes),
            )
        }
        BackendKind::Pjrt => {
            #[cfg(feature = "pjrt")]
            {
                println!("loading PJRT runtime from {artifacts:?} ...");
                let e = crate::coordinator::pjrt_executor(
                    artifacts.to_path_buf(),
                    &model,
                )?;
                (Arc::new(e), None)
            }
            #[cfg(not(feature = "pjrt"))]
            {
                anyhow::bail!(
                    "this zebra binary was built without the `pjrt` \
                     feature; rebuild with `cargo build --features pjrt` \
                     or use --backend reference"
                );
            }
        }
    };
    Ok((exec, classes, backend, ledger))
}

/// `serve` with an explicit artifacts directory (tests inject a temp
/// dir here instead of mutating `ZEBRA_ARTIFACTS`).
pub fn run_with(args: &Args, artifacts: std::path::PathBuf) -> Result<()> {
    // The shared flag surface validates first: a bad --queue or a
    // --flush-us/--wait-ms conflict must fail before any executor is
    // built.
    let opts = ServeOpts::from_args(args)?;
    let model = args.get_or("model", "rn18-c10-t0.1");
    let n_requests = args.get_usize("requests", 64)?;
    // Synthetic-test-set seed: reproducible by default, varied on
    // demand (`--seed`).
    let synth_seed = args.get_usize("seed", 0xB1A5)? as u64;

    let t0 = Instant::now();
    let (exec, classes, backend, ledger) = build_executor(args, &artifacts)?;
    println!(
        "backend {} | model {} | batches {:?} | threads {} | ready in {:.1}s",
        backend.name(),
        model,
        exec.batch_sizes(),
        exec.exec_threads(),
        t0.elapsed().as_secs_f64()
    );

    // --port: expose this server on TCP instead of replaying a test
    // set against it (`--port 0` binds an ephemeral port and prints
    // the bound address, so scripts never race on fixed ports).
    if opts.port.is_some() {
        return super::cluster::expose_worker(&opts, args, exec, ledger);
    }

    // Test set: prefer the exported one when it matches this model's
    // resolution; on the reference backend fall back to a synthetic
    // one (missing artifacts OR a mismatched export — e.g. a 32px
    // CIFAR export on disk while serving an 8px/64px model).
    let hw_want = exec.image_hw();
    let (images, labels, synthetic) = match (load_testset(&artifacts), classes) {
        (Ok((im, lb)), _)
            if testset_matches(&im, hw_want) && lb.len() >= im.shape()[0] =>
        {
            (im, lb, false)
        }
        (Ok(_), Some(classes)) => {
            println!("(exported test set is not {hw_want}px; serving synthetic images)");
            (synth_images(hw_want, 64, synth_seed), synth_labels(64, classes, synth_seed), true)
        }
        (Err(e), Some(classes)) => {
            println!("no exported test set ({e:#}); serving synthetic images");
            (synth_images(hw_want, 64, synth_seed), synth_labels(64, classes, synth_seed), true)
        }
        (Ok((im, _)), None) => anyhow::bail!(
            "test set is {}px but model {model} wants {hw_want}px",
            im.shape().get(2).copied().unwrap_or(0)
        ),
        (Err(e), None) => return Err(e),
    };
    let hw = images.shape()[2];
    let per = 3 * hw * hw;

    // Server config comes whole from the shared flag surface
    // (flush window, queue, max-batch, ship codec geometry), plus the
    // flight recorder when tracing/--flight-dir is on.
    let image_hw = exec.image_hw();
    let flight = opts.flight_recorder("serve");
    let mut cfg = opts.server_config(image_hw)?;
    cfg.flight = flight.clone();
    cfg.ledger = Some(ledger.clone());
    cfg.slo = Some(SloEngine::new(opts.slo.clone(), flight.clone()));
    // server_config threads --chaos through (worker stall/slow and
    // spill corruption fire in a plain replay too); say so up front.
    if let Some(fi) = &opts.faults {
        println!("serve chaos: {}", fi.plan().summary());
    }
    let server = Server::start(exec, cfg);

    let n_avail = images.shape()[0];
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut shed = 0usize;
    for i in 0..n_requests {
        let idx = i % n_avail;
        let img = Tensor::from_vec(
            &[3, hw, hw],
            images.data()[idx * per..(idx + 1) * per].to_vec(),
        );
        // One shard key (the default) so the whole replay shares one
        // batch queue — same batching behavior the old static batcher
        // had. `--priority` picks the admission class.
        let mut req = SubmitRequest::new(img)
            .with_priority(opts.priority.for_request(i));
        if opts.trace_sample > 0 {
            let tid = trace_id_for(synth_seed, i as u64);
            req = req.with_trace(tid, sampled(tid, opts.trace_sample));
        }
        let (tx, rx) = channel();
        match server.submit(req, tx) {
            SubmitOutcome::Enqueued { .. } => pending.push((idx, rx)),
            SubmitOutcome::Shed { priority, queued } => {
                if shed == 0 {
                    println!(
                        "(admission control shed a {} class request; \
                         {queued} queued)",
                        priority.name()
                    );
                }
                shed += 1;
            }
            SubmitOutcome::Closed => {
                anyhow::bail!("server closed while submitting")
            }
        }
    }
    let answered = pending.len();
    let mut correct = 0usize;
    let mut first_trace = None;
    for (idx, rx) in pending {
        let resp = rx.recv().context("request dropped")?;
        if resp.predicted as i32 == labels[idx] {
            correct += 1;
        }
        if first_trace.is_none() {
            first_trace = resp.trace;
        }
    }
    let wall = t0.elapsed();
    println!(
        "\nserved {answered}/{n_requests} requests ({shed} shed) in \
         {:.2}s ({:.1} req/s), top-1 {:.1}%{}",
        wall.as_secs_f64(),
        answered as f64 / wall.as_secs_f64(),
        100.0 * correct as f64 / answered.max(1) as f64,
        if synthetic { " (synthetic labels — accuracy is chance)" } else { "" }
    );
    println!("metrics: {}", server.metrics.summary());
    // Per-layer bandwidth ledger from the replay (the same cells a
    // live node exports as `zebra_ledger_*`).
    let snap = ledger.snapshot();
    if !snap.cells.is_empty() {
        println!("ledger (dense -> encoded bytes per layer/codec):");
        for ((layer, codec), c) in &snap.cells {
            println!(
                "  {layer}/{codec}: {} -> {} ({:.1}% saved, {} of {} \
                 blocks zero)",
                c.dense_bytes,
                c.encoded_bytes,
                c.achieved_savings_pct(),
                c.zero_blocks,
                c.blocks
            );
        }
    }
    print!("{}", server.telemetry.snapshot().report(Some("serve.batch")));
    // One sampled request's waterfall, as a taste of what `zebra obs
    // replay` renders from a full flight dump.
    if let Some(rec) = &first_trace {
        print!("\n{}", render_waterfall(rec));
    }
    if let Some(f) = &flight {
        if let Some(Err(e)) = f.dump() {
            eprintln!("flight dump failed: {e}");
        }
    }
    server.shutdown();
    Ok(())
}

pub fn load_testset(
    artifacts: &std::path::Path,
) -> Result<(Tensor, Vec<i32>)> {
    let images = read_zten(artifacts.join("testset_images.zten"))
        .context("testset images (run `make artifacts`)")?;
    let (_, labels) = read_zten_i32(artifacts.join("testset_labels.zten"))?;
    Ok((images, labels))
}
