//! Activation trace loading: replaying the Python model's real DRAM
//! spills through the Rust codecs and the accelerator simulator.
//!
//! A trace directory (written by `python/compile/trace.py`) holds one
//! `.zten` per spill plus `trace.json` metadata. See DESIGN.md §5.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::tensor::{read_zten, Tensor};
use crate::util::json::{self, Value};
use crate::zebra::bandwidth::SpillShape;

/// One loaded spill: static shape info + the actual batch tensor.
#[derive(Debug)]
pub struct TraceSpill {
    pub shape: SpillShape,
    /// `(N, C, H, W)` activations for the traced batch.
    pub tensor: Tensor,
}

/// A full model trace: every spill of one batch of images.
#[derive(Debug)]
pub struct Trace {
    pub dir: PathBuf,
    pub model: String,
    pub dataset: String,
    pub t_obj: f64,
    pub zebra: bool,
    pub labels: Vec<i64>,
    pub spills: Vec<TraceSpill>,
}

impl Trace {
    /// Batch size of the traced run.
    pub fn batch(&self) -> usize {
        self.spills.first().map(|s| s.tensor.shape()[0]).unwrap_or(0)
    }

    /// The static spill plan (shapes only).
    pub fn plan(&self) -> Vec<SpillShape> {
        self.spills.iter().map(|s| s.shape.clone()).collect()
    }

    /// Raw test images, if the trace carries them (Fig. 4 overlays).
    pub fn raw_images(&self) -> Result<(Vec<usize>, Vec<u8>)> {
        crate::tensor::read_zten_u8(self.dir.join("raw_images.zten"))
    }
}

/// Load a trace directory.
pub fn load(dir: impl AsRef<Path>) -> Result<Trace> {
    let dir = dir.as_ref().to_path_buf();
    let meta_path = dir.join("trace.json");
    let text = std::fs::read_to_string(&meta_path)
        .with_context(|| format!("reading {meta_path:?}"))?;
    let meta = json::parse(&text).context("parsing trace.json")?;
    let spills = load_spills(&dir, &meta)?;
    Ok(Trace {
        model: meta.get("model").as_str().unwrap_or("?").to_string(),
        dataset: meta.get("dataset").as_str().unwrap_or("?").to_string(),
        t_obj: meta.get("t_obj").as_f64().unwrap_or(0.0),
        zebra: meta.get("zebra").as_bool().unwrap_or(false),
        labels: meta
            .get("labels")
            .as_array()
            .map(|a| {
                a.iter().filter_map(|v| v.as_f64()).map(|f| f as i64).collect()
            })
            .unwrap_or_default(),
        spills,
        dir,
    })
}

fn load_spills(dir: &Path, meta: &Value) -> Result<Vec<TraceSpill>> {
    let entries = meta
        .get("spills")
        .as_array()
        .context("trace.json: missing spills[]")?;
    let mut out = Vec::with_capacity(entries.len());
    for (i, e) in entries.iter().enumerate() {
        let file = e
            .get("file")
            .as_str()
            .with_context(|| format!("spill[{i}] missing file"))?;
        let tensor = read_zten(dir.join(file))
            .with_context(|| format!("loading spill {file}"))?;
        let ts = tensor.shape();
        anyhow::ensure!(ts.len() == 4, "spill {file} is not NCHW: {ts:?}");
        let block = e
            .get("block")
            .as_usize()
            .with_context(|| format!("spill[{i}] missing block"))?;
        out.push(TraceSpill {
            shape: SpillShape {
                name: e.get("name").as_str().unwrap_or(file).to_string(),
                c: ts[1],
                h: ts[2],
                w: ts[3],
                block,
            },
            tensor,
        });
    }
    anyhow::ensure!(!out.is_empty(), "trace has no spills");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::write_zten;

    fn make_trace_dir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("ztrace_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let t = Tensor::from_vec(&[2, 1, 4, 4], (0..32).map(|v| v as f32).collect());
        write_zten(dir.join("s0_conv.zten"), &t).unwrap();
        std::fs::write(
            dir.join("trace.json"),
            r#"{"model":"m","dataset":"cifar10","t_obj":0.1,"zebra":true,
                "labels":[3,7],
                "spills":[{"name":"s0.conv","file":"s0_conv.zten",
                           "shape":[2,1,4,4],"block":2}]}"#,
        )
        .unwrap();
        dir
    }

    #[test]
    fn loads_trace_directory() {
        let dir = make_trace_dir("ok");
        let tr = load(&dir).unwrap();
        assert_eq!(tr.model, "m");
        assert_eq!(tr.batch(), 2);
        assert_eq!(tr.labels, vec![3, 7]);
        assert_eq!(tr.spills[0].shape.block, 2);
        assert_eq!(tr.spills[0].shape.c, 1);
        assert_eq!(tr.plan().len(), 1);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_metadata_is_an_error() {
        let dir = std::env::temp_dir()
            .join(format!("ztrace_{}_none", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_spill_file_is_an_error() {
        let dir = make_trace_dir("gone");
        std::fs::remove_file(dir.join("s0_conv.zten")).unwrap();
        assert!(load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
