//! Dynamic batcher: groups single-image requests into the fixed batch
//! sizes the AOT artifacts were exported with.
//!
//! PJRT executables have static shapes, so the batcher's job is the
//! vLLM-style one restricted to classification: pick, for the current
//! queue depth, the exported batch size that maximizes occupancy within
//! a latency budget. Policy:
//!
//! 1. Block until at least one request is pending.
//! 2. If the queue already covers the largest exported batch, take it.
//! 3. Otherwise wait up to `max_wait` for more arrivals, then choose
//!    the smallest exported batch >= queue depth (padding the tail) —
//!    padding wastes compute but never delays a request by more than
//!    `max_wait`.
//!
//! Invariants (property-tested): no request is dropped or duplicated,
//! arrival order is preserved, batches never exceed the largest
//! exported size, and every emitted batch size is one of the exported
//! sizes.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// A batch of items plus how many padding slots the executor must add.
#[derive(Debug)]
pub struct Batch<T> {
    pub items: Vec<T>,
    /// Artifact batch size to run (>= items.len()).
    pub exec_size: usize,
}

impl<T> Batch<T> {
    pub fn padding(&self) -> usize {
        self.exec_size - self.items.len()
    }
}

/// Thread-safe dynamic batcher over any payload type.
pub struct Batcher<T> {
    inner: Mutex<State<T>>,
    cv: Condvar,
    /// Exported batch sizes, ascending (e.g. [1, 4, 8]).
    sizes: Vec<usize>,
    max_wait: Duration,
}

struct State<T> {
    queue: VecDeque<T>,
    closed: bool,
}

impl<T> Batcher<T> {
    /// `sizes` must be non-empty; they are sorted ascending internally.
    pub fn new(mut sizes: Vec<usize>, max_wait: Duration) -> Self {
        assert!(!sizes.is_empty(), "need at least one exported batch size");
        sizes.sort_unstable();
        sizes.dedup();
        Batcher {
            inner: Mutex::new(State { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            sizes,
            max_wait,
        }
    }

    pub fn max_batch(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Enqueue one item. Returns false if the batcher is closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.inner.lock().unwrap();
        if st.closed {
            return false;
        }
        st.queue.push_back(item);
        drop(st);
        self.cv.notify_one();
        true
    }

    /// Close the queue: pending items still drain, pushes are rejected,
    /// and `next_batch` returns None once empty.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Current queue depth (for backpressure decisions).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Smallest exported size >= n, or the largest if n exceeds all.
    fn size_for(&self, n: usize) -> usize {
        for &s in &self.sizes {
            if s >= n {
                return s;
            }
        }
        self.max_batch()
    }

    /// Blocking: assemble the next batch (None after close+drain).
    pub fn next_batch(&self) -> Option<Batch<T>> {
        let mut st = self.inner.lock().unwrap();
        // Phase 1: wait for at least one item.
        loop {
            if !st.queue.is_empty() {
                break;
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
        // Phase 2: give laggards `max_wait` to fill the largest batch.
        let deadline = Instant::now() + self.max_wait;
        while st.queue.len() < self.max_batch() && !st.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) =
                self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let take = st.queue.len().min(self.max_batch());
        let exec_size = self.size_for(take);
        let items: Vec<T> = st.queue.drain(..take).collect();
        Some(Batch { items, exec_size })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{forall, Config};
    use std::sync::Arc;

    #[test]
    fn batches_respect_exported_sizes() {
        let b = Batcher::new(vec![4, 1, 8], Duration::from_millis(0));
        for i in 0..6 {
            assert!(b.push(i));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items.len(), 6);
        assert_eq!(batch.exec_size, 8);
        assert_eq!(batch.padding(), 2);
    }

    #[test]
    fn full_queue_takes_largest_batch_without_waiting() {
        let b = Batcher::new(vec![1, 4], Duration::from_secs(60));
        for i in 0..9 {
            b.push(i);
        }
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(1));
        assert_eq!(batch.items, vec![0, 1, 2, 3]);
        assert_eq!(batch.exec_size, 4);
    }

    #[test]
    fn close_drains_then_ends() {
        let b = Batcher::new(vec![2], Duration::from_millis(1));
        b.push(1);
        b.close();
        assert!(!b.push(2), "push after close must be rejected");
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.items, vec![1]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn waits_for_laggards_up_to_deadline() {
        let b = Arc::new(Batcher::new(vec![1, 2], Duration::from_millis(200)));
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            b2.push(2u32);
        });
        b.push(1u32);
        let batch = b.next_batch().unwrap();
        t.join().unwrap();
        assert_eq!(batch.items, vec![1, 2], "laggard should join the batch");
    }

    #[test]
    fn prop_no_drop_dup_or_reorder() {
        forall(Config::cases(40), |rng| {
            let mut sizes = vec![1usize];
            if rng.chance(0.7) {
                sizes.push(rng.range(2, 6));
            }
            if rng.chance(0.5) {
                sizes.push(rng.range(7, 12));
            }
            let b = Batcher::new(sizes.clone(), Duration::from_millis(0));
            let n = rng.range(1, 64);
            for i in 0..n {
                b.push(i);
            }
            b.close();
            let mut got = Vec::new();
            while let Some(batch) = b.next_batch() {
                assert!(batch.items.len() <= *sizes.iter().max().unwrap());
                assert!(
                    sizes.contains(&batch.exec_size),
                    "exec size {} not exported {:?}",
                    batch.exec_size,
                    sizes
                );
                assert!(batch.exec_size >= batch.items.len());
                got.extend(batch.items);
            }
            assert_eq!(got, (0..n).collect::<Vec<_>>());
        });
    }

    #[test]
    fn prop_concurrent_producers_lose_nothing() {
        forall(Config::cases(10), |rng| {
            let b = Arc::new(Batcher::new(
                vec![1, 4, 8],
                Duration::from_micros(rng.range(0, 500) as u64),
            ));
            let producers = rng.range(1, 4);
            let per = rng.range(1, 32);
            let mut handles = Vec::new();
            for p in 0..producers {
                let b = b.clone();
                handles.push(std::thread::spawn(move || {
                    for i in 0..per {
                        b.push(p * 1000 + i);
                    }
                }));
            }
            let consumer = {
                let b = b.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(batch) = b.next_batch() {
                        got.extend(batch.items);
                    }
                    got
                })
            };
            for h in handles {
                h.join().unwrap();
            }
            b.close();
            let mut got = consumer.join().unwrap();
            got.sort_unstable();
            let mut want: Vec<usize> = (0..producers)
                .flat_map(|p| (0..per).map(move |i| p * 1000 + i))
                .collect();
            want.sort_unstable();
            assert_eq!(got, want);
        });
    }
}
