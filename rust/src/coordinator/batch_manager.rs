//! Continuous batch manager: per-key queues, priority classes with
//! deterministic load-shedding, deadline-based flush, and dynamic
//! batch sizing driven by observed executor latency.
//!
//! This replaces the static `Batcher` (a single global FIFO flushed on
//! a fixed cadence). Heterogeneous traffic — per-layer codec choices,
//! multiple models, multiple input shapes — makes batches *keyed*: only
//! requests sharing a batch key (model, shape, codec) may share an
//! executed batch, so one queue per key, never one global queue where a
//! slow shape convoys everything behind it.
//!
//! Scheduling policy (the load-aware part):
//!
//! 1. **Admission by class.** Capacity is shared, but each [`Priority`]
//!    class may only occupy a slice of it: `Low` sheds once the queue
//!    is 50% full, `Normal` at 85%, `High` only when completely full
//!    ([`Priority::admission_cap`]). Shedding is an explicit
//!    [`Admission::Shed`] outcome — never a silent drop.
//! 2. **Deadline-based flush.** Every item is due `flush_wait` after
//!    arrival (sooner if it carries an explicit deadline). A key
//!    flushes when its oldest item is due or when it has a full
//!    target-sized batch, whichever happens first.
//! 3. **Priority scheduling.** Among flush-ready keys, the one holding
//!    the highest class goes first (ties broken by earliest due), and
//!    within a key higher classes pop first. `High` traffic can
//!    therefore starve `Low` — by design: `Low` is the sheddable,
//!    best-effort class, and the deadline-miss counter makes any
//!    starvation visible.
//! 4. **Dynamic batch sizing.** The manager watches the executor's
//!    telemetry stage (`serve.execute`): observed nanoseconds per
//!    executed slot turn the flush window into a *batch size budget* —
//!    under load, batches are cut so one batch's execution roughly fits
//!    the flush window and a request on another key is never stuck
//!    behind an arbitrarily large convoy. With a fast executor (or no
//!    data yet) the target is the largest exported size, i.e. exactly
//!    the old static behavior.
//!
//! Invariants (property-tested): nothing is dropped or duplicated,
//! arrival order is preserved per (key, class), every batch holds items
//! of one key only, and every emitted `exec_size` is an exported batch
//! size.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::telemetry::Stage;

/// Priority class of a submitted request. Under overload the lowest
/// class sheds first; see [`Priority::admission_cap`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low = 0,
    Normal = 1,
    High = 2,
}

impl Priority {
    /// All classes, lowest first.
    pub const ALL: [Priority; 3] =
        [Priority::Low, Priority::Normal, Priority::High];

    /// CLI / wire name.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Parse a CLI name. Errors list the valid options.
    pub fn parse(s: &str) -> Result<Priority> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            other => bail!("unknown priority {other:?} (low|normal|high)"),
        }
    }

    /// Wire byte (stable: Low=0, Normal=1, High=2).
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    /// Inverse of [`Priority::as_u8`]; `None` for bytes no class owns
    /// (wire parsers turn that into a structured error, never a panic).
    pub fn from_u8(b: u8) -> Option<Priority> {
        match b {
            0 => Some(Priority::Low),
            1 => Some(Priority::Normal),
            2 => Some(Priority::High),
            _ => None,
        }
    }

    /// How much of a shared capacity this class may occupy before it is
    /// shed: 50% for `Low`, 85% for `Normal`, all of it for `High`.
    /// Always at least 1 so a tiny capacity never locks a class out
    /// entirely. The router applies the same split to its per-worker
    /// in-flight caps, so shed-lowest-first holds cluster-wide.
    pub fn admission_cap(self, capacity: usize) -> usize {
        let pct = match self {
            Priority::Low => 50,
            Priority::Normal => 85,
            Priority::High => 100,
        };
        (capacity * pct).div_ceil(100).max(1)
    }
}

/// Outcome of one [`BatchManager::push`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Enqueued; the item will be batched and executed.
    Accepted,
    /// Refused by the class's admission cap (`queued` = depth at the
    /// moment of refusal). The caller owes the client a structured
    /// overload response — shedding is never silent.
    Shed { queued: usize },
    /// The manager is closed; nothing new is accepted.
    Closed,
}

/// A flushed batch: one key's items plus the exported batch size the
/// executor must run (>= items.len(); the tail is padding).
#[derive(Debug)]
pub struct Batch<T> {
    /// The batch key every item shares.
    pub key: u64,
    pub items: Vec<T>,
    /// Exported batch size to execute (>= items.len()).
    pub exec_size: usize,
    /// Items whose explicit deadline had already passed at flush time.
    /// They are still served (a miss is counted, never dropped); the
    /// caller feeds this into its deadline-miss counter.
    pub deadline_misses: usize,
}

impl<T> Batch<T> {
    pub fn padding(&self) -> usize {
        self.exec_size - self.items.len()
    }
}

struct Entry<T> {
    item: T,
    /// When this item wants to be flushed (arrival + flush window,
    /// sooner under an explicit deadline).
    due: Instant,
    /// The explicit deadline, if any (for miss accounting).
    hard: Option<Instant>,
}

/// One key's queue: a FIFO per priority class.
struct KeyQueue<T> {
    classes: [std::collections::VecDeque<Entry<T>>; 3],
}

impl<T> Default for KeyQueue<T> {
    fn default() -> Self {
        KeyQueue {
            classes: [
                std::collections::VecDeque::new(),
                std::collections::VecDeque::new(),
                std::collections::VecDeque::new(),
            ],
        }
    }
}

impl<T> KeyQueue<T> {
    fn count(&self) -> usize {
        self.classes.iter().map(|c| c.len()).sum()
    }

    /// (highest class present, earliest due across class fronts,
    /// total items) — `None` when empty.
    fn summary(&self) -> Option<(usize, Instant, usize)> {
        let mut best_class = None;
        let mut due: Option<Instant> = None;
        for (c, q) in self.classes.iter().enumerate() {
            if let Some(front) = q.front() {
                best_class = Some(c);
                due = Some(match due {
                    Some(d) if d <= front.due => d,
                    _ => front.due,
                });
            }
        }
        Some((best_class?, due?, self.count()))
    }
}

struct State<T> {
    queues: HashMap<u64, KeyQueue<T>>,
    /// Total queued items across every key and class.
    total: usize,
    closed: bool,
}

/// Thread-safe continuous batch manager over any payload type.
pub struct BatchManager<T> {
    inner: Mutex<State<T>>,
    cv: Condvar,
    /// Exported batch sizes, ascending (e.g. [1, 4, 8]).
    sizes: Vec<usize>,
    /// The flush window: no admitted item waits longer than this for
    /// its batch to start assembling an execution.
    flush_wait: Duration,
    /// Global queue capacity the class admission caps are cut from.
    max_queue: usize,
    /// Hard cap on items per batch (<= the largest exported size).
    max_batch: usize,
    /// Executor telemetry (`serve.execute`) feeding dynamic sizing.
    exec_stage: Option<Arc<Stage>>,
    /// Executed slots handed out so far (denominator turning the
    /// stage's accumulated nanoseconds into per-slot latency).
    dispatched_slots: AtomicU64,
    /// Brownout pressure (0 = none), set by the SLO sampler when its
    /// burn policy fires: each level shaves another slice off the
    /// Low/Normal admission caps (see [`BatchManager::browned_cap`]),
    /// shedding best-effort load progressively instead of falling over.
    pressure: AtomicU32,
}

impl<T> BatchManager<T> {
    /// `sizes` must be non-empty; they are sorted ascending internally.
    pub fn new(
        mut sizes: Vec<usize>,
        flush_wait: Duration,
        max_queue: usize,
    ) -> Self {
        assert!(!sizes.is_empty(), "need at least one exported batch size");
        sizes.sort_unstable();
        sizes.dedup();
        let max_batch = *sizes.last().unwrap();
        BatchManager {
            inner: Mutex::new(State {
                queues: HashMap::new(),
                total: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            sizes,
            flush_wait,
            max_queue: max_queue.max(1),
            max_batch,
            exec_stage: None,
            dispatched_slots: AtomicU64::new(0),
            pressure: AtomicU32::new(0),
        }
    }

    /// Set the brownout pressure level (0 restores full caps).
    pub fn set_pressure(&self, level: u32) {
        self.pressure.store(level, Ordering::Relaxed);
    }

    /// The brownout pressure level currently applied to admission.
    pub fn pressure(&self) -> u32 {
        self.pressure.load(Ordering::Relaxed)
    }

    /// The class's admission cap after brownout pressure: every level
    /// takes another 25% off the `Low` cap and 15% off the `Normal`
    /// cap (never below 1 — brownout degrades, it does not lock a
    /// class out); `High` is never browned out.
    fn browned_cap(&self, priority: Priority) -> usize {
        let cap = priority.admission_cap(self.max_queue);
        let level = self.pressure.load(Ordering::Relaxed) as usize;
        if level == 0 {
            return cap;
        }
        let shave = match priority {
            Priority::Low => 25,
            Priority::Normal => 15,
            Priority::High => 0,
        };
        let keep = 100usize.saturating_sub(shave * level);
        (cap * keep / 100).max(1)
    }

    /// Cap batches below the largest exported size (0 keeps the
    /// default). The executed size still snaps *up* to an exported
    /// size; the cap bounds how many real items ride in one batch.
    pub fn with_max_batch(mut self, cap: usize) -> Self {
        if cap > 0 {
            self.max_batch = cap.min(*self.sizes.last().unwrap()).max(1);
        }
        self
    }

    /// Attach the executor's telemetry stage; observed per-slot latency
    /// then drives the dynamic target size.
    pub fn with_exec_stage(mut self, stage: Arc<Stage>) -> Self {
        self.exec_stage = Some(stage);
        self
    }

    /// Largest number of items one batch may carry.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Current queue depth across all keys (the backpressure gauge).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().total
    }

    /// Enqueue one item under `key` with the class's admission check.
    /// An explicit `deadline` flushes sooner than the window if it is
    /// tighter, and is counted as missed if it passes before flush.
    pub fn push(
        &self,
        key: u64,
        priority: Priority,
        deadline: Option<Duration>,
        item: T,
    ) -> Admission {
        let now = Instant::now();
        let mut st = self.inner.lock().unwrap();
        if st.closed {
            return Admission::Closed;
        }
        if st.total >= self.browned_cap(priority) {
            return Admission::Shed { queued: st.total };
        }
        let window = match deadline {
            Some(d) if d < self.flush_wait => d,
            _ => self.flush_wait,
        };
        st.queues.entry(key).or_default().classes[priority as usize]
            .push_back(Entry {
                item,
                due: now + window,
                hard: deadline.map(|d| now + d),
            });
        st.total += 1;
        drop(st);
        self.cv.notify_one();
        Admission::Accepted
    }

    /// Close the manager: pending items still drain (flushed
    /// immediately, ignoring due times), new pushes get
    /// [`Admission::Closed`], and `next_batch` returns `None` once
    /// everything is out.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Smallest exported size >= n, or the largest if n exceeds all.
    fn size_for(&self, n: usize) -> usize {
        for &s in &self.sizes {
            if s >= n {
                return s;
            }
        }
        *self.sizes.last().unwrap()
    }

    /// Largest exported size <= n, or the smallest if none fit.
    fn floor_size(&self, n: usize) -> usize {
        self.sizes
            .iter()
            .rev()
            .find(|&&s| s <= n)
            .copied()
            .unwrap_or(self.sizes[0])
    }

    /// The current target batch size. Cold (no executor data yet) it is
    /// the full `max_batch`; warm, it is how many slots the observed
    /// per-slot execution latency fits into one flush window — so under
    /// load a single batch's execution roughly matches the latency
    /// budget instead of convoying every other key behind it.
    fn target_size(&self) -> usize {
        let full = self.max_batch;
        let Some(stage) = &self.exec_stage else { return full };
        let slots = self.dispatched_slots.load(Ordering::Relaxed);
        let stats = stage.stats();
        if stats.calls == 0 || slots == 0 {
            return full;
        }
        let per_slot = stats.nanos / slots;
        let budget = self.flush_wait.as_nanos().min(u64::MAX as u128) as u64;
        if per_slot == 0 || budget == 0 {
            // Sub-ns slots or a zero window: no budget to subdivide.
            return full;
        }
        let raw = (budget / per_slot).clamp(1, full as u64) as usize;
        self.floor_size(raw).min(full).max(1)
    }

    /// Blocking: assemble the next batch (None after close + drain).
    pub fn next_batch(&self) -> Option<Batch<T>> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if st.total == 0 {
                if st.closed {
                    return None;
                }
                st = self.cv.wait(st).unwrap();
                continue;
            }
            let target = self.target_size();
            let now = Instant::now();
            // A key is flush-ready when its oldest item is due, it can
            // fill a target batch, or the manager is closing. Among
            // ready keys the highest class wins, then the earliest due.
            let mut ready: Option<(u64, usize, Instant)> = None;
            let mut wake: Option<Instant> = None;
            for (&key, q) in &st.queues {
                let Some((class, due, count)) = q.summary() else {
                    continue;
                };
                if st.closed || now >= due || count >= target {
                    let better = match ready {
                        None => true,
                        Some((_, c, d)) => {
                            class > c || (class == c && due < d)
                        }
                    };
                    if better {
                        ready = Some((key, class, due));
                    }
                } else {
                    wake = Some(match wake {
                        Some(w) if w <= due => w,
                        _ => due,
                    });
                }
            }
            if let Some((key, _, _)) = ready {
                return Some(self.flush(&mut st, key, target, now));
            }
            let wake = wake.expect("items queued but no key reported");
            let (guard, _) = self
                .cv
                .wait_timeout(st, wake.saturating_duration_since(now))
                .unwrap();
            st = guard;
        }
    }

    /// Pop up to the effective take from `key` (highest class first,
    /// FIFO within a class) and account deadline misses.
    fn flush(
        &self,
        st: &mut State<T>,
        key: u64,
        target: usize,
        now: Instant,
    ) -> Batch<T> {
        let closed = st.closed;
        let q = st.queues.get_mut(&key).expect("ready key exists");
        let cap = if closed { self.max_batch } else { target };
        let take = q.count().min(cap);
        let mut items = Vec::with_capacity(take);
        let mut deadline_misses = 0usize;
        for class in (0..3).rev() {
            while items.len() < take {
                let Some(e) = q.classes[class].pop_front() else { break };
                if e.hard.is_some_and(|h| now > h) {
                    deadline_misses += 1;
                }
                items.push(e.item);
            }
        }
        if q.count() == 0 {
            st.queues.remove(&key);
        }
        st.total -= take;
        let exec_size = self.size_for(take);
        self.dispatched_slots
            .fetch_add(exec_size as u64, Ordering::Relaxed);
        Batch { key, items, exec_size, deadline_misses }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Telemetry;
    use crate::util::prng::Rng;
    use crate::util::prop::{forall, Config};

    fn mgr(sizes: Vec<usize>, wait_ms: u64, queue: usize) -> BatchManager<u64> {
        BatchManager::new(sizes, Duration::from_millis(wait_ms), queue)
    }

    fn push_n(m: &BatchManager<u64>, n: u64) {
        for i in 0..n {
            assert_eq!(
                m.push(0, Priority::Normal, None, i),
                Admission::Accepted
            );
        }
    }

    #[test]
    fn priority_parses_and_round_trips() {
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
            assert_eq!(Priority::from_u8(p.as_u8()), Some(p));
        }
        assert!(Priority::parse("urgent").is_err());
        assert_eq!(Priority::from_u8(3), None);
    }

    #[test]
    fn admission_caps_split_capacity_by_class() {
        assert_eq!(Priority::Low.admission_cap(100), 50);
        assert_eq!(Priority::Normal.admission_cap(100), 85);
        assert_eq!(Priority::High.admission_cap(100), 100);
        // Tiny capacities never lock a class out entirely.
        for p in Priority::ALL {
            assert!(p.admission_cap(1) >= 1);
        }
        assert_eq!(Priority::High.admission_cap(2), 2);
        assert_eq!(Priority::Normal.admission_cap(2), 2);
        assert_eq!(Priority::Low.admission_cap(2), 1);
    }

    #[test]
    fn batches_respect_exported_sizes() {
        let m = mgr(vec![4, 1, 8], 0, 1024);
        push_n(&m, 6);
        let b = m.next_batch().unwrap();
        assert_eq!(b.items.len(), 6);
        assert_eq!(b.exec_size, 8);
        assert_eq!(b.padding(), 2);
        assert_eq!(b.key, 0);
    }

    #[test]
    fn full_queue_flushes_without_waiting() {
        let m = mgr(vec![1, 4], 60_000, 1024);
        push_n(&m, 9);
        let t0 = Instant::now();
        let b = m.next_batch().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(1));
        assert_eq!(b.items, vec![0, 1, 2, 3]);
        assert_eq!(b.exec_size, 4);
    }

    #[test]
    fn close_drains_then_ends() {
        let m = mgr(vec![2], 1, 1024);
        m.push(0, Priority::Normal, None, 1);
        m.close();
        assert_eq!(m.push(0, Priority::Normal, None, 2), Admission::Closed);
        let b = m.next_batch().unwrap();
        assert_eq!(b.items, vec![1]);
        assert!(m.next_batch().is_none());
    }

    #[test]
    fn waits_for_laggards_up_to_the_flush_window() {
        let m = std::sync::Arc::new(mgr(vec![1, 2], 200, 1024));
        let m2 = m.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            m2.push(0, Priority::Normal, None, 2);
        });
        m.push(0, Priority::Normal, None, 1);
        let b = m.next_batch().unwrap();
        t.join().unwrap();
        assert_eq!(b.items, vec![1, 2], "laggard should join the batch");
    }

    #[test]
    fn keys_never_share_a_batch() {
        let m = mgr(vec![1, 8], 0, 1024);
        for i in 0..4 {
            m.push(7, Priority::Normal, None, i);
            m.push(9, Priority::Normal, None, 100 + i);
        }
        let mut seen = std::collections::HashMap::new();
        while m.depth() > 0 {
            let b = m.next_batch().unwrap();
            let expect_band = if b.key == 9 { 1 } else { 0 };
            for it in &b.items {
                assert_eq!(it / 100, expect_band, "foreign key in batch");
            }
            *seen.entry(b.key).or_insert(0usize) += b.items.len();
        }
        assert_eq!(seen[&7], 4);
        assert_eq!(seen[&9], 4);
    }

    #[test]
    fn low_class_sheds_first_and_is_never_silent() {
        let m = mgr(vec![1, 16], 60_000, 8);
        // Low occupies at most 50% of 8 = 4 slots.
        for i in 0..4 {
            assert_eq!(m.push(0, Priority::Low, None, i), Admission::Accepted);
        }
        assert_eq!(
            m.push(0, Priority::Low, None, 99),
            Admission::Shed { queued: 4 }
        );
        // Normal still fits (cap ceil(8*0.85)=7), High to the brim.
        for i in 0..3 {
            assert_eq!(
                m.push(0, Priority::Normal, None, 10 + i),
                Admission::Accepted
            );
        }
        assert_eq!(
            m.push(0, Priority::Normal, None, 99),
            Admission::Shed { queued: 7 }
        );
        assert_eq!(m.push(0, Priority::High, None, 20), Admission::Accepted);
        assert_eq!(
            m.push(0, Priority::High, None, 99),
            Admission::Shed { queued: 8 }
        );
    }

    #[test]
    fn brownout_pressure_shrinks_low_and_normal_caps_only() {
        let m = mgr(vec![1, 16], 60_000, 8);
        // Level 1: Low keeps 75% of 4 = 3, Normal 85% of 7 = 5, High
        // keeps all 8.
        m.set_pressure(1);
        assert_eq!(m.pressure(), 1);
        for i in 0..3 {
            assert_eq!(m.push(0, Priority::Low, None, i), Admission::Accepted);
        }
        assert_eq!(
            m.push(0, Priority::Low, None, 99),
            Admission::Shed { queued: 3 }
        );
        for i in 0..2 {
            assert_eq!(
                m.push(0, Priority::Normal, None, 10 + i),
                Admission::Accepted
            );
        }
        assert_eq!(
            m.push(0, Priority::Normal, None, 99),
            Admission::Shed { queued: 5 }
        );
        // High is never browned out: it fills to the full capacity.
        for i in 0..3 {
            assert_eq!(
                m.push(0, Priority::High, None, 20 + i),
                Admission::Accepted
            );
        }
        assert_eq!(
            m.push(0, Priority::High, None, 99),
            Admission::Shed { queued: 8 }
        );
        // Recovery restores the un-browned caps; extreme levels clamp
        // at 1 instead of locking a class out.
        m.set_pressure(0);
        assert_eq!(Priority::Low.admission_cap(8), 4);
        m.set_pressure(100);
        assert_eq!(m.browned_cap(Priority::Low), 1);
        assert_eq!(m.browned_cap(Priority::Normal), 1);
        assert_eq!(m.browned_cap(Priority::High), 8);
    }

    #[test]
    fn higher_classes_pop_first_within_a_key() {
        let m = mgr(vec![8], 0, 1024);
        m.push(0, Priority::Low, None, 1);
        m.push(0, Priority::High, None, 2);
        m.push(0, Priority::Normal, None, 3);
        m.push(0, Priority::High, None, 4);
        let b = m.next_batch().unwrap();
        assert_eq!(b.items, vec![2, 4, 3, 1]);
    }

    #[test]
    fn high_priority_key_flushes_before_older_low_key() {
        let m = mgr(vec![1, 8], 0, 1024);
        m.push(1, Priority::Low, None, 10);
        m.push(2, Priority::High, None, 20);
        let b = m.next_batch().unwrap();
        assert_eq!((b.key, b.items.clone()), (2, vec![20]));
        let b = m.next_batch().unwrap();
        assert_eq!((b.key, b.items.clone()), (1, vec![10]));
    }

    #[test]
    fn explicit_deadline_flushes_early_and_misses_are_counted() {
        let m = mgr(vec![1, 8], 60_000, 1024);
        // Tighter than the window: flushes in ~5ms, not 60s.
        m.push(0, Priority::Normal, Some(Duration::from_millis(5)), 1);
        let t0 = Instant::now();
        let b = m.next_batch().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(b.items, vec![1]);
        assert_eq!(b.deadline_misses, 0, "flushed at its deadline, not past");

        // Already-expired deadline: served anyway, counted as missed.
        m.push(0, Priority::Normal, Some(Duration::ZERO), 2);
        std::thread::sleep(Duration::from_millis(2));
        let b = m.next_batch().unwrap();
        assert_eq!(b.items, vec![2]);
        assert_eq!(b.deadline_misses, 1);
    }

    #[test]
    fn observed_latency_shrinks_the_target_batch() {
        let tel = Telemetry::new();
        let stage = tel.stage("serve.execute");
        let m = BatchManager::new(
            vec![1, 4, 8],
            Duration::from_millis(1),
            1024,
        )
        .with_exec_stage(stage.clone());
        // Cold: no executor data, full batch.
        push_n(&m, 8);
        let b = m.next_batch().unwrap();
        assert_eq!(b.items.len(), 8);
        // Report 10ms/slot: a 1ms window fits one slot -> batches of 1.
        stage.record(Duration::from_millis(80));
        push_n(&m, 8);
        let b = m.next_batch().unwrap();
        assert_eq!(b.items.len(), 1, "slow executor must cut the batch");
        assert_eq!(b.exec_size, 1);
    }

    #[test]
    fn prop_no_drop_dup_or_reorder_per_key_and_class() {
        forall(Config::cases(30), |rng| {
            let mut sizes = vec![1usize];
            if rng.chance(0.7) {
                sizes.push(rng.range(2, 6));
            }
            if rng.chance(0.5) {
                sizes.push(rng.range(7, 12));
            }
            let m = BatchManager::new(
                sizes.clone(),
                Duration::ZERO,
                usize::MAX >> 1,
            );
            let n = rng.range(1, 64) as u64;
            let keys = rng.range(1, 4) as u64;
            // Payload encodes (key, class, seq) for order checking.
            let mut pushed: HashMap<(u64, usize), Vec<u64>> = HashMap::new();
            for i in 0..n {
                let key = i % keys;
                let class = rng.range(0, 3);
                let p = Priority::from_u8(class as u8).unwrap();
                assert_eq!(m.push(key, p, None, i), Admission::Accepted);
                pushed.entry((key, class)).or_default().push(i);
            }
            m.close();
            let mut got: HashMap<(u64, usize), Vec<u64>> = HashMap::new();
            while let Some(b) = m.next_batch() {
                assert!(b.items.len() <= *sizes.iter().max().unwrap());
                assert!(
                    sizes.contains(&b.exec_size),
                    "exec size {} not exported {:?}",
                    b.exec_size,
                    sizes
                );
                assert!(b.exec_size >= b.items.len());
                for &v in &b.items {
                    assert_eq!(v % keys, b.key, "foreign key in batch");
                    // Reconstruct the class this item was pushed with.
                    let class = pushed
                        .iter()
                        .find(|((k, _), vs)| *k == b.key && vs.contains(&v))
                        .map(|((_, c), _)| *c)
                        .unwrap();
                    got.entry((b.key, class)).or_default().push(v);
                }
            }
            // Per (key, class): exactly the pushed items, in order.
            for (kc, vs) in &pushed {
                assert_eq!(got.get(kc), Some(vs), "key/class {kc:?}");
            }
            let total: usize = got.values().map(|v| v.len()).sum();
            assert_eq!(total as u64, n);
        });
    }

    #[test]
    fn prop_concurrent_producers_lose_nothing() {
        forall(Config::cases(10), |rng| {
            let m = std::sync::Arc::new(BatchManager::new(
                vec![1, 4, 8],
                Duration::from_micros(rng.range(0, 500) as u64),
                usize::MAX >> 1,
            ));
            let producers = rng.range(1, 4) as u64;
            let per = rng.range(1, 32) as u64;
            let mut handles = Vec::new();
            for p in 0..producers {
                let m = m.clone();
                handles.push(std::thread::spawn(move || {
                    for i in 0..per {
                        let pri = Priority::from_u8((i % 3) as u8).unwrap();
                        assert_eq!(
                            m.push(p, pri, None, p * 1000 + i),
                            Admission::Accepted
                        );
                    }
                }));
            }
            let consumer = {
                let m = m.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(b) = m.next_batch() {
                        got.extend(b.items);
                    }
                    got
                })
            };
            for h in handles {
                h.join().unwrap();
            }
            m.close();
            let mut got = consumer.join().unwrap();
            got.sort_unstable();
            let mut want: Vec<u64> = (0..producers)
                .flat_map(|p| (0..per).map(move |i| p * 1000 + i))
                .collect();
            want.sort_unstable();
            assert_eq!(got, want);
        });
    }
}
