//! Serving metrics: lock-free counters + a coarse latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram bucket count: bucket `i` holds samples whose
/// upper bound is `2^i` microseconds (powers of two up to ~8 s). The
/// bucket layout is shared verbatim by the cluster layer's metrics
/// aggregation (`cluster::metrics`), so it is part of the crate API.
pub const LATENCY_BUCKETS: usize = 24;
const BUCKETS: usize = LATENCY_BUCKETS;

/// Approximate percentile over fixed power-of-two latency buckets
/// (returns the bucket's upper bound in microseconds, 0 when empty).
/// Shared by [`Metrics::latency_percentile_us`], the cluster router's
/// aggregated histograms, and `zebra loadgen`. Bucket indices are
/// clamped to 63 so a wider-than-expected histogram (e.g. from a
/// version-skewed cluster peer) can never shift-overflow.
pub fn percentile_from_buckets(counts: &[u64], p: f64) -> u64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = (total as f64 * p).ceil() as u64;
    let mut seen = 0;
    for (i, c) in counts.iter().enumerate() {
        seen += c;
        if seen >= target {
            return 1u64 << i.min(63);
        }
    }
    1u64 << (counts.len().max(1) - 1).min(63)
}

/// The paper's Eq. 2–3 bandwidth reduction in percent — the one
/// formula every tier reports (per-response, per-node metrics,
/// cluster aggregate).
pub fn reduction_pct_of(dense: u64, stored: u64, index: u64) -> f64 {
    if dense == 0 {
        return 0.0;
    }
    100.0 * (1.0 - (stored + index) as f64 / dense as f64)
}

/// Shared serving metrics. All methods are thread-safe.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Eq. 2–3 accounting, summed over responses.
    pub dense_bytes: AtomicU64,
    pub stored_bytes: AtomicU64,
    pub index_bytes: AtomicU64,
    /// `.zspill` frame bytes produced for cross-node spill shipping
    /// (0 unless `ServerConfig::ship_spills` is set).
    pub shipped_spill_bytes: AtomicU64,
    /// Compute worker threads the executor uses per batch (a gauge set
    /// once at server start; summed across workers in cluster
    /// aggregates to give total cluster compute parallelism).
    pub exec_threads: AtomicU64,
    /// Requests refused by the admission caps, per priority class
    /// (shed-lowest-first: `Low` sheds at 50% queue occupancy,
    /// `Normal` at 85%, `High` only when full). Every shed is an
    /// explicit outcome to its caller — these counters are the
    /// accounting side of "never a silent drop".
    pub shed_low: AtomicU64,
    pub shed_normal: AtomicU64,
    pub shed_high: AtomicU64,
    /// Admitted requests that were flushed after their explicit
    /// deadline had already passed (still served; the miss is counted).
    pub deadline_miss: AtomicU64,
    /// Queue depth gauge (set at submit/flush time, not a counter).
    pub queue_depth: AtomicU64,
    /// Admitted requests whose batch execution failed (reply channels
    /// dropped). `responses + shed_* + failed` accounts for every
    /// admitted-or-shed submit.
    pub failed: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one shed in the class's counter.
    pub fn count_shed(&self, p: super::batch_manager::Priority) {
        use super::batch_manager::Priority;
        match p {
            Priority::Low => &self.shed_low,
            Priority::Normal => &self.shed_normal,
            Priority::High => &self.shed_high,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    /// Total sheds across all classes.
    pub fn shed_total(&self) -> u64 {
        self.shed_low.load(Ordering::Relaxed)
            + self.shed_normal.load(Ordering::Relaxed)
            + self.shed_high.load(Ordering::Relaxed)
    }

    pub fn record_latency_us(&self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.latency_us[b].fetch_add(1, Ordering::Relaxed);
        self.responses.fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate percentile from the histogram (bucket upper bound).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        percentile_from_buckets(&self.latency_bucket_counts(), p)
    }

    /// Snapshot of the latency histogram's bucket counts (bucket `i`
    /// covers latencies up to `2^i` us) — what the cluster layer ships
    /// across nodes and merges into cluster-wide percentiles.
    pub fn latency_bucket_counts(&self) -> [u64; LATENCY_BUCKETS] {
        let mut out = [0u64; LATENCY_BUCKETS];
        for (o, c) in out.iter_mut().zip(self.latency_us.iter()) {
            *o = c.load(Ordering::Relaxed);
        }
        out
    }

    /// Mean batch occupancy (items per executed batch).
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Measured bandwidth reduction % across all served requests.
    pub fn reduction_pct(&self) -> f64 {
        reduction_pct_of(
            self.dense_bytes.load(Ordering::Relaxed),
            self.stored_bytes.load(Ordering::Relaxed),
            self.index_bytes.load(Ordering::Relaxed),
        )
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} responses={} batches={} mean_batch={:.2} \
             padded={} threads={} shed={}/{}/{} misses={} failed={} \
             depth={} p50={}us p95={}us p99={}us \
             bw_reduction={:.1}% shipped={}B",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            self.padded_slots.load(Ordering::Relaxed),
            self.exec_threads.load(Ordering::Relaxed).max(1),
            self.shed_low.load(Ordering::Relaxed),
            self.shed_normal.load(Ordering::Relaxed),
            self.shed_high.load(Ordering::Relaxed),
            self.deadline_miss.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.queue_depth.load(Ordering::Relaxed),
            self.latency_percentile_us(0.5),
            self.latency_percentile_us(0.95),
            self.latency_percentile_us(0.99),
            self.reduction_pct(),
            self.shipped_spill_bytes.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_track_recorded_values() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency_us(100); // bucket ~128
        }
        for _ in 0..10 {
            m.record_latency_us(100_000); // bucket ~131072
        }
        assert!(m.latency_percentile_us(0.5) <= 256);
        assert!(m.latency_percentile_us(0.99) >= 65_536);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(0.99), 0);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.reduction_pct(), 0.0);
    }

    #[test]
    fn bucket_counts_round_trip_through_free_percentile() {
        let m = Metrics::new();
        for _ in 0..80 {
            m.record_latency_us(100);
        }
        for _ in 0..15 {
            m.record_latency_us(10_000);
        }
        for _ in 0..5 {
            m.record_latency_us(1_000_000);
        }
        let counts = m.latency_bucket_counts();
        assert_eq!(counts.iter().sum::<u64>(), 100);
        // The free function over the snapshot must agree with the
        // method — this is the contract the cluster aggregation uses.
        for p in [0.5, 0.95, 0.99] {
            assert_eq!(
                percentile_from_buckets(&counts, p),
                m.latency_percentile_us(p)
            );
        }
        assert!(m.latency_percentile_us(0.95) >= 8192);
        assert!(m.latency_percentile_us(0.5) <= 256);
        assert_eq!(percentile_from_buckets(&[], 0.5), 0);
    }

    #[test]
    fn percentile_edge_cases() {
        // Empty slice and all-zero counts: no samples -> 0.
        assert_eq!(percentile_from_buckets(&[], 0.5), 0);
        assert_eq!(percentile_from_buckets(&[0; LATENCY_BUCKETS], 0.99), 0);
        // A single populated bucket answers every percentile with that
        // bucket's upper bound.
        let mut one = [0u64; LATENCY_BUCKETS];
        one[7] = 42;
        for p in [0.01, 0.5, 0.99, 1.0] {
            assert_eq!(percentile_from_buckets(&one, p), 1 << 7);
        }
        // Everything in the overflow (last) bucket.
        let mut last = [0u64; LATENCY_BUCKETS];
        last[LATENCY_BUCKETS - 1] = 5;
        assert_eq!(
            percentile_from_buckets(&last, 0.5),
            1u64 << (LATENCY_BUCKETS - 1)
        );
        // Histograms wider than 64 buckets clamp the shift instead of
        // overflowing (version-skewed cluster peers).
        let mut wide = vec![0u64; 80];
        wide[79] = 1;
        assert_eq!(percentile_from_buckets(&wide, 0.5), 1u64 << 63);
        // p beyond the mass still lands in the last populated bucket.
        assert_eq!(percentile_from_buckets(&[1, 0, 0], 1.0), 1);
    }

    #[test]
    fn summary_surfaces_p95() {
        let m = Metrics::new();
        m.record_latency_us(1000);
        assert!(m.summary().contains("p95="), "{}", m.summary());
    }

    #[test]
    fn shed_counters_split_by_class() {
        use crate::coordinator::batch_manager::Priority;
        let m = Metrics::new();
        m.count_shed(Priority::Low);
        m.count_shed(Priority::Low);
        m.count_shed(Priority::High);
        assert_eq!(m.shed_low.load(Ordering::Relaxed), 2);
        assert_eq!(m.shed_normal.load(Ordering::Relaxed), 0);
        assert_eq!(m.shed_high.load(Ordering::Relaxed), 1);
        assert_eq!(m.shed_total(), 3);
        assert!(m.summary().contains("shed=2/0/1"), "{}", m.summary());
    }

    #[test]
    fn reduction_math() {
        let m = Metrics::new();
        m.dense_bytes.store(1000, Ordering::Relaxed);
        m.stored_bytes.store(400, Ordering::Relaxed);
        m.index_bytes.store(100, Ordering::Relaxed);
        assert!((m.reduction_pct() - 50.0).abs() < 1e-9);
    }
}
