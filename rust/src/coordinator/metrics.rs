//! Serving metrics: lock-free counters + a coarse latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

/// Histogram buckets in microseconds (powers of two up to ~8 s).
const BUCKETS: usize = 24;

/// Shared serving metrics. All methods are thread-safe.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Eq. 2–3 accounting, summed over responses.
    pub dense_bytes: AtomicU64,
    pub stored_bytes: AtomicU64,
    pub index_bytes: AtomicU64,
    /// `.zspill` frame bytes produced for cross-node spill shipping
    /// (0 unless `ServerConfig::ship_spills` is set).
    pub shipped_spill_bytes: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency_us(&self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.latency_us[b].fetch_add(1, Ordering::Relaxed);
        self.responses.fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate percentile from the histogram (bucket upper bound).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let counts: Vec<u64> = self
            .latency_us
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut seen = 0;
        for (i, c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << i;
            }
        }
        1u64 << (BUCKETS - 1)
    }

    /// Mean batch occupancy (items per executed batch).
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Measured bandwidth reduction % across all served requests.
    pub fn reduction_pct(&self) -> f64 {
        let d = self.dense_bytes.load(Ordering::Relaxed) as f64;
        if d == 0.0 {
            return 0.0;
        }
        let s = self.stored_bytes.load(Ordering::Relaxed) as f64;
        let i = self.index_bytes.load(Ordering::Relaxed) as f64;
        100.0 * (1.0 - (s + i) / d)
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} responses={} batches={} mean_batch={:.2} \
             padded={} p50={}us p99={}us bw_reduction={:.1}% shipped={}B",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            self.padded_slots.load(Ordering::Relaxed),
            self.latency_percentile_us(0.5),
            self.latency_percentile_us(0.99),
            self.reduction_pct(),
            self.shipped_spill_bytes.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_track_recorded_values() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency_us(100); // bucket ~128
        }
        for _ in 0..10 {
            m.record_latency_us(100_000); // bucket ~131072
        }
        assert!(m.latency_percentile_us(0.5) <= 256);
        assert!(m.latency_percentile_us(0.99) >= 65_536);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(0.99), 0);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.reduction_pct(), 0.0);
    }

    #[test]
    fn reduction_math() {
        let m = Metrics::new();
        m.dense_bytes.store(1000, Ordering::Relaxed);
        m.stored_bytes.store(400, Ordering::Relaxed);
        m.index_bytes.store(100, Ordering::Relaxed);
        assert!((m.reduction_pct() - 50.0).abs() < 1e-9);
    }
}
