//! Layer-3 coordinator: the serving pipeline that runs the Zebra
//! models from Rust with Python entirely out of the request path.
//!
//! Request flow: [`Server::submit`] (one unified entry point taking a
//! [`SubmitRequest`] — batch key, [`Priority`] class, optional
//! deadline — and returning a [`SubmitOutcome`], used identically by
//! in-process callers, the TCP cluster worker, and the router) ->
//! [`batch_manager::BatchManager`] (continuous batching: per-key
//! queues, deterministic shed-lowest-class-first admission,
//! deadline-based flush, dynamic batch sizing from observed executor
//! latency) -> worker thread ->
//! [`crate::backend::InferenceBackend::execute`] (bridged by
//! [`server::BackendExecutor`]; the pure-Rust reference backend in
//! every build, PJRT under `--features pjrt`) -> per-request
//! [`server::Response`] with logits and Eq. 2–3 bandwidth accounting
//! derived from the model's own mask outputs.
//!
//! With [`ServerConfig::ship_spills`](server::ServerConfig) set, each
//! worker additionally frames its executed batch as a versioned
//! `.zspill` (see `compress` and `rust/docs/zspill.md`) through one
//! per-worker reused [`crate::compress::SpillBuf`] — the wire bytes a
//! multi-node deployment ships between coordinator nodes — and meters
//! them in [`Metrics::shipped_spill_bytes`].
//!
//! Built on std threads + channels (tokio is not in the offline vendor
//! set — DESIGN.md §7); at CPU-PJRT speeds a worker thread per client
//! plus one executor thread is far from the bottleneck.

pub mod batch_manager;
pub mod metrics;
pub mod server;

pub use batch_manager::{Admission, Batch, BatchManager, Priority};
pub use metrics::{percentile_from_buckets, Metrics, LATENCY_BUCKETS};
#[cfg(feature = "pjrt")]
pub use server::pjrt_executor;
pub use server::{
    reference_executor, reference_executor_with_ledger, BackendExecutor,
    BatchExecutor, Request, Response, Server, ServerConfig, ShipSpills,
    SubmitOutcome, SubmitRequest,
};
