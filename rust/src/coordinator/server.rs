//! The serving pipeline: unified request intake -> continuous batch
//! manager -> executor worker(s) -> per-request responses with
//! bandwidth accounting.
//!
//! All intake — in-process callers, the TCP cluster worker, and the
//! router behind it — goes through ONE entry point:
//! [`Server::submit`] takes a [`SubmitRequest`] (batch key, priority
//! class, optional deadline, image) plus a caller-owned reply channel
//! and returns a [`SubmitOutcome`]. Overload is an explicit
//! [`SubmitOutcome::Shed`], never an error string and never a silent
//! drop, so every tier can relay a structured overload response.
//!
//! The executor is abstracted behind [`BatchExecutor`] so the pipeline
//! is testable with a closure/mock; production wires it to any
//! [`InferenceBackend`] via [`BackendExecutor`] — the pure-Rust
//! [`crate::backend::reference::ReferenceBackend`] in every build,
//! PJRT (`--features pjrt`) through [`pjrt_executor`].

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::batch_manager::{Admission, BatchManager, Priority};
use super::metrics::Metrics;
use crate::backend::{InferenceBackend, ModelOutput};
use crate::compress::{self, Codec, CodecId, SpillBuf};
use crate::faults::FaultInjector;
use crate::obs::ledger::{Ledger, LedgerCell};
use crate::obs::slo::{SloEngine, SloInput};
use crate::obs::{now_ns, FlightRecorder, TerminalKind, TraceRecord};
use crate::telemetry::{Telemetry, TelemetrySnapshot};
use crate::tensor::Tensor;
use crate::zebra::bandwidth::ELEM_BITS;

/// One submission: what to run and how urgently. `key` groups requests
/// that may share an executed batch (model, shape, codec — requests
/// with different keys never ride in one batch); `priority` picks the
/// admission/scheduling class; `deadline`, when set, flushes the batch
/// sooner than the server's window and counts a miss if it passes.
#[derive(Debug, Clone)]
pub struct SubmitRequest {
    pub key: u64,
    pub priority: Priority,
    pub deadline: Option<Duration>,
    /// Edge-assigned trace id (0 = untraced). Rides into
    /// flight-recorder events even when the request isn't sampled.
    pub trace_id: u64,
    /// Sampled: the server assembles a [`TraceRecord`] (queue wait,
    /// batch assembly, execution, per-layer prune/encode) and returns
    /// it on [`Response::trace`].
    pub trace: bool,
    pub image: Tensor,
}

impl SubmitRequest {
    /// Defaults: key 0, `Normal` priority, no explicit deadline,
    /// untraced.
    pub fn new(image: Tensor) -> SubmitRequest {
        SubmitRequest {
            key: 0,
            priority: Priority::Normal,
            deadline: None,
            trace_id: 0,
            trace: false,
            image,
        }
    }

    pub fn with_key(mut self, key: u64) -> SubmitRequest {
        self.key = key;
        self
    }

    pub fn with_priority(mut self, p: Priority) -> SubmitRequest {
        self.priority = p;
        self
    }

    pub fn with_deadline(mut self, d: Duration) -> SubmitRequest {
        self.deadline = Some(d);
        self
    }

    /// Attach an edge-assigned trace id; `sampled` turns on span
    /// assembly for this request.
    pub fn with_trace(mut self, id: u64, sampled: bool) -> SubmitRequest {
        self.trace_id = id;
        self.trace = sampled;
        self
    }
}

/// What [`Server::submit`] did with a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Admitted under `id`; the response arrives on the reply channel.
    Enqueued { id: u64 },
    /// Refused by the class's admission cap (`queued` = depth at
    /// refusal). Nothing will arrive on the reply channel; the caller
    /// owes its client a structured overload response.
    Shed { priority: Priority, queued: usize },
    /// The server is shutting down; nothing new is accepted.
    Closed,
}

impl SubmitOutcome {
    /// The assigned request id, when admitted.
    pub fn id(&self) -> Option<u64> {
        match self {
            SubmitOutcome::Enqueued { id } => Some(*id),
            _ => None,
        }
    }
}

/// One admitted request riding through the batch manager.
pub struct Request {
    pub id: u64,
    pub image: Tensor,
    pub enqueued: Instant,
    /// Edge-assigned trace id (0 = untraced).
    pub trace_id: u64,
    /// Sampled: assemble and return a [`TraceRecord`].
    pub traced: bool,
    pub reply: Sender<Response>,
}

/// The response: logits + the request's bandwidth accounting.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Class logits for this image.
    pub logits: Vec<f32>,
    pub predicted: usize,
    /// Eq. 2–3 accounting for this image's activation spills.
    pub dense_bytes: u64,
    pub stored_bytes: u64,
    pub index_bytes: u64,
    /// This request's share of the `.zspill` frame bytes produced for
    /// cross-node spill shipping (0 unless the server ships spills).
    pub spill_frame_bytes: u64,
    pub latency: Duration,
    /// Sampled requests only: the server-side spans (queue wait, batch
    /// assembly, execution with batch-mates count, per-layer
    /// prune/encode with zero-block permille). Callers up the stack
    /// (cluster worker, router, client) append their own spans.
    pub trace: Option<TraceRecord>,
}

impl Response {
    pub fn reduction_pct(&self) -> f64 {
        super::metrics::reduction_pct_of(
            self.dense_bytes,
            self.stored_bytes,
            self.index_bytes,
        )
    }
}

/// Runs one padded batch tensor, returns logits + masks.
pub trait BatchExecutor: Send + Sync {
    /// `x` is `(exec_size, 3, H, W)`; returns outputs for all slots.
    fn execute(&self, x: &Tensor) -> Result<ModelOutput>;
    /// Batch sizes this executor supports, ascending.
    fn batch_sizes(&self) -> Vec<usize>;
    /// Image spatial size.
    fn image_hw(&self) -> usize;
    /// Worker threads the underlying compute hot path uses per
    /// execution (`--threads` / `ZEBRA_THREADS` on the reference
    /// backend). Recorded in [`Metrics::exec_threads`] so every tier's
    /// metrics can report node parallelism.
    fn exec_threads(&self) -> usize {
        1
    }
}

/// Production executor: bridges any [`InferenceBackend`] onto the
/// batch manager's worker threads. Backends need not be `Send` (the
/// `xla` crate's PJRT handles are `Rc` + raw pointers), so the backend
/// is constructed on — and never leaves — ONE dedicated execution
/// thread; this handle talks to it over channels and is therefore
/// freely shareable with the batching workers.
pub struct BackendExecutor {
    tx: std::sync::Mutex<Sender<ExecJob>>,
    name: String,
    sizes: Vec<usize>,
    hw: usize,
    threads: usize,
}

struct ExecJob {
    x: Tensor,
    reply: Sender<Result<ModelOutput>>,
}

impl BackendExecutor {
    /// Spawn the execution thread: `init` runs there, builds the
    /// backend (loading/compiling every model variant up front so
    /// serving never hits a load stall mid-request), and startup
    /// errors propagate back to the caller.
    pub fn spawn<B, F>(init: F) -> Result<BackendExecutor>
    where
        B: InferenceBackend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = channel::<ExecJob>();
        let (ready_tx, ready_rx) = channel::<Result<BackendMeta>>();
        std::thread::spawn(move || backend_thread(init, rx, ready_tx));
        let (name, mut sizes, hw, threads) = ready_rx
            .recv()
            .context("backend thread died during startup")??;
        sizes.sort_unstable();
        anyhow::ensure!(!sizes.is_empty(), "backend {name} exports no batch sizes");
        Ok(BackendExecutor {
            tx: std::sync::Mutex::new(tx),
            name,
            sizes,
            hw,
            threads,
        })
    }

    /// Which backend this executor runs ("reference", "pjrt", ...).
    pub fn backend_name(&self) -> &str {
        &self.name
    }
}

/// Startup metadata the backend thread reports: name, batch sizes,
/// image size, compute threads.
type BackendMeta = (String, Vec<usize>, usize, usize);

fn backend_thread<B, F>(
    init: F,
    rx: Receiver<ExecJob>,
    ready: Sender<Result<BackendMeta>>,
) where
    B: InferenceBackend,
    F: FnOnce() -> Result<B>,
{
    let backend = match init() {
        Ok(b) => {
            let meta = (
                b.name().to_string(),
                b.batch_sizes(),
                b.image_hw(),
                b.exec_threads(),
            );
            let _ = ready.send(Ok(meta));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        let _ = job.reply.send(backend.execute(&job.x));
    }
}

impl BatchExecutor for BackendExecutor {
    fn execute(&self, x: &Tensor) -> Result<ModelOutput> {
        let (reply, rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(ExecJob { x: x.clone(), reply })
            .map_err(|_| anyhow!("{} executor thread is gone", self.name))?;
        rx.recv()
            .with_context(|| format!("{} executor dropped the job", self.name))?
    }
    fn batch_sizes(&self) -> Vec<usize> {
        self.sizes.clone()
    }
    fn image_hw(&self) -> usize {
        self.hw
    }
    fn exec_threads(&self) -> usize {
        self.threads
    }
}

/// [`BackendExecutor`] over the pure-Rust reference backend (always
/// available — this is what the default build serves with).
pub fn reference_executor(
    spec: crate::backend::reference::RefSpec,
) -> Result<BackendExecutor> {
    BackendExecutor::spawn(move || {
        crate::backend::reference::ReferenceBackend::new(spec)
    })
}

/// [`reference_executor`] with the node's bandwidth [`Ledger`]
/// attached: every executed batch routes through the capture-encoded
/// path and records dense/encoded bytes and zero blocks into the
/// ledger's per-layer cells.
pub fn reference_executor_with_ledger(
    spec: crate::backend::reference::RefSpec,
    ledger: Arc<Ledger>,
) -> Result<BackendExecutor> {
    BackendExecutor::spawn(move || {
        let mut b = crate::backend::reference::ReferenceBackend::new(spec)?;
        b.attach_ledger(&ledger);
        Ok(b)
    })
}

/// [`BackendExecutor`] over the PJRT runtime: eagerly compiles every
/// exported batch variant of `key` from `artifacts` on the execution
/// thread (PJRT state is `!Send`).
#[cfg(feature = "pjrt")]
pub fn pjrt_executor(
    artifacts: std::path::PathBuf,
    key: &str,
) -> Result<BackendExecutor> {
    let key = key.to_string();
    BackendExecutor::spawn(move || {
        crate::runtime::PjrtBackend::new(&artifacts, &key)
    })
}

/// Spill-shipping configuration: which codec frames each executed
/// batch as a `.zspill` for a peer coordinator node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShipSpills {
    pub codec: CodecId,
    /// Block size for block-structured codecs (must divide the image
    /// H/W); ignored by parameterless codecs.
    pub block: u16,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Flush window: no admitted request waits longer than this for
    /// its batch to start executing (`--flush-us`).
    pub max_wait: Duration,
    /// Executor worker threads (1 is right for the CPU PJRT client).
    pub workers: usize,
    /// Queue capacity the per-class admission caps are cut from:
    /// `Low` sheds at 50% of it, `Normal` at 85%, `High` when full.
    pub max_queue: usize,
    /// Cap on real items per executed batch (`--max-batch`; 0 = the
    /// backend's largest exported size). Dynamic sizing can cut
    /// batches further when observed executor latency demands it.
    pub max_batch: usize,
    /// When set, each executed batch tensor is also encoded and framed
    /// as a versioned `.zspill` — the bytes a multi-node deployment
    /// ships to a peer — metered per worker through one reused
    /// [`SpillBuf`] (no per-spill allocation on the request path).
    pub ship_spills: Option<ShipSpills>,
    /// Where the framed `.zspill` bytes actually go. With
    /// `ship_spills` set and a sink present, every executed batch's
    /// frame is sent here (the cluster worker forwards them upstream
    /// as `SpillShip` wire frames); without a sink the frames are
    /// metered but not materialized, preserving the PR 1 behavior.
    pub spill_sink: Option<Sender<Vec<u8>>>,
    /// Flight recorder (`--flight-dir`): sheds and deadline misses
    /// record terminal events (and dump the ring when a directory is
    /// configured); completed sampled traces are ring-buffered for
    /// post-mortems. `None` = no recording.
    pub flight: Option<Arc<FlightRecorder>>,
    /// Bandwidth ledger. When present *and* spill shipping is on, the
    /// worker loop records each shipped batch into the ledger's
    /// `("spill_out", <codec>)` cell; attach the same ledger to the
    /// backend (see `reference_executor_with_ledger`) for the
    /// per-layer cells. Its snapshot rides the node's telemetry
    /// ([`Server::obs_telemetry`]).
    pub ledger: Option<Arc<Ledger>>,
    /// SLO engine: the node's sampler feeds it
    /// ([`Server::slo_input`]) and its status rides the telemetry
    /// snapshot next to the ledger. `None` = no objectives evaluated.
    pub slo: Option<Arc<SloEngine>>,
    /// Deterministic fault injector (`--chaos` / `ZEBRA_CHAOS`,
    /// `rust/docs/robustness.md`). The worker loop honors the
    /// `worker.stall` / `worker.slow` sites around execution and the
    /// `spill.ship` site on shipped `.zspill` frames (with a decode
    /// self-check + dense re-encode fallback); the cluster wire layer
    /// reads the same injector for its own sites. `None` = no faults.
    pub faults: Option<Arc<FaultInjector>>,
    /// Read timeout applied by the TCP wire layer to this node's
    /// inbound connections (`--io-timeout-ms`; `None` = unbounded).
    /// Lives here so `WorkerNode::attach` can read it off a started
    /// server without another plumbing path.
    pub io_timeout: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(2),
            workers: 1,
            max_queue: 1024,
            max_batch: 0,
            ship_spills: None,
            spill_sink: None,
            flight: None,
            ledger: None,
            slo: None,
            faults: None,
            io_timeout: Some(Duration::from_secs(30)),
        }
    }
}

/// The coordinator server.
pub struct Server {
    manager: Arc<BatchManager<Request>>,
    pub metrics: Arc<Metrics>,
    /// Wall-time/byte accounting for the serving hot loop. Every batch
    /// records a `serve.batch` umbrella scope plus `serve.assemble`,
    /// `serve.ship`, `serve.execute` and `serve.respond` sub-stages, so
    /// `snapshot().coverage("serve.batch", ...)` attributes (nearly)
    /// all worker wall time.
    pub telemetry: Arc<Telemetry>,
    /// The flight recorder, when configured (shared with the workers).
    pub flight: Option<Arc<FlightRecorder>>,
    /// The node's bandwidth ledger, when configured.
    pub ledger: Option<Arc<Ledger>>,
    /// The node's SLO engine, when configured.
    pub slo: Option<Arc<SloEngine>>,
    /// The node's fault injector, when chaos is configured (shared
    /// with the wire layer for its `wire.worker` / crash sites).
    pub faults: Option<Arc<FaultInjector>>,
    /// Read timeout the wire layer applies to inbound connections.
    pub io_timeout: Option<Duration>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    pub fn start(exec: Arc<dyn BatchExecutor>, cfg: ServerConfig) -> Server {
        let telemetry = Arc::new(Telemetry::new());
        // The manager watches the executor stage: observed per-slot
        // latency drives its dynamic batch-size target.
        let manager = Arc::new(
            BatchManager::new(exec.batch_sizes(), cfg.max_wait, cfg.max_queue)
                .with_max_batch(cfg.max_batch)
                .with_exec_stage(telemetry.stage("serve.execute")),
        );
        let metrics = Arc::new(Metrics::new());
        // Gauge, not counter: how parallel this node's compute is —
        // surfaced through metrics snapshots so cluster tooling can
        // report per-worker thread counts.
        metrics
            .exec_threads
            .store(exec.exec_threads() as u64, Ordering::Relaxed);
        // Resolve the shipping codec once, up front: a bad codec id /
        // block combination must fail at startup, not in a worker.
        let shipper: Option<Arc<dyn Codec>> = cfg.ship_spills.map(|s| {
            let codec = compress::from_id(s.codec, s.block)
                .expect("ship_spills names an invalid codec");
            let needs_block = compress::registry()
                .iter()
                .any(|r| r.id == s.codec && r.needs_block);
            assert!(
                !needs_block || exec.image_hw() % s.block as usize == 0,
                "ship_spills block {} does not divide image size {}",
                s.block,
                exec.image_hw()
            );
            Arc::from(codec)
        });
        // Shipped-batch bandwidth cell: one per node, shared by every
        // worker (LedgerCell::record is a handful of relaxed atomics).
        let ship_cell: Option<Arc<LedgerCell>> = match (&cfg.ledger, &cfg.ship_spills)
        {
            (Some(ledger), Some(s)) => {
                Some(ledger.cell("spill_out", s.codec.name()))
            }
            _ => None,
        };
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let b = manager.clone();
            let m = metrics.clone();
            let e = exec.clone();
            let s = shipper.clone();
            let sink = cfg.spill_sink.clone();
            let t = telemetry.clone();
            let f = cfg.flight.clone();
            let lc = ship_cell.clone();
            let fi = cfg.faults.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(b, e, m, s, sink, t, f, lc, fi)
            }));
        }
        Server {
            manager,
            metrics,
            telemetry,
            flight: cfg.flight,
            ledger: cfg.ledger,
            slo: cfg.slo,
            faults: cfg.faults,
            io_timeout: cfg.io_timeout,
            workers,
            next_id: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Apply a brownout level (0 = none) from the SLO sampler: the
    /// batch manager progressively shrinks the Low/Normal admission
    /// caps (High is never browned out), shedding best-effort load
    /// first while the burn lasts.
    pub fn set_brownout(&self, level: u32) {
        self.manager.set_pressure(level);
    }

    /// The brownout level currently applied to admission.
    pub fn brownout_level(&self) -> u32 {
        self.manager.pressure()
    }

    /// The node's telemetry snapshot with the observability planes
    /// folded in: the ledger snapshot and the SLO status ride as
    /// synthetic `ledger.*` / `slo.*` stages, so they travel inside
    /// the existing v3 `MetricsResp` telemetry block with no wire
    /// format change. Peers strip the prefixes back out with
    /// [`LedgerSnapshot::from_telemetry`] /
    /// [`crate::obs::slo::parse_slo`].
    pub fn obs_telemetry(&self) -> TelemetrySnapshot {
        let mut snap = self.telemetry.snapshot();
        if let Some(ledger) = &self.ledger {
            ledger.snapshot().to_stages(&mut snap);
        }
        if let Some(slo) = &self.slo {
            slo.to_stages(&mut snap);
        }
        snap
    }

    /// Assemble the [`SloInput`] counters the node's SLO sampler feeds
    /// to [`SloEngine::observe`] — everything from this server's own
    /// metrics and ledger; no wall clock (the caller supplies
    /// `now_ms` from its own monotonic origin).
    pub fn slo_input(&self) -> SloInput {
        let m = &self.metrics;
        let (dense, encoded) = match &self.ledger {
            Some(l) => {
                let t = l.snapshot().total();
                (t.dense_bytes, t.encoded_bytes)
            }
            None => (0, 0),
        };
        SloInput {
            requests: m.requests.load(Ordering::Relaxed),
            responses: m.responses.load(Ordering::Relaxed),
            shed: m.shed_total(),
            deadline_miss: m.deadline_miss.load(Ordering::Relaxed),
            p99_latency_us: m.latency_percentile_us(0.99),
            dense_bytes: dense,
            encoded_bytes: encoded,
        }
    }

    /// THE submission entry point — in-process callers, the TCP
    /// worker, and the router all go through here. The response (if
    /// admitted) arrives on `reply`; the outcome says immediately
    /// whether the request was enqueued, shed by its class's admission
    /// cap, or refused because the server is closing.
    pub fn submit(
        &self,
        req: SubmitRequest,
        reply: Sender<Response>,
    ) -> SubmitOutcome {
        let SubmitRequest { key, priority, deadline, trace_id, trace, image } =
            req;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let admission = self.manager.push(
            key,
            priority,
            deadline,
            Request {
                id,
                image,
                enqueued: Instant::now(),
                trace_id,
                traced: trace,
                reply,
            },
        );
        match admission {
            Admission::Accepted => {
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.metrics
                    .queue_depth
                    .store(self.manager.depth() as u64, Ordering::Relaxed);
                SubmitOutcome::Enqueued { id }
            }
            Admission::Shed { queued } => {
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.metrics.count_shed(priority);
                if let Some(f) = &self.flight {
                    f.record_event(
                        trace_id,
                        TerminalKind::shed(priority),
                        &format!(
                            "{} class over its admission cap \
                             ({queued} queued)",
                            priority.name()
                        ),
                    );
                }
                SubmitOutcome::Shed { priority, queued }
            }
            Admission::Closed => SubmitOutcome::Closed,
        }
    }

    /// Blocking convenience: submit with defaults and wait. Shed and
    /// shutdown outcomes surface as errors.
    pub fn classify(&self, image: Tensor) -> Result<Response> {
        let (tx, rx) = channel();
        match self.submit(SubmitRequest::new(image), tx) {
            SubmitOutcome::Enqueued { .. } => {
                rx.recv().context("server dropped the request")
            }
            SubmitOutcome::Shed { priority, queued } => Err(anyhow!(
                "request shed: {} class over its admission cap \
                 ({queued} queued)",
                priority.name()
            )),
            SubmitOutcome::Closed => Err(anyhow!("server is shut down")),
        }
    }

    /// Current queue depth (the backpressure gauge).
    pub fn queue_depth(&self) -> usize {
        self.manager.depth()
    }

    /// Stop accepting work and let the workers drain, without waiting
    /// for them (shared-handle shutdown — what `cluster::WorkerNode`
    /// calls through its `Arc<Server>`). Pending requests still
    /// complete; subsequent submits return [`SubmitOutcome::Closed`].
    pub fn close(&self) {
        self.manager.close();
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.manager.close();
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    manager: Arc<BatchManager<Request>>,
    exec: Arc<dyn BatchExecutor>,
    metrics: Arc<Metrics>,
    shipper: Option<Arc<dyn Codec>>,
    spill_sink: Option<Sender<Vec<u8>>>,
    telemetry: Arc<Telemetry>,
    flight: Option<Arc<FlightRecorder>>,
    ship_cell: Option<Arc<LedgerCell>>,
    faults: Option<Arc<FaultInjector>>,
) {
    let hw = exec.image_hw();
    // Stage handles resolved once — recording inside the loop is two
    // relaxed atomics, no lock. `serve.batch` is the umbrella scope
    // (batch in hand -> responses sent); the sub-stages must account
    // for >= 95% of it (pinned by the loopback telemetry test).
    let st_batch = telemetry.stage("serve.batch");
    let st_assemble = telemetry.stage("serve.assemble");
    let st_ship = telemetry.stage("serve.ship");
    let st_execute = telemetry.stage("serve.execute");
    let st_respond = telemetry.stage("serve.respond");
    // One SpillBuf per worker: spill-shipping reuses its arenas across
    // every batch this worker ever executes.
    let mut spill_buf = SpillBuf::new();
    while let Some(batch) = manager.next_batch() {
        // Time starts when a batch is in hand — queue wait is the
        // manager's, not this worker's.
        let _whole = st_batch.time();
        let n = batch.items.len();
        let exec_size = batch.exec_size;
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.batched_items.fetch_add(n as u64, Ordering::Relaxed);
        metrics
            .padded_slots
            .fetch_add(batch.padding() as u64, Ordering::Relaxed);
        metrics
            .deadline_miss
            .fetch_add(batch.deadline_misses as u64, Ordering::Relaxed);
        metrics
            .queue_depth
            .store(manager.depth() as u64, Ordering::Relaxed);
        if batch.deadline_misses > 0 {
            if let Some(f) = &flight {
                f.record_event(
                    0,
                    TerminalKind::DeadlineMiss,
                    &format!(
                        "{} of {n} batch items past their deadline at \
                         flush",
                        batch.deadline_misses
                    ),
                );
            }
        }
        // Trace timestamps are taken only when this batch carries a
        // sampled request — untraced batches never touch the wall
        // clock beyond the telemetry Instants they already pay for.
        let any_traced = batch.items.iter().any(|r| r.traced);
        let batch_start = Instant::now();
        let batch_start_ns = if any_traced { now_ns() } else { 0 };
        // Assemble the padded batch tensor.
        let t_assemble = st_assemble.time();
        let mut x = Tensor::zeros(&[exec_size, 3, hw, hw]);
        let per = 3 * hw * hw;
        for (i, req) in batch.items.iter().enumerate() {
            let src = req.image.data();
            x.data_mut()[i * per..(i + 1) * per].copy_from_slice(src);
        }
        drop(t_assemble);
        let assemble_end_ns = if any_traced { now_ns() } else { 0 };
        // Cross-node shipping: encode the batch into the worker's
        // reused SpillBuf and meter the exact `.zspill` frame size a
        // peer node receives. Without a sink the frame is never
        // materialized (frame_len predicts to_bytes exactly); with one
        // — the cluster worker's upstream pump — the frame bytes are
        // built once here and handed off, keeping the TCP write off
        // the request path.
        let frame_share = match &shipper {
            Some(codec) => {
                let _t = st_ship.time();
                codec.encode_into(&x, &mut spill_buf);
                let len = spill_buf.view().frame_len() as u64;
                if let Some(cell) = &ship_cell {
                    // Payload + index only (no wire header): the
                    // bandwidth the encoding actually saves, matching
                    // the per-layer cells. Blocks/zeros stay 0 — the
                    // shape of the shipped frame is codec-specific.
                    cell.record(
                        (x.data().len() * 4) as u64,
                        spill_buf.total_bytes() as u64,
                        0,
                        0,
                    );
                }
                st_ship.add_bytes(len);
                metrics
                    .shipped_spill_bytes
                    .fetch_add(len, Ordering::Relaxed);
                if let Some(sink) = &spill_sink {
                    // A gone sink (upstream pump shut down) is not a
                    // serving error; the metering above still counts.
                    let mut bytes = spill_buf.view().to_bytes();
                    // Chaos `spill.corrupt`: a bit flip *after* the
                    // checksum was computed — the shape of silent disk
                    // or DMA corruption. The worker still holds the
                    // dense batch tensor, so a failed decode self-check
                    // downgrades to a structured SpillCorrupt outcome
                    // and re-encodes the same data dense — responses
                    // are never on this path, so logits are unaffected
                    // (`docs/robustness.md`).
                    let corrupted = faults
                        .as_ref()
                        .map(|fi| fi.corrupt_spill(&mut bytes))
                        .unwrap_or(false);
                    if corrupted && compress::decode_frame(&bytes).is_err() {
                        if let Some(f) = &flight {
                            f.record_event(
                                0,
                                TerminalKind::SpillCorrupt,
                                &format!(
                                    "spill frame failed decode self-check \
                                     ({} bytes); re-shipping dense",
                                    bytes.len()
                                ),
                            );
                        }
                        bytes = compress::from_id(CodecId::Dense, 1)
                            .expect("dense codec always constructs")
                            .encode(&x)
                            .to_bytes();
                    }
                    let _ = sink.send(bytes);
                }
                len / exec_size.max(1) as u64
            }
            None => 0,
        };
        // Chaos `worker.stall`: a fixed pause before execution (GC
        // pause / page-fault storm shape).
        if let Some(d) = faults.as_ref().and_then(|fi| fi.stall()) {
            std::thread::sleep(d);
        }
        let exec_start_ns = if any_traced { now_ns() } else { 0 };
        let exec_t0 = Instant::now();
        let result = {
            let _t = st_execute.time();
            exec.execute(&x)
        };
        // Chaos `worker.slow`: stretch the measured execution by the
        // drawn multiplier (thermal throttling / noisy-neighbor shape)
        // — inside the telemetry window would distort the batch
        // manager's latency-driven sizing, so the stretch lands after
        // the stage scope closes.
        if let Some(mult) = faults.as_ref().and_then(|fi| fi.slow_mult()) {
            std::thread::sleep(exec_t0.elapsed() * mult.saturating_sub(1));
        }
        let exec_end_ns = if any_traced { now_ns() } else { 0 };
        match result {
            Ok(out) => {
                let _t = st_respond.time();
                let trace_ctx = any_traced.then_some(BatchTrace {
                    batch_start,
                    batch_start_ns,
                    assemble_end_ns,
                    exec_start_ns,
                    exec_end_ns,
                    mates: n,
                });
                respond(
                    batch.items,
                    &out,
                    &metrics,
                    frame_share,
                    trace_ctx,
                    flight.as_deref(),
                );
            }
            Err(e) => {
                // Failed batch: drop the reply channels; callers see a
                // RecvError. The `failed` counter keeps the
                // served+shed+failed accounting gap-free.
                metrics.failed.fetch_add(n as u64, Ordering::Relaxed);
                eprintln!("[server] batch of {n} failed: {e:#}");
            }
        }
    }
}

/// Batch-level timestamps for trace assembly, captured by the worker
/// loop only when the batch carries a sampled request.
struct BatchTrace {
    batch_start: Instant,
    batch_start_ns: u64,
    assemble_end_ns: u64,
    exec_start_ns: u64,
    exec_end_ns: u64,
    /// Real items sharing the executed batch (the span's aux).
    mates: usize,
}

fn respond(
    items: Vec<Request>,
    out: &ModelOutput,
    metrics: &Metrics,
    spill_frame_bytes: u64,
    trace_ctx: Option<BatchTrace>,
    flight: Option<&FlightRecorder>,
) {
    let classes = out.logits.shape()[1];
    for (i, req) in items.into_iter().enumerate() {
        let logits =
            out.logits.data()[i * classes..(i + 1) * classes].to_vec();
        let predicted = argmax(&logits);
        let mut rec = (req.traced && trace_ctx.is_some())
            .then(|| TraceRecord::new(req.trace_id));
        // Per-image bandwidth accounting from this request's mask rows
        // (Eq. 2: kept blocks * B^2 * 4 bytes; Eq. 3: 1 bit per block).
        // The same sweep yields the per-layer zero-block permille the
        // layer spans carry.
        let (mut dense, mut stored, mut index) = (0u64, 0u64, 0u64);
        // Per-layer execution time is split from the backend's
        // measured layer_nanos; layers the backend didn't time get
        // zero-length spans anchored at the execution start.
        let mut layer_off_ns = trace_ctx
            .as_ref()
            .map(|c| c.exec_start_ns)
            .unwrap_or(0);
        for (mi, m) in out.masks.iter().enumerate() {
            let s = m.shape(); // (batch, C, H/b, W/b)
            let blocks: usize = s[1] * s[2] * s[3];
            let row = &m.data()[i * blocks..(i + 1) * blocks];
            let kept: usize = row.iter().filter(|&&v| v != 0.0).count();
            let elems_per_block =
                out.block_elems.get(mi).copied().unwrap_or(16);
            let bytes_per_block = elems_per_block * ELEM_BITS / 8;
            let layer_stored = (kept * bytes_per_block) as u64;
            dense += (blocks * bytes_per_block) as u64;
            stored += layer_stored;
            index += blocks.div_ceil(8) as u64;
            if let Some(rec) = rec.as_mut() {
                let zero_permille = if blocks > 0 {
                    ((blocks - kept) * 1000 / blocks) as u64
                } else {
                    0
                };
                let dur = out.layer_nanos.get(mi).copied().unwrap_or(0);
                rec.push(
                    &format!("layer.{mi}.prune_encode"),
                    layer_off_ns,
                    layer_off_ns + dur,
                    layer_stored,
                    zero_permille,
                );
                layer_off_ns += dur;
            }
        }
        metrics.dense_bytes.fetch_add(dense, Ordering::Relaxed);
        metrics.stored_bytes.fetch_add(stored, Ordering::Relaxed);
        metrics.index_bytes.fetch_add(index, Ordering::Relaxed);
        let latency = req.enqueued.elapsed();
        metrics.record_latency_us(latency.as_micros() as u64);
        let trace = match (rec, &trace_ctx) {
            (Some(mut rec), Some(ctx)) => {
                let wait_ns = ctx
                    .batch_start
                    .saturating_duration_since(req.enqueued)
                    .as_nanos() as u64;
                rec.push(
                    "queue.wait",
                    ctx.batch_start_ns.saturating_sub(wait_ns),
                    ctx.batch_start_ns,
                    0,
                    0,
                );
                rec.push(
                    "serve.assemble",
                    ctx.batch_start_ns,
                    ctx.assemble_end_ns,
                    req.image.data().len() as u64 * 4,
                    0,
                );
                rec.push(
                    "serve.execute",
                    ctx.exec_start_ns,
                    ctx.exec_end_ns,
                    stored + index,
                    ctx.mates as u64,
                );
                if let Some(f) = flight {
                    f.record_trace(rec.clone());
                }
                Some(rec)
            }
            _ => None,
        };
        let _ = req.reply.send(Response {
            id: req.id,
            logits,
            predicted,
            dense_bytes: dense,
            stored_bytes: stored,
            index_bytes: index,
            spill_frame_bytes,
            latency,
            trace,
        });
    }
}

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{forall, Config};

    /// Mock model: "logits" = [mean, -mean]; one 2x2-blocked mask layer
    /// where a block is kept iff the image mean > 0.5.
    struct MockExec {
        hw: usize,
        sizes: Vec<usize>,
        delay: Duration,
    }

    impl BatchExecutor for MockExec {
        fn execute(&self, x: &Tensor) -> Result<ModelOutput> {
            std::thread::sleep(self.delay);
            let b = x.shape()[0];
            let per = 3 * self.hw * self.hw;
            let mut logits = Vec::with_capacity(b * 2);
            let mut mask = Vec::new();
            for i in 0..b {
                let mean: f32 = x.data()[i * per..(i + 1) * per]
                    .iter()
                    .sum::<f32>()
                    / per as f32;
                logits.extend_from_slice(&[mean, -mean]);
                let kept = if mean > 0.5 { 1.0 } else { 0.0 };
                mask.extend(std::iter::repeat(kept).take(4)); // C=1, 2x2 grid
            }
            Ok(ModelOutput {
                logits: Tensor::from_vec(&[b, 2], logits),
                masks: vec![Tensor::from_vec(&[b, 1, 2, 2], mask)],
                block_elems: vec![4],
                layer_nanos: vec![100],
            })
        }
        fn batch_sizes(&self) -> Vec<usize> {
            self.sizes.clone()
        }
        fn image_hw(&self) -> usize {
            self.hw
        }
    }

    fn image(hw: usize, fill: f32) -> Tensor {
        Tensor::from_vec(&[3, hw, hw], vec![fill; 3 * hw * hw])
    }

    /// Submit with defaults, panicking unless admitted — the test-side
    /// stand-in for the old `submit(image) -> Receiver` convenience.
    fn submit_ok(srv: &Server, image: Tensor) -> Receiver<Response> {
        let (tx, rx) = channel();
        match srv.submit(SubmitRequest::new(image), tx) {
            SubmitOutcome::Enqueued { .. } => rx,
            other => panic!("expected admission, got {other:?}"),
        }
    }

    #[test]
    fn classify_routes_logits_back() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1, 4],
            delay: Duration::ZERO,
        });
        let srv = Server::start(exec, ServerConfig::default());
        let r = srv.classify(image(4, 0.9)).unwrap();
        assert_eq!(r.predicted, 0, "positive mean -> class 0");
        assert!((r.logits[0] - 0.9).abs() < 1e-5);
        let r2 = srv.classify(image(4, -0.9)).unwrap();
        assert_eq!(r2.predicted, 1);
        srv.shutdown();
    }

    #[test]
    fn bandwidth_accounting_per_request() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let srv = Server::start(exec, ServerConfig::default());
        // Bright image: all 4 blocks kept -> stored == dense.
        let r = srv.classify(image(4, 0.9)).unwrap();
        assert_eq!(r.dense_bytes, 4 * 4 * 4); // 4 blocks * 4 elems * 4B
        assert_eq!(r.stored_bytes, r.dense_bytes);
        // Dark image: everything pruned -> only index bytes remain.
        let r2 = srv.classify(image(4, 0.1)).unwrap();
        assert_eq!(r2.stored_bytes, 0);
        assert_eq!(r2.index_bytes, 1);
        assert!(r2.reduction_pct() > 95.0);
        srv.shutdown();
    }

    #[test]
    fn ships_spill_frames_when_configured() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let srv = Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::ZERO,
                max_queue: 16,
                ship_spills: Some(ShipSpills {
                    codec: CodecId::ZeroBlock,
                    block: 2,
                }),
                ..ServerConfig::default()
            },
        );
        let r = srv.classify(image(4, 0.9)).unwrap();
        assert!(r.spill_frame_bytes > 0, "shipping must meter frame bytes");
        let shipped =
            srv.metrics.shipped_spill_bytes.load(Ordering::Relaxed);
        assert!(shipped >= r.spill_frame_bytes);
        // A second request reuses the worker's SpillBuf and ships an
        // identically-sized frame (same image geometry).
        let r2 = srv.classify(image(4, 0.9)).unwrap();
        assert_eq!(r2.spill_frame_bytes, r.spill_frame_bytes);
        assert_eq!(
            srv.metrics.shipped_spill_bytes.load(Ordering::Relaxed),
            2 * shipped
        );
        srv.shutdown();
    }

    #[test]
    fn shipping_disabled_reports_zero_frame_bytes() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let srv = Server::start(exec, ServerConfig::default());
        let r = srv.classify(image(4, 0.5)).unwrap();
        assert_eq!(r.spill_frame_bytes, 0);
        assert_eq!(
            srv.metrics.shipped_spill_bytes.load(Ordering::Relaxed),
            0
        );
        srv.shutdown();
    }

    #[test]
    fn telemetry_accounts_the_worker_wall_time() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1, 4],
            delay: Duration::from_millis(4),
        });
        let srv = Server::start(exec, ServerConfig::default());
        for _ in 0..6 {
            srv.classify(image(4, 0.9)).unwrap();
        }
        let snap = srv.telemetry.snapshot();
        let cov = snap
            .coverage(
                "serve.batch",
                &[
                    "serve.assemble",
                    "serve.ship",
                    "serve.execute",
                    "serve.respond",
                ],
            )
            .expect("serve.batch must have recorded time");
        assert!(
            cov >= 0.95,
            "sub-stages cover only {:.1}% of the hot loop",
            100.0 * cov
        );
        assert!(snap.get("serve.execute").calls >= 1);
        assert_eq!(snap.get("serve.batch").calls, snap.get("serve.execute").calls);
        // No shipping configured: the stage exists but never moved bytes.
        assert_eq!(snap.get("serve.ship").bytes, 0);
        srv.shutdown();
    }

    #[test]
    fn batches_fill_under_concurrent_load() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1, 4, 8],
            delay: Duration::from_millis(3),
        });
        let srv = Arc::new(Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::from_millis(10),
                ..ServerConfig::default()
            },
        ));
        let mut waiters = Vec::new();
        for _ in 0..32 {
            waiters.push(submit_ok(&srv, image(4, 0.7)));
        }
        for rx in waiters {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.predicted, 0);
        }
        assert!(
            srv.metrics.mean_batch() > 1.5,
            "batching should engage under load: mean {}",
            srv.metrics.mean_batch()
        );
        Arc::try_unwrap(srv).ok().map(|s| s.shutdown());
    }

    #[test]
    fn backpressure_sheds_when_full() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::from_millis(50),
        });
        let srv = Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::ZERO,
                max_queue: 2,
                ..ServerConfig::default()
            },
        );
        let mut receivers = Vec::new();
        let mut shed = None;
        for _ in 0..8 {
            let (tx, rx) = channel();
            match srv.submit(SubmitRequest::new(image(4, 0.5)), tx) {
                SubmitOutcome::Enqueued { .. } => receivers.push(rx),
                SubmitOutcome::Shed { priority, queued } => {
                    shed = Some((priority, queued));
                    break;
                }
                SubmitOutcome::Closed => panic!("server is not closed"),
            }
        }
        let (priority, queued) =
            shed.expect("expected a Shed outcome under backpressure");
        assert_eq!(priority, Priority::Normal);
        assert!(queued >= 2, "shed at depth {queued}");
        assert!(
            srv.metrics.shed_normal.load(Ordering::Relaxed) >= 1,
            "shed must be counted, never silent"
        );
        srv.shutdown();
    }

    #[test]
    fn low_class_sheds_before_high_class() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::from_millis(50),
        });
        let srv = Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::ZERO,
                max_queue: 8,
                ..ServerConfig::default()
            },
        );
        // Fill the Low slice of the queue, then one more Low: shed.
        // High still gets in at the same depth.
        let mut keep = Vec::new();
        let mut low_shed = false;
        for _ in 0..16 {
            let (tx, rx) = channel();
            let req =
                SubmitRequest::new(image(4, 0.5)).with_priority(Priority::Low);
            match srv.submit(req, tx) {
                SubmitOutcome::Enqueued { .. } => keep.push(rx),
                SubmitOutcome::Shed { priority, .. } => {
                    assert_eq!(priority, Priority::Low);
                    low_shed = true;
                    break;
                }
                SubmitOutcome::Closed => panic!("not closed"),
            }
        }
        assert!(low_shed, "Low must hit its cap");
        let (tx, rx) = channel();
        let req =
            SubmitRequest::new(image(4, 0.5)).with_priority(Priority::High);
        match srv.submit(req, tx) {
            SubmitOutcome::Enqueued { .. } => keep.push(rx),
            other => panic!("High must still be admitted, got {other:?}"),
        }
        assert!(srv.metrics.shed_low.load(Ordering::Relaxed) >= 1);
        assert_eq!(srv.metrics.shed_high.load(Ordering::Relaxed), 0);
        srv.shutdown();
    }

    #[test]
    fn submit_multiplexes_one_reply_channel() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let srv = Server::start(exec, ServerConfig::default());
        let (tx, rx) = channel();
        let mut want = std::collections::HashMap::new();
        for &fill in &[0.9f32, -0.9, 0.3] {
            let outcome =
                srv.submit(SubmitRequest::new(image(4, fill)), tx.clone());
            let id = outcome.id().expect("default queue must admit");
            want.insert(id, fill);
        }
        for _ in 0..want.len() {
            let r = rx.recv().unwrap();
            let fill = want.remove(&r.id).expect("unknown or duplicate id");
            assert!((r.logits[0] - fill).abs() < 1e-5);
        }
        assert!(want.is_empty(), "every id must be answered exactly once");
        srv.shutdown();
    }

    #[test]
    fn served_shed_failed_account_for_every_submit() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::from_millis(20),
        });
        let srv = Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::ZERO,
                max_queue: 4,
                ..ServerConfig::default()
            },
        );
        let mut receivers = Vec::new();
        let mut submitted = 0u64;
        for i in 0..24 {
            let p = Priority::from_u8((i % 3) as u8).unwrap();
            let (tx, rx) = channel();
            let req = SubmitRequest::new(image(4, 0.5)).with_priority(p);
            match srv.submit(req, tx) {
                SubmitOutcome::Enqueued { .. } => receivers.push(rx),
                SubmitOutcome::Shed { .. } => {}
                SubmitOutcome::Closed => panic!("not closed"),
            }
            submitted += 1;
        }
        // Drain every admitted request, then check the books balance.
        for rx in receivers {
            rx.recv().unwrap();
        }
        let m = &srv.metrics;
        let sheds = m.shed_low.load(Ordering::Relaxed)
            + m.shed_normal.load(Ordering::Relaxed)
            + m.shed_high.load(Ordering::Relaxed);
        assert_eq!(m.requests.load(Ordering::Relaxed), submitted);
        assert_eq!(
            m.responses.load(Ordering::Relaxed)
                + sheds
                + m.failed.load(Ordering::Relaxed),
            submitted,
            "served+shed+failed must account for every submit"
        );
        assert!(sheds > 0, "this load must overflow a queue of 4");
        srv.shutdown();
    }

    #[test]
    fn spill_sink_receives_the_metered_frames() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let (sink_tx, sink_rx) = channel();
        let srv = Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::ZERO,
                max_queue: 16,
                ship_spills: Some(ShipSpills {
                    codec: CodecId::ZeroBlock,
                    block: 2,
                }),
                spill_sink: Some(sink_tx),
                ..ServerConfig::default()
            },
        );
        let r = srv.classify(image(4, 0.9)).unwrap();
        let frame = sink_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("sink must receive the batch frame");
        // The sink gets exactly the bytes the metric counted, and they
        // parse as a valid `.zspill`.
        assert_eq!(frame.len() as u64, r.spill_frame_bytes);
        let view = compress::EncodedView::parse(&frame)
            .expect("shipped frame must be a valid .zspill");
        assert_eq!(view.codec, CodecId::ZeroBlock);
        srv.shutdown();
    }

    #[test]
    fn close_on_shared_handle_rejects_new_work() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let srv = Arc::new(Server::start(exec, ServerConfig::default()));
        let r = srv.classify(image(4, 0.9)).unwrap();
        assert_eq!(r.predicted, 0);
        srv.close();
        let (tx, _rx) = channel();
        assert_eq!(
            srv.submit(SubmitRequest::new(image(4, 0.9)), tx),
            SubmitOutcome::Closed
        );
    }

    #[test]
    fn distinct_keys_never_share_a_batch() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1, 8],
            delay: Duration::from_millis(2),
        });
        let srv = Arc::new(Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::from_millis(10),
                ..ServerConfig::default()
            },
        ));
        let mut waiters = Vec::new();
        for i in 0..16 {
            let (tx, rx) = channel();
            let req =
                SubmitRequest::new(image(4, 0.7)).with_key(i % 2);
            assert!(matches!(
                srv.submit(req, tx),
                SubmitOutcome::Enqueued { .. }
            ));
            waiters.push(rx);
        }
        for rx in waiters {
            rx.recv().unwrap();
        }
        // Two keys -> at least two batches even though 16 fits in 8+8.
        assert!(srv.metrics.batches.load(Ordering::Relaxed) >= 2);
        Arc::try_unwrap(srv).ok().map(|s| s.shutdown());
    }

    #[test]
    fn sampled_requests_return_a_full_trace() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::from_micros(200),
        });
        let flight = Arc::new(crate::obs::FlightRecorder::new(
            "unit", 8, None,
        ));
        let srv = Server::start(
            exec,
            ServerConfig {
                flight: Some(flight.clone()),
                ..ServerConfig::default()
            },
        );
        let (tx, rx) = channel();
        let req = SubmitRequest::new(image(4, 0.9))
            .with_trace(0xABCD_EF01_2345_6789, true);
        assert!(matches!(
            srv.submit(req, tx),
            SubmitOutcome::Enqueued { .. }
        ));
        let r = rx.recv().unwrap();
        let rec = r.trace.expect("sampled request must carry a trace");
        assert_eq!(rec.trace_id, 0xABCD_EF01_2345_6789);
        for label in ["queue.wait", "serve.assemble", "serve.execute"] {
            assert!(rec.span(label).is_some(), "missing span {label}");
        }
        let layers = rec.spans_with_prefix("layer.");
        assert_eq!(layers.len(), 1, "one mask layer -> one layer span");
        assert_eq!(layers[0].label, "layer.0.prune_encode");
        assert_eq!(layers[0].aux, 0, "bright image keeps every block");
        assert!(layers[0].bytes > 0, "kept blocks store bytes");
        let exec_span = rec.span("serve.execute").unwrap();
        assert_eq!(exec_span.aux, 1, "one batch-mate");
        assert!(exec_span.duration_ns() > 0, "mock sleeps 200us");
        // The completed trace landed in the flight ring too.
        assert!(flight
            .entries()
            .iter()
            .any(|e| matches!(e,
                crate::obs::FlightEntry::Trace(t)
                    if t.trace_id == rec.trace_id)));
        // An unsampled request in the same server stays untraced.
        let r2 = srv.classify(image(4, 0.9)).unwrap();
        assert!(r2.trace.is_none());
        srv.shutdown();
    }

    #[test]
    fn shed_records_a_flight_event_naming_the_trace_id() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::from_millis(50),
        });
        let flight = Arc::new(crate::obs::FlightRecorder::new(
            "unit", 8, None,
        ));
        let srv = Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::ZERO,
                max_queue: 8,
                flight: Some(flight.clone()),
                ..ServerConfig::default()
            },
        );
        // Drive Low past its 50% admission cap with traced submits.
        let mut keep = Vec::new();
        let mut shed_id = None;
        for i in 0..16u64 {
            let (tx, rx) = channel();
            let req = SubmitRequest::new(image(4, 0.5))
                .with_priority(Priority::Low)
                .with_trace(1000 + i, false);
            match srv.submit(req, tx) {
                SubmitOutcome::Enqueued { .. } => keep.push(rx),
                SubmitOutcome::Shed { .. } => {
                    shed_id = Some(1000 + i);
                    break;
                }
                SubmitOutcome::Closed => panic!("not closed"),
            }
        }
        let shed_id = shed_id.expect("Low must hit its cap");
        let hit = flight.entries().into_iter().any(|e| match e {
            crate::obs::FlightEntry::Event { trace_id, kind, .. } => {
                trace_id == shed_id
                    && kind == crate::obs::TerminalKind::ShedLow
            }
            _ => false,
        });
        assert!(hit, "shed must record a shed_low event with the id");
        srv.shutdown();
    }

    #[test]
    fn prop_every_request_gets_its_own_answer() {
        forall(Config::cases(8), |rng: &mut Rng| {
            let exec = Arc::new(MockExec {
                hw: 2,
                sizes: vec![1, rng.range(2, 5)],
                delay: Duration::from_micros(rng.range(0, 300) as u64),
            });
            let srv = Arc::new(Server::start(
                exec,
                ServerConfig {
                    max_wait: Duration::from_micros(rng.range(0, 500) as u64),
                    max_queue: 4096,
                    ..ServerConfig::default()
                },
            ));
            let n = rng.range(1, 24);
            let fills: Vec<f32> =
                (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            let rxs: Vec<_> = fills
                .iter()
                .map(|&f| submit_ok(&srv, image(2, f)))
                .collect();
            for (f, rx) in fills.iter().zip(rxs) {
                let r = rx.recv().unwrap();
                assert!(
                    (r.logits[0] - f).abs() < 1e-4,
                    "answer mismatched request: want {f}, got {}",
                    r.logits[0]
                );
            }
            Arc::try_unwrap(srv).ok().map(|s| s.shutdown());
        });
    }
}
