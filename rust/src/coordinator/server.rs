//! The serving pipeline: request intake -> dynamic batcher -> executor
//! worker(s) -> per-request responses with bandwidth accounting.
//!
//! The executor is abstracted behind [`BatchExecutor`] so the pipeline
//! is testable with a closure/mock; production wires it to any
//! [`InferenceBackend`] via [`BackendExecutor`] — the pure-Rust
//! [`crate::backend::reference::ReferenceBackend`] in every build,
//! PJRT (`--features pjrt`) through [`pjrt_executor`].

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::batcher::Batcher;
use super::metrics::Metrics;
use crate::backend::{InferenceBackend, ModelOutput};
use crate::compress::{self, Codec, CodecId, SpillBuf};
use crate::telemetry::Telemetry;
use crate::tensor::Tensor;
use crate::zebra::bandwidth::ELEM_BITS;

/// One classification request: a normalized (3, H, W) image.
pub struct Request {
    pub id: u64,
    pub image: Tensor,
    pub enqueued: Instant,
    pub reply: Sender<Response>,
}

/// The response: logits + the request's bandwidth accounting.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Class logits for this image.
    pub logits: Vec<f32>,
    pub predicted: usize,
    /// Eq. 2–3 accounting for this image's activation spills.
    pub dense_bytes: u64,
    pub stored_bytes: u64,
    pub index_bytes: u64,
    /// This request's share of the `.zspill` frame bytes produced for
    /// cross-node spill shipping (0 unless the server ships spills).
    pub spill_frame_bytes: u64,
    pub latency: Duration,
}

impl Response {
    pub fn reduction_pct(&self) -> f64 {
        super::metrics::reduction_pct_of(
            self.dense_bytes,
            self.stored_bytes,
            self.index_bytes,
        )
    }
}

/// Runs one padded batch tensor, returns logits + masks.
pub trait BatchExecutor: Send + Sync {
    /// `x` is `(exec_size, 3, H, W)`; returns outputs for all slots.
    fn execute(&self, x: &Tensor) -> Result<ModelOutput>;
    /// Batch sizes this executor supports, ascending.
    fn batch_sizes(&self) -> Vec<usize>;
    /// Image spatial size.
    fn image_hw(&self) -> usize;
    /// Worker threads the underlying compute hot path uses per
    /// execution (`--threads` / `ZEBRA_THREADS` on the reference
    /// backend). Recorded in [`Metrics::exec_threads`] so every tier's
    /// metrics can report node parallelism.
    fn exec_threads(&self) -> usize {
        1
    }
}

/// Production executor: bridges any [`InferenceBackend`] onto the
/// batcher's worker threads. Backends need not be `Send` (the `xla`
/// crate's PJRT handles are `Rc` + raw pointers), so the backend is
/// constructed on — and never leaves — ONE dedicated execution thread;
/// this handle talks to it over channels and is therefore freely
/// shareable with the batcher workers.
pub struct BackendExecutor {
    tx: std::sync::Mutex<Sender<ExecJob>>,
    name: String,
    sizes: Vec<usize>,
    hw: usize,
    threads: usize,
}

struct ExecJob {
    x: Tensor,
    reply: Sender<Result<ModelOutput>>,
}

impl BackendExecutor {
    /// Spawn the execution thread: `init` runs there, builds the
    /// backend (loading/compiling every model variant up front so
    /// serving never hits a load stall mid-request), and startup
    /// errors propagate back to the caller.
    pub fn spawn<B, F>(init: F) -> Result<BackendExecutor>
    where
        B: InferenceBackend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = channel::<ExecJob>();
        let (ready_tx, ready_rx) = channel::<Result<BackendMeta>>();
        std::thread::spawn(move || backend_thread(init, rx, ready_tx));
        let (name, mut sizes, hw, threads) = ready_rx
            .recv()
            .context("backend thread died during startup")??;
        sizes.sort_unstable();
        anyhow::ensure!(!sizes.is_empty(), "backend {name} exports no batch sizes");
        Ok(BackendExecutor {
            tx: std::sync::Mutex::new(tx),
            name,
            sizes,
            hw,
            threads,
        })
    }

    /// Which backend this executor runs ("reference", "pjrt", ...).
    pub fn backend_name(&self) -> &str {
        &self.name
    }
}

/// Startup metadata the backend thread reports: name, batch sizes,
/// image size, compute threads.
type BackendMeta = (String, Vec<usize>, usize, usize);

fn backend_thread<B, F>(
    init: F,
    rx: Receiver<ExecJob>,
    ready: Sender<Result<BackendMeta>>,
) where
    B: InferenceBackend,
    F: FnOnce() -> Result<B>,
{
    let backend = match init() {
        Ok(b) => {
            let meta = (
                b.name().to_string(),
                b.batch_sizes(),
                b.image_hw(),
                b.exec_threads(),
            );
            let _ = ready.send(Ok(meta));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        let _ = job.reply.send(backend.execute(&job.x));
    }
}

impl BatchExecutor for BackendExecutor {
    fn execute(&self, x: &Tensor) -> Result<ModelOutput> {
        let (reply, rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(ExecJob { x: x.clone(), reply })
            .map_err(|_| anyhow!("{} executor thread is gone", self.name))?;
        rx.recv()
            .with_context(|| format!("{} executor dropped the job", self.name))?
    }
    fn batch_sizes(&self) -> Vec<usize> {
        self.sizes.clone()
    }
    fn image_hw(&self) -> usize {
        self.hw
    }
    fn exec_threads(&self) -> usize {
        self.threads
    }
}

/// [`BackendExecutor`] over the pure-Rust reference backend (always
/// available — this is what the default build serves with).
pub fn reference_executor(
    spec: crate::backend::reference::RefSpec,
) -> Result<BackendExecutor> {
    BackendExecutor::spawn(move || {
        crate::backend::reference::ReferenceBackend::new(spec)
    })
}

/// [`BackendExecutor`] over the PJRT runtime: eagerly compiles every
/// exported batch variant of `key` from `artifacts` on the execution
/// thread (PJRT state is `!Send`).
#[cfg(feature = "pjrt")]
pub fn pjrt_executor(
    artifacts: std::path::PathBuf,
    key: &str,
) -> Result<BackendExecutor> {
    let key = key.to_string();
    BackendExecutor::spawn(move || {
        crate::runtime::PjrtBackend::new(&artifacts, &key)
    })
}

/// Spill-shipping configuration: which codec frames each executed
/// batch as a `.zspill` for a peer coordinator node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShipSpills {
    pub codec: CodecId,
    /// Block size for block-structured codecs (must divide the image
    /// H/W); ignored by parameterless codecs.
    pub block: u16,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Batching window.
    pub max_wait: Duration,
    /// Executor worker threads (1 is right for the CPU PJRT client).
    pub workers: usize,
    /// Reject pushes beyond this queue depth (backpressure).
    pub max_queue: usize,
    /// When set, each executed batch tensor is also encoded and framed
    /// as a versioned `.zspill` — the bytes a multi-node deployment
    /// ships to a peer — metered per worker through one reused
    /// [`SpillBuf`] (no per-spill allocation on the request path).
    pub ship_spills: Option<ShipSpills>,
    /// Where the framed `.zspill` bytes actually go. With
    /// `ship_spills` set and a sink present, every executed batch's
    /// frame is sent here (the cluster worker forwards them upstream
    /// as `SpillShip` wire frames); without a sink the frames are
    /// metered but not materialized, preserving the PR 1 behavior.
    pub spill_sink: Option<Sender<Vec<u8>>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(2),
            workers: 1,
            max_queue: 1024,
            ship_spills: None,
            spill_sink: None,
        }
    }
}

/// The coordinator server.
pub struct Server {
    batcher: Arc<Batcher<Request>>,
    pub metrics: Arc<Metrics>,
    /// Wall-time/byte accounting for the serving hot loop. Every batch
    /// records a `serve.batch` umbrella scope plus `serve.assemble`,
    /// `serve.ship`, `serve.execute` and `serve.respond` sub-stages, so
    /// `snapshot().coverage("serve.batch", ...)` attributes (nearly)
    /// all worker wall time.
    pub telemetry: Arc<Telemetry>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    max_queue: usize,
}

impl Server {
    pub fn start(exec: Arc<dyn BatchExecutor>, cfg: ServerConfig) -> Server {
        let batcher =
            Arc::new(Batcher::new(exec.batch_sizes(), cfg.max_wait));
        let metrics = Arc::new(Metrics::new());
        // Gauge, not counter: how parallel this node's compute is —
        // surfaced through metrics snapshots so cluster tooling can
        // report per-worker thread counts.
        metrics
            .exec_threads
            .store(exec.exec_threads() as u64, Ordering::Relaxed);
        // Resolve the shipping codec once, up front: a bad codec id /
        // block combination must fail at startup, not in a worker.
        let shipper: Option<Arc<dyn Codec>> = cfg.ship_spills.map(|s| {
            let codec = compress::from_id(s.codec, s.block)
                .expect("ship_spills names an invalid codec");
            let needs_block = compress::registry()
                .iter()
                .any(|r| r.id == s.codec && r.needs_block);
            assert!(
                !needs_block || exec.image_hw() % s.block as usize == 0,
                "ship_spills block {} does not divide image size {}",
                s.block,
                exec.image_hw()
            );
            Arc::from(codec)
        });
        let telemetry = Arc::new(Telemetry::new());
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let b = batcher.clone();
            let m = metrics.clone();
            let e = exec.clone();
            let s = shipper.clone();
            let sink = cfg.spill_sink.clone();
            let t = telemetry.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(b, e, m, s, sink, t)
            }));
        }
        Server {
            batcher,
            metrics,
            telemetry,
            workers,
            next_id: std::sync::atomic::AtomicU64::new(0),
            max_queue: cfg.max_queue,
        }
    }

    /// Submit an image; the response arrives on the returned channel.
    /// Errors immediately under backpressure (queue full) or shutdown.
    pub fn submit(&self, image: Tensor) -> Result<Receiver<Response>> {
        let (tx, rx) = channel();
        self.submit_routed(image, tx)?;
        Ok(rx)
    }

    /// Submit with a caller-owned reply channel, returning the
    /// assigned request id. This is the multiplexed intake the cluster
    /// worker uses: one TCP connection funnels every response through
    /// a single `Sender` instead of one channel per request, and the
    /// returned id lets the caller pair responses with wire frames.
    pub fn submit_routed(
        &self,
        image: Tensor,
        reply: Sender<Response>,
    ) -> Result<u64> {
        if self.batcher.depth() >= self.max_queue {
            return Err(anyhow!("queue full ({} pending)", self.max_queue));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let ok = self.batcher.push(Request {
            id,
            image,
            enqueued: Instant::now(),
            reply,
        });
        anyhow::ensure!(ok, "server is shut down");
        Ok(id)
    }

    /// Blocking convenience: submit and wait.
    pub fn classify(&self, image: Tensor) -> Result<Response> {
        let rx = self.submit(image)?;
        rx.recv().context("server dropped the request")
    }

    /// Stop accepting work and let the workers drain, without waiting
    /// for them (shared-handle shutdown — what `cluster::WorkerNode`
    /// calls through its `Arc<Server>`). Pending requests still
    /// complete; subsequent submits error.
    pub fn close(&self) {
        self.batcher.close();
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

fn worker_loop(
    batcher: Arc<Batcher<Request>>,
    exec: Arc<dyn BatchExecutor>,
    metrics: Arc<Metrics>,
    shipper: Option<Arc<dyn Codec>>,
    spill_sink: Option<Sender<Vec<u8>>>,
    telemetry: Arc<Telemetry>,
) {
    let hw = exec.image_hw();
    // Stage handles resolved once — recording inside the loop is two
    // relaxed atomics, no lock. `serve.batch` is the umbrella scope
    // (batch in hand -> responses sent); the sub-stages must account
    // for >= 95% of it (pinned by the loopback telemetry test).
    let st_batch = telemetry.stage("serve.batch");
    let st_assemble = telemetry.stage("serve.assemble");
    let st_ship = telemetry.stage("serve.ship");
    let st_execute = telemetry.stage("serve.execute");
    let st_respond = telemetry.stage("serve.respond");
    // One SpillBuf per worker: spill-shipping reuses its arenas across
    // every batch this worker ever executes.
    let mut spill_buf = SpillBuf::new();
    while let Some(batch) = batcher.next_batch() {
        // Time starts when a batch is in hand — queue wait is the
        // batcher's, not this worker's.
        let _whole = st_batch.time();
        let n = batch.items.len();
        let exec_size = batch.exec_size;
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.batched_items.fetch_add(n as u64, Ordering::Relaxed);
        metrics
            .padded_slots
            .fetch_add(batch.padding() as u64, Ordering::Relaxed);
        // Assemble the padded batch tensor.
        let t_assemble = st_assemble.time();
        let mut x = Tensor::zeros(&[exec_size, 3, hw, hw]);
        let per = 3 * hw * hw;
        for (i, req) in batch.items.iter().enumerate() {
            let src = req.image.data();
            x.data_mut()[i * per..(i + 1) * per].copy_from_slice(src);
        }
        drop(t_assemble);
        // Cross-node shipping: encode the batch into the worker's
        // reused SpillBuf and meter the exact `.zspill` frame size a
        // peer node receives. Without a sink the frame is never
        // materialized (frame_len predicts to_bytes exactly); with one
        // — the cluster worker's upstream pump — the frame bytes are
        // built once here and handed off, keeping the TCP write off
        // the request path.
        let frame_share = match &shipper {
            Some(codec) => {
                let _t = st_ship.time();
                codec.encode_into(&x, &mut spill_buf);
                let len = spill_buf.view().frame_len() as u64;
                st_ship.add_bytes(len);
                metrics
                    .shipped_spill_bytes
                    .fetch_add(len, Ordering::Relaxed);
                if let Some(sink) = &spill_sink {
                    // A gone sink (upstream pump shut down) is not a
                    // serving error; the metering above still counts.
                    let _ = sink.send(spill_buf.view().to_bytes());
                }
                len / exec_size.max(1) as u64
            }
            None => 0,
        };
        let result = {
            let _t = st_execute.time();
            exec.execute(&x)
        };
        match result {
            Ok(out) => {
                let _t = st_respond.time();
                respond(batch.items, &out, &metrics, frame_share);
            }
            Err(e) => {
                // Failed batch: drop the reply channels; callers see a
                // RecvError. Metrics still count the attempt.
                eprintln!("[server] batch of {n} failed: {e:#}");
            }
        }
    }
}

fn respond(
    items: Vec<Request>,
    out: &ModelOutput,
    metrics: &Metrics,
    spill_frame_bytes: u64,
) {
    let classes = out.logits.shape()[1];
    for (i, req) in items.into_iter().enumerate() {
        let logits =
            out.logits.data()[i * classes..(i + 1) * classes].to_vec();
        let predicted = argmax(&logits);
        // Per-image bandwidth accounting from this request's mask rows
        // (Eq. 2: kept blocks * B^2 * 4 bytes; Eq. 3: 1 bit per block).
        let (mut dense, mut stored, mut index) = (0u64, 0u64, 0u64);
        for (mi, m) in out.masks.iter().enumerate() {
            let s = m.shape(); // (batch, C, H/b, W/b)
            let blocks: usize = s[1] * s[2] * s[3];
            let row = &m.data()[i * blocks..(i + 1) * blocks];
            let kept: usize = row.iter().filter(|&&v| v != 0.0).count();
            let elems_per_block =
                out.block_elems.get(mi).copied().unwrap_or(16);
            let bytes_per_block = elems_per_block * ELEM_BITS / 8;
            dense += (blocks * bytes_per_block) as u64;
            stored += (kept * bytes_per_block) as u64;
            index += blocks.div_ceil(8) as u64;
        }
        metrics.dense_bytes.fetch_add(dense, Ordering::Relaxed);
        metrics.stored_bytes.fetch_add(stored, Ordering::Relaxed);
        metrics.index_bytes.fetch_add(index, Ordering::Relaxed);
        let latency = req.enqueued.elapsed();
        metrics.record_latency_us(latency.as_micros() as u64);
        let _ = req.reply.send(Response {
            id: req.id,
            logits,
            predicted,
            dense_bytes: dense,
            stored_bytes: stored,
            index_bytes: index,
            spill_frame_bytes,
            latency,
        });
    }
}

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{forall, Config};

    /// Mock model: "logits" = [mean, -mean]; one 2x2-blocked mask layer
    /// where a block is kept iff the image mean > 0.5.
    struct MockExec {
        hw: usize,
        sizes: Vec<usize>,
        delay: Duration,
    }

    impl BatchExecutor for MockExec {
        fn execute(&self, x: &Tensor) -> Result<ModelOutput> {
            std::thread::sleep(self.delay);
            let b = x.shape()[0];
            let per = 3 * self.hw * self.hw;
            let mut logits = Vec::with_capacity(b * 2);
            let mut mask = Vec::new();
            for i in 0..b {
                let mean: f32 = x.data()[i * per..(i + 1) * per]
                    .iter()
                    .sum::<f32>()
                    / per as f32;
                logits.extend_from_slice(&[mean, -mean]);
                let kept = if mean > 0.5 { 1.0 } else { 0.0 };
                mask.extend(std::iter::repeat(kept).take(4)); // C=1, 2x2 grid
            }
            Ok(ModelOutput {
                logits: Tensor::from_vec(&[b, 2], logits),
                masks: vec![Tensor::from_vec(&[b, 1, 2, 2], mask)],
                block_elems: vec![4],
            })
        }
        fn batch_sizes(&self) -> Vec<usize> {
            self.sizes.clone()
        }
        fn image_hw(&self) -> usize {
            self.hw
        }
    }

    fn image(hw: usize, fill: f32) -> Tensor {
        Tensor::from_vec(&[3, hw, hw], vec![fill; 3 * hw * hw])
    }

    #[test]
    fn classify_routes_logits_back() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1, 4],
            delay: Duration::ZERO,
        });
        let srv = Server::start(exec, ServerConfig::default());
        let r = srv.classify(image(4, 0.9)).unwrap();
        assert_eq!(r.predicted, 0, "positive mean -> class 0");
        assert!((r.logits[0] - 0.9).abs() < 1e-5);
        let r2 = srv.classify(image(4, -0.9)).unwrap();
        assert_eq!(r2.predicted, 1);
        srv.shutdown();
    }

    #[test]
    fn bandwidth_accounting_per_request() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let srv = Server::start(exec, ServerConfig::default());
        // Bright image: all 4 blocks kept -> stored == dense.
        let r = srv.classify(image(4, 0.9)).unwrap();
        assert_eq!(r.dense_bytes, 4 * 4 * 4); // 4 blocks * 4 elems * 4B
        assert_eq!(r.stored_bytes, r.dense_bytes);
        // Dark image: everything pruned -> only index bytes remain.
        let r2 = srv.classify(image(4, 0.1)).unwrap();
        assert_eq!(r2.stored_bytes, 0);
        assert_eq!(r2.index_bytes, 1);
        assert!(r2.reduction_pct() > 95.0);
        srv.shutdown();
    }

    #[test]
    fn ships_spill_frames_when_configured() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let srv = Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::ZERO,
                workers: 1,
                max_queue: 16,
                ship_spills: Some(ShipSpills {
                    codec: CodecId::ZeroBlock,
                    block: 2,
                }),
                spill_sink: None,
            },
        );
        let r = srv.classify(image(4, 0.9)).unwrap();
        assert!(r.spill_frame_bytes > 0, "shipping must meter frame bytes");
        let shipped =
            srv.metrics.shipped_spill_bytes.load(Ordering::Relaxed);
        assert!(shipped >= r.spill_frame_bytes);
        // A second request reuses the worker's SpillBuf and ships an
        // identically-sized frame (same image geometry).
        let r2 = srv.classify(image(4, 0.9)).unwrap();
        assert_eq!(r2.spill_frame_bytes, r.spill_frame_bytes);
        assert_eq!(
            srv.metrics.shipped_spill_bytes.load(Ordering::Relaxed),
            2 * shipped
        );
        srv.shutdown();
    }

    #[test]
    fn shipping_disabled_reports_zero_frame_bytes() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let srv = Server::start(exec, ServerConfig::default());
        let r = srv.classify(image(4, 0.5)).unwrap();
        assert_eq!(r.spill_frame_bytes, 0);
        assert_eq!(
            srv.metrics.shipped_spill_bytes.load(Ordering::Relaxed),
            0
        );
        srv.shutdown();
    }

    #[test]
    fn telemetry_accounts_the_worker_wall_time() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1, 4],
            delay: Duration::from_millis(4),
        });
        let srv = Server::start(exec, ServerConfig::default());
        for _ in 0..6 {
            srv.classify(image(4, 0.9)).unwrap();
        }
        let snap = srv.telemetry.snapshot();
        let cov = snap
            .coverage(
                "serve.batch",
                &[
                    "serve.assemble",
                    "serve.ship",
                    "serve.execute",
                    "serve.respond",
                ],
            )
            .expect("serve.batch must have recorded time");
        assert!(
            cov >= 0.95,
            "sub-stages cover only {:.1}% of the hot loop",
            100.0 * cov
        );
        assert!(snap.get("serve.execute").calls >= 1);
        assert_eq!(snap.get("serve.batch").calls, snap.get("serve.execute").calls);
        // No shipping configured: the stage exists but never moved bytes.
        assert_eq!(snap.get("serve.ship").bytes, 0);
        srv.shutdown();
    }

    #[test]
    fn batches_fill_under_concurrent_load() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1, 4, 8],
            delay: Duration::from_millis(3),
        });
        let srv = Arc::new(Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::from_millis(10),
                workers: 1,
                max_queue: 1024,
                ship_spills: None,
                spill_sink: None,
            },
        ));
        let mut waiters = Vec::new();
        for _ in 0..32 {
            waiters.push(srv.submit(image(4, 0.7)).unwrap());
        }
        for rx in waiters {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.predicted, 0);
        }
        assert!(
            srv.metrics.mean_batch() > 1.5,
            "batching should engage under load: mean {}",
            srv.metrics.mean_batch()
        );
        Arc::try_unwrap(srv).ok().map(|s| s.shutdown());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::from_millis(50),
        });
        let srv = Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::ZERO,
                workers: 1,
                max_queue: 2,
                ship_spills: None,
                spill_sink: None,
            },
        );
        let _a = srv.submit(image(4, 0.5)).unwrap();
        let _b = srv.submit(image(4, 0.5)).unwrap();
        let _c = srv.submit(image(4, 0.5)).unwrap();
        // Queue is at capacity (worker holds one, two waiting).
        let mut rejected = false;
        for _ in 0..4 {
            if srv.submit(image(4, 0.5)).is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "expected backpressure rejection");
        srv.shutdown();
    }

    #[test]
    fn submit_routed_multiplexes_one_reply_channel() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let srv = Server::start(exec, ServerConfig::default());
        let (tx, rx) = channel();
        let mut want = std::collections::HashMap::new();
        for &fill in &[0.9f32, -0.9, 0.3] {
            let id = srv.submit_routed(image(4, fill), tx.clone()).unwrap();
            want.insert(id, fill);
        }
        for _ in 0..want.len() {
            let r = rx.recv().unwrap();
            let fill = want.remove(&r.id).expect("unknown or duplicate id");
            assert!((r.logits[0] - fill).abs() < 1e-5);
        }
        assert!(want.is_empty(), "every id must be answered exactly once");
        srv.shutdown();
    }

    #[test]
    fn spill_sink_receives_the_metered_frames() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let (sink_tx, sink_rx) = channel();
        let srv = Server::start(
            exec,
            ServerConfig {
                max_wait: Duration::ZERO,
                workers: 1,
                max_queue: 16,
                ship_spills: Some(ShipSpills {
                    codec: CodecId::ZeroBlock,
                    block: 2,
                }),
                spill_sink: Some(sink_tx),
            },
        );
        let r = srv.classify(image(4, 0.9)).unwrap();
        let frame = sink_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("sink must receive the batch frame");
        // The sink gets exactly the bytes the metric counted, and they
        // parse as a valid `.zspill`.
        assert_eq!(frame.len() as u64, r.spill_frame_bytes);
        let view = compress::EncodedView::parse(&frame)
            .expect("shipped frame must be a valid .zspill");
        assert_eq!(view.codec, CodecId::ZeroBlock);
        srv.shutdown();
    }

    #[test]
    fn close_on_shared_handle_rejects_new_work() {
        let exec = Arc::new(MockExec {
            hw: 4,
            sizes: vec![1],
            delay: Duration::ZERO,
        });
        let srv = Arc::new(Server::start(exec, ServerConfig::default()));
        let r = srv.classify(image(4, 0.9)).unwrap();
        assert_eq!(r.predicted, 0);
        srv.close();
        assert!(srv.submit(image(4, 0.9)).is_err());
    }

    #[test]
    fn prop_every_request_gets_its_own_answer() {
        forall(Config::cases(8), |rng: &mut Rng| {
            let exec = Arc::new(MockExec {
                hw: 2,
                sizes: vec![1, rng.range(2, 5)],
                delay: Duration::from_micros(rng.range(0, 300) as u64),
            });
            let srv = Arc::new(Server::start(
                exec,
                ServerConfig {
                    max_wait: Duration::from_micros(rng.range(0, 500) as u64),
                    workers: 1,
                    max_queue: 4096,
                    ship_spills: None,
                    spill_sink: None,
                },
            ));
            let n = rng.range(1, 24);
            let fills: Vec<f32> =
                (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            let rxs: Vec<_> = fills
                .iter()
                .map(|&f| srv.submit(image(2, f)).unwrap())
                .collect();
            for (f, rx) in fills.iter().zip(rxs) {
                let r = rx.recv().unwrap();
                assert!(
                    (r.logits[0] - f).abs() < 1e-4,
                    "answer mismatched request: want {f}, got {}",
                    r.logits[0]
                );
            }
            Arc::try_unwrap(srv).ok().map(|s| s.shutdown());
        });
    }
}
