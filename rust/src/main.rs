//! `zebra` binary — see `zebra help` (rust/src/cli/mod.rs).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = zebra::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
