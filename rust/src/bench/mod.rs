//! In-repo benchmarking harness (criterion is not in the offline vendor
//! set — DESIGN.md §7): warmup + timed iterations, robust stats, and
//! the table printer every `benches/table*.rs` regenerator uses.

pub mod paper;

use std::time::Instant;

/// Summary statistics over timed iterations (nanoseconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Throughput in units/sec given work per iteration.
    pub fn per_sec(&self, work_per_iter: f64) -> f64 {
        work_per_iter / (self.mean_ns / 1e9)
    }

    /// Throughput in GB/s given bytes of work per iteration (the unit
    /// every codec row reports).
    pub fn gbps(&self, bytes_per_iter: f64) -> f64 {
        self.per_sec(bytes_per_iter) / 1e9
    }

    /// Mean-time speedup of `self` over `baseline` (>1 means faster).
    pub fn speedup_over(&self, baseline: &Stats) -> f64 {
        baseline.mean_ns / self.mean_ns
    }
}

/// CI smoke mode: `ZEBRA_BENCH_SMOKE=1` caps every [`bench`] call at a
/// ~1 ms measuring budget (3 iterations minimum) so the whole
/// `table*`/`fig*` suite finishes in seconds — the numbers are
/// meaningless, but every code path still executes and every shape
/// check still fires.
pub fn smoke() -> bool {
    std::env::var_os("ZEBRA_BENCH_SMOKE").is_some_and(|v| v != "0" && !v.is_empty())
}

/// Smoke-mode artifact guard for the `table*`/`fig*` regenerators:
/// under [`smoke`], a missing artifact input means "skip this bench,
/// exit 0" (CI has no trained artifacts); outside smoke mode it
/// returns false and the caller's normal load error fires.
pub fn smoke_skip(required: &std::path::Path) -> bool {
    if smoke() && !required.exists() {
        eprintln!(
            "  [bench] smoke mode: {required:?} missing (run `make \
             artifacts`) — skipping"
        );
        return true;
    }
    false
}

/// Time `f` with warmup; picks an iteration count so the measured phase
/// runs ~`budget_ms` (clamped to ~1 ms under [`smoke`]).
pub fn bench<F: FnMut()>(label: &str, budget_ms: u64, mut f: F) -> Stats {
    let budget_ms = if smoke() { budget_ms.min(1) } else { budget_ms };
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as u64;
    let target = budget_ms * 1_000_000;
    let iters = (target / once).clamp(3, 10_000) as usize;
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let stats = Stats {
        iters: n,
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        p50_ns: samples[n / 2],
        p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        min_ns: samples[0],
        max_ns: samples[n - 1],
    };
    eprintln!(
        "  [bench] {label}: mean {:.3} ms  p50 {:.3}  p95 {:.3}  ({} iters)",
        stats.mean_ns / 1e6,
        stats.p50_ns / 1e6,
        stats.p95_ns / 1e6,
        n
    );
    stats
}

/// Fixed-width table printer for paper-vs-measured comparisons.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        println!("\n{title}");
        println!("{}", "=".repeat(total.min(100)));
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                line.push_str(&format!(" {c:>w$} |"));
            }
            line
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(total.min(100)));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        println!();
    }
}

/// Format a measured-vs-paper pair like `"36.4 / 33.1"`.
pub fn vs(paper: f64, measured: f64) -> String {
    format!("{paper:>5.1} / {measured:>5.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let mut x = 0u64;
        let s = bench("noop", 5, || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.min_ns <= s.p50_ns);
        assert!(s.p50_ns <= s.p95_ns);
        assert!(s.p95_ns <= s.max_ns);
        assert!(s.iters >= 3);
    }

    #[test]
    fn smoke_skip_only_fires_in_smoke_mode_on_missing_paths() {
        // Env-var manipulation is process-global; this test covers the
        // non-smoke default (CI sets the var only for the bench job).
        if !smoke() {
            assert!(!smoke_skip(std::path::Path::new("/nonexistent/x")));
        } else {
            assert!(smoke_skip(std::path::Path::new("/nonexistent/x")));
            assert!(!smoke_skip(std::path::Path::new("/")));
        }
    }

    #[test]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".to_string()]);
        }));
        assert!(r.is_err());
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            iters: 1,
            mean_ns: 1e6, // 1 ms
            p50_ns: 1e6,
            p95_ns: 1e6,
            min_ns: 1e6,
            max_ns: 1e6,
        };
        // 1 MB per 1 ms = 1 GB/s.
        let gbps = s.per_sec(1e6) / 1e9;
        assert!((gbps - 1.0).abs() < 1e-9);
        assert!((s.gbps(1e6) - 1.0).abs() < 1e-9);
        let slow = Stats { mean_ns: 2e6, ..s.clone() };
        assert!((s.speedup_over(&slow) - 2.0).abs() < 1e-9);
        assert!((slow.speedup_over(&s) - 0.5).abs() < 1e-9);
    }
}
