//! Shared loader for the paper-reproduction benches: joins
//! `artifacts/metrics.json` (the trained grid + paper reference
//! numbers) with table layouts so each `benches/table*.rs` regenerator
//! prints paper-vs-measured rows.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

/// One experiment's outcome + the paper's reference numbers.
#[derive(Debug, Clone)]
pub struct Run {
    pub key: String,
    pub arch: String,
    pub dataset: String,
    pub t_obj: f64,
    pub ns: f64,
    pub wp: f64,
    pub zebra: bool,
    pub top1: f64,
    pub top5: f64,
    pub reduced_pct: f64,
    pub paper_bw: Option<f64>,
    /// (top1, top5) — top5 only for Tiny-ImageNet rows.
    pub paper_acc: Option<(f64, Option<f64>)>,
    /// Mean learned threshold per logged step (Fig. 3 evidence).
    pub mean_t_history: Vec<f64>,
    pub loss_history: Vec<f64>,
}

/// Full metrics file.
pub struct PaperMetrics {
    pub raw: Value,
}

impl PaperMetrics {
    pub fn load(artifacts: &Path) -> Result<PaperMetrics> {
        let path = artifacts.join("metrics.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {path:?} — run `make artifacts` first")
        })?;
        Ok(PaperMetrics { raw: json::parse(&text)? })
    }

    pub fn run(&self, key: &str) -> Option<Run> {
        let r = self.raw.get("runs").get(key);
        if r.is_null() {
            return None;
        }
        let cfg = r.get("config");
        let ev = r.get("eval");
        let paper = r.get("paper");
        let paper_acc = match paper.get("acc") {
            Value::Num(a) => Some((*a, None)),
            Value::Array(v) if v.len() == 2 => {
                Some((v[0].as_f64()?, Some(v[1].as_f64()?)))
            }
            _ => None,
        };
        let hist = |name: &str| -> Vec<f64> {
            r.get("history")
                .get(name)
                .as_array()
                .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
                .unwrap_or_default()
        };
        Some(Run {
            key: key.to_string(),
            arch: cfg.get("arch").as_str().unwrap_or("?").into(),
            dataset: cfg.get("dataset").as_str().unwrap_or("?").into(),
            t_obj: cfg.get("t_obj").as_f64().unwrap_or(0.0),
            ns: cfg.get("ns_ratio").as_f64().unwrap_or(0.0),
            wp: cfg.get("wp_ratio").as_f64().unwrap_or(0.0),
            zebra: cfg.get("zebra").as_bool().unwrap_or(false),
            top1: ev.get("top1").as_f64().unwrap_or(0.0),
            top5: ev.get("top5").as_f64().unwrap_or(0.0),
            reduced_pct: ev.get("reduced_pct").as_f64().unwrap_or(0.0),
            paper_bw: paper.get("bw").as_f64(),
            paper_acc,
            mean_t_history: hist("mean_t"),
            loss_history: hist("loss"),
        })
    }

    /// All run keys present.
    pub fn keys(&self) -> Vec<String> {
        self.raw
            .get("runs")
            .as_object()
            .map(|o| o.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// The `tables` layout written by the pipeline: (label, key) rows.
    pub fn table_rows(&self, table: &str) -> Vec<(String, String)> {
        self.raw
            .get("tables")
            .get(table)
            .as_array()
            .map(|rows| {
                rows.iter()
                    .filter_map(|r| {
                        Some((
                            r.get("label").as_str()?.to_string(),
                            r.get("key").as_str()?.to_string(),
                        ))
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Paper reference (bw, acc) for a Table IV row label.
    pub fn table4_paper(&self, label: &str) -> Option<(f64, f64)> {
        let v = self.raw.get("table4_paper").get(label);
        Some((v.idx(0).as_f64()?, v.idx(1).as_f64()?))
    }

    /// Table I block-size sweep: (measured, paper) per label.
    pub fn table1(&self) -> Vec<(String, f64, f64)> {
        let t = self.raw.get("table1");
        ["2x2", "4x4", "whole"]
            .iter()
            .filter_map(|&label| {
                Some((
                    label.to_string(),
                    t.get("measured").get(label).as_f64()?,
                    t.get("paper").get(label).as_f64()?,
                ))
            })
            .collect()
    }
}

/// Shared "how to read these tables" banner.
pub fn banner() {
    println!(
        "NOTE: measured numbers come from the CPU-budget reproduction \
         (width-scaled models, synthetic dataset — DESIGN.md §7).\n\
         Compare SHAPES (ordering, deltas, crossovers), not absolutes."
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake() -> PaperMetrics {
        let text = r#"{
          "runs": {"k1": {
            "config": {"arch":"resnet18","dataset":"cifar10","t_obj":0.1,
                       "ns_ratio":0.2,"wp_ratio":0.0,"zebra":true},
            "eval": {"top1":80.5,"top5":99.0,"reduced_pct":30.25},
            "paper": {"bw":33.5,"acc":90.41},
            "history": {"mean_t":[0.09,0.1],"loss":[2.0,1.0]}
          },
          "k2": {
            "config": {"arch":"resnet18","dataset":"tiny","t_obj":0.2,
                       "ns_ratio":0,"wp_ratio":0,"zebra":true},
            "eval": {"top1":30.0,"top5":90.0,"reduced_pct":28.0},
            "paper": {"bw":47.2,"acc":[56.5,78.92]},
            "history": {}
          }},
          "tables": {"table2": [{"label":"row1","key":"k1"}]},
          "table4_paper": {"row1": [21.9, 92.84]},
          "table1": {"measured":{"2x2":35.2,"4x4":21.9,"whole":1.1},
                     "paper":{"2x2":24.7,"4x4":7.9,"whole":1.1}}
        }"#;
        PaperMetrics { raw: json::parse(text).unwrap() }
    }

    #[test]
    fn parses_runs_and_paper_refs() {
        let m = fake();
        let r = m.run("k1").unwrap();
        assert_eq!(r.arch, "resnet18");
        assert_eq!(r.paper_bw, Some(33.5));
        assert_eq!(r.paper_acc, Some((90.41, None)));
        assert_eq!(r.mean_t_history, vec![0.09, 0.1]);
        let r2 = m.run("k2").unwrap();
        assert_eq!(r2.paper_acc, Some((56.5, Some(78.92))));
        assert!(m.run("nope").is_none());
    }

    #[test]
    fn table_layout_and_refs() {
        let m = fake();
        assert_eq!(
            m.table_rows("table2"),
            vec![("row1".to_string(), "k1".to_string())]
        );
        assert_eq!(m.table4_paper("row1"), Some((21.9, 92.84)));
        assert_eq!(m.table1().len(), 3);
        assert_eq!(m.keys().len(), 2);
    }
}
