//! # Zebra — memory-bandwidth reduction for CNN accelerators
//!
//! Rust reproduction of *"Zebra: Memory Bandwidth Reduction for CNN
//! Accelerators with Zero Block Regularization of Activation Maps"*
//! (Shih & Chang, ISCAS 2020), built as a three-layer Rust + JAX +
//! Pallas stack (see DESIGN.md):
//!
//! - **Layer 1** (`python/compile/kernels/`): the fused ReLU+Zebra
//!   block-prune op and the MXU-tiled GEMM as Pallas kernels.
//! - **Layer 2** (`python/compile/`): the model zoo (VGG16, ResNet-18/56,
//!   MobileNet) with Zebra's learned-threshold training, AOT-lowered to
//!   HLO text.
//! - **Layer 3** (this crate): everything after `make artifacts` —
//!   Python never runs on the request path.
//!
//! Crate layout:
//! - [`tensor`] — NCHW tensors + `.zten` interchange with Python.
//! - [`zebra`] — block geometry, the pruning hot path, Eq. 2–5 math.
//! - [`compress`] — the streaming codec API v2: buffer-reusing
//!   `encode_into`/`decode_into` over a `SpillBuf`, the codec registry
//!   (single source of truth for names/ids), and the versioned
//!   `.zspill` wire format (layout in `rust/docs/zspill.md`) with
//!   strict never-panicking parsing. Hosts the zero-block codec and
//!   the paper's baselines.
//! - [`models`] — static spill plans (incl. the paper's full-width
//!   architectures for Table V).
//! - [`trace`] — replaying Python-dumped activation traces.
//! - [`accel`] — the layer-by-layer accelerator simulator (PE array,
//!   SRAM, DRAM bursts) that turns zero blocks into bytes-on-the-wire.
//! - [`hal`] — target manifests (`.target` files + committed
//!   `rust/targets/` profiles) describing the hardware envelope the
//!   simulator runs against: DRAM bandwidth, burst size, buffer, PE
//!   geometry, clock. `zebra simulate --target` / `zebra targets`.
//! - [`backend`] — pluggable inference backends behind the
//!   `InferenceBackend` trait: the pure-Rust reference backend (always
//!   available, zero external dependencies — what CI gates) and, under
//!   `--features pjrt`, the PJRT runtime.
//! - [`runtime`] — artifact manifest parsing (every build) + PJRT
//!   loading/execution of the AOT HLO artifacts (`pjrt` feature).
//! - [`coordinator`] — the serving pipeline: continuous batch manager
//!   (per-key queues, priority admission, deadline-based flush, dynamic
//!   batch sizing), worker pool, per-request bandwidth metering.
//! - [`cluster`] — multi-node serving over TCP: a versioned,
//!   checksummed frame protocol (`.zspill` discipline on the wire),
//!   worker nodes wrapping the coordinator, a sharding/failover
//!   router with cluster-wide metrics, and the client the load
//!   generator drives.
//! - [`train`] — native Zebra training: a reverse-mode tape over the
//!   reference backend's own ops, the `CE + lambda * sum ||block||`
//!   objective with a straight-through estimator through the block
//!   gate, SGD + momentum under threshold/lambda warmup schedules, and
//!   a mini-batch loop that checkpoints `w%05d.zten` leaves the
//!   reference backend serves unchanged — the train -> artifact ->
//!   serve loop with no Python anywhere.
//! - [`faults`] — the deterministic chaos engine: a seeded
//!   [`FaultPlan`](faults::FaultPlan) (`--chaos` / `ZEBRA_CHAOS`)
//!   injecting wire drops/corruption/delays, worker stalls/crashes,
//!   and post-checksum spill corruption, plus the self-healing
//!   primitives it validates — per-worker circuit breakers and
//!   deterministic exponential backoff (`rust/docs/robustness.md`).
//! - [`obs`] — request-level observability: 64-bit trace ids riding
//!   wire v3 with per-hop spans, a flight-recorder ring dumped as
//!   JSON-lines on terminal events, and the unified metrics-export
//!   plane (`zebra obs`: Prometheus text + JSON) merging serving
//!   counters, cluster stats, and telemetry stages.
//! - [`telemetry`] — labeled wall-time/byte stages with lock-cheap
//!   recording and mergeable snapshots, threaded through the serve hot
//!   loop, the cluster nodes, and the simulator so every stage's time
//!   and bytes are attributable from one report.
//! - [`bench`] — the in-repo benchmarking harness (criterion is not in
//!   the offline vendor set) used by every table/figure regenerator.
//! - [`cli`] — the `zebra` binary's subcommands.
//! - [`util`] — JSON, PRNG and property-testing support.

pub mod accel;
pub mod backend;
pub mod bench;
pub mod cli;
pub mod cluster;
pub mod compress;
pub mod coordinator;
pub mod faults;
pub mod hal;
pub mod models;
pub mod obs;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod trace;
pub mod train;
pub mod util;
pub mod zebra;

/// Crate version (used by the CLI).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Locate the artifacts directory: `$ZEBRA_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("ZEBRA_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| "artifacts".into())
}
