//! Support utilities: JSON parsing (no serde in the offline vendor
//! set), a deterministic PRNG, and the in-repo property-test harness.

pub mod json;
pub mod prng;
pub mod prop;
