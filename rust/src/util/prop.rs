//! In-repo property-testing harness (the `proptest` crate is not in the
//! offline vendor set — DESIGN.md §7).
//!
//! A property runs against many generated cases from a deterministic
//! [`Rng`](super::prng::Rng); on failure the harness re-runs a bounded
//! shrink loop (halving sizes via the case's [`Shrink`] impl, if any)
//! and reports the seed so the exact failure is reproducible:
//!
//! ```no_run
//! // (no_run: rustdoc binaries miss the xla rpath; the same example
//! // runs as a unit test below.)
//! use zebra::util::prop::{forall, Config};
//! forall(Config::cases(256), |rng| {
//!     let n = rng.range(0, 100);
//!     let v: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     assert_eq!(v, w);
//! });
//! ```

use super::prng::Rng;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of generated cases.
    pub cases: usize,
    /// Base seed; case `i` runs with seed `base_seed + i`.
    pub base_seed: u64,
}

impl Config {
    pub fn cases(n: usize) -> Self {
        Config { cases: n, base_seed: default_seed() }
    }
}

impl Default for Config {
    fn default() -> Self {
        Config::cases(128)
    }
}

/// `ZEBRA_PROP_SEED` pins the base seed for reproduction.
fn default_seed() -> u64 {
    std::env::var("ZEBRA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EB2A) // "zebra"
}

/// Run `prop` for every generated case. Panics (with the failing seed in
/// the message) on the first failing case.
pub fn forall<F: FnMut(&mut Rng)>(cfg: Config, mut prop: F) {
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                let mut rng = Rng::new(seed);
                prop(&mut rng);
            },
        ));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| {
                    payload.downcast_ref::<&str>().map(|s| s.to_string())
                })
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed on case {i} (seed {seed}; rerun with \
                 ZEBRA_PROP_SEED={seed}):\n{msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(Config::cases(32), |rng| {
            let a = rng.range(0, 1000);
            let b = rng.range(0, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            forall(Config { cases: 64, base_seed: 99 }, |rng| {
                assert!(rng.range(0, 9) != 3, "hit the forbidden value");
            });
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().unwrap().clone(),
            Ok(()) => panic!("property unexpectedly passed"),
        };
        assert!(msg.contains("ZEBRA_PROP_SEED="), "msg: {msg}");
        assert!(msg.contains("forbidden"), "msg: {msg}");
    }

    #[test]
    fn deterministic_given_base_seed() {
        let mut first: Vec<usize> = Vec::new();
        forall(Config { cases: 16, base_seed: 7 }, |rng| {
            first.push(rng.range(0, 1_000_000));
        });
        let mut second: Vec<usize> = Vec::new();
        forall(Config { cases: 16, base_seed: 7 }, |rng| {
            second.push(rng.range(0, 1_000_000));
        });
        assert_eq!(first, second);
    }
}
