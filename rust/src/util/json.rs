//! Minimal JSON parser/serializer for manifest + metrics interchange.
//!
//! The offline image vendors only the `xla` crate's dependency closure
//! (no serde), so this module implements the small subset of JSON we
//! need: full parsing of RFC 8259 documents into a [`Value`] tree and
//! serialization back. Numbers are kept as `f64` (all our payloads are
//! shapes, percentages and byte counts — well within f64's exact-int
//! range).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Null` for anything that isn't there.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Object(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array index lookup; `Null` out of range.
    pub fn idx(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Array(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Array(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Array(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // Surrogate pairs: combine if a low surrogate
                            // follows a high one.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let lohex = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| self.err("bad pair"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(lohex)
                                            .map_err(|_| self.err("bad pair"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad pair"))?;
                                    self.i += 6;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(c) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    let mut j = self.i;
                    while j < self.b.len()
                        && self.b[j] != b'"'
                        && self.b[j] != b'\\'
                    {
                        j += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..j])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i = j;
                    let _ = c;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize a [`Value`] to compact JSON text.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Array(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Object(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(0).as_f64(), Some(1.0));
        assert!(v.get("a").idx(2).get("b").is_null());
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A \u{1F600}"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{'a': 1}").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"a":[1,2.5,{"b":null,"c":true}],"d":"x\ny"}"#;
        let v = parse(src).unwrap();
        let re = parse(&to_string(&v)).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").idx(1).as_f64(), Some(2.0));
    }

    #[test]
    fn accessor_fallbacks() {
        let v = parse("[1]").unwrap();
        assert!(v.get("nope").is_null());
        assert!(v.idx(5).is_null());
        assert_eq!(v.idx(0).as_usize(), Some(1));
    }
}
