//! Deterministic PRNG (xoshiro256**): test data, property generators
//! and workload synthesis without a `rand` dependency.

/// xoshiro256** — fast, high-quality, and tiny. Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Multiply-shift rejection-free mapping (slight modulo bias is
        // irrelevant at our ranges; tests only need coverage).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-7);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelated() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
