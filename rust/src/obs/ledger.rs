//! The bandwidth ledger — per-layer, per-codec accounting of *bytes
//! not moved*.
//!
//! Zebra's entire value proposition is DRAM traffic avoided, yet the
//! time-oriented planes (metrics, telemetry, traces) cannot answer
//! "which layer saved how much, under which codec, on the traffic we
//! actually served?". The ledger does: every fused
//! `relu_prune_encode` sweep in the reference backend, every `.zspill`
//! frame shipped by a worker, and every frame ingested by the router
//! records one `(dense, encoded, blocks, zero_blocks)` observation
//! into an atomic [`LedgerCell`] keyed `(layer, codec)`.
//!
//! From those four counters everything else is derived on read:
//! zero-block permille, achieved savings, and the Eq. 2–3 *analytic*
//! savings the same mix of blocks predicts — so achieved-vs-analytic
//! drift (payload overhead, codec mismatch, index cost) is one
//! subtraction. The HAL target envelope enters as a denominator:
//! [`CellStats::channel_us`] converts byte totals into DRAM channel
//! time under a [`TargetManifest`]'s sustained bandwidth.
//!
//! Snapshots are mergeable label-wise and ride the existing v3
//! telemetry block as synthetic `ledger.<layer>.<codec>.{dense,enc}`
//! stages ([`LedgerSnapshot::to_stages`] /
//! [`LedgerSnapshot::from_telemetry`]) — no wire bump, and the
//! router's label-wise telemetry merge aggregates ledgers across
//! workers for free.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::hal::TargetManifest;
use crate::telemetry::{StageStats, TelemetrySnapshot};

/// Stage-label prefix ledger cells use inside a telemetry snapshot.
pub const LEDGER_STAGE_PREFIX: &str = "ledger.";

/// One `(layer, codec)` accumulator. All four counters are relaxed
/// atomics — recording is four `fetch_add`s on the hot sweep path,
/// no locks, no allocation.
#[derive(Debug, Default)]
pub struct LedgerCell {
    sweeps: AtomicU64,
    dense_bytes: AtomicU64,
    encoded_bytes: AtomicU64,
    blocks: AtomicU64,
    zero_blocks: AtomicU64,
}

impl LedgerCell {
    /// Record one sweep: `dense` bytes the tensor would move raw,
    /// `encoded` bytes it actually moves (payload + index), out of
    /// `blocks` total blocks of which `zero_blocks` were all-zero.
    pub fn record(
        &self,
        dense: u64,
        encoded: u64,
        blocks: u64,
        zero_blocks: u64,
    ) {
        self.sweeps.fetch_add(1, Ordering::Relaxed);
        self.dense_bytes.fetch_add(dense, Ordering::Relaxed);
        self.encoded_bytes.fetch_add(encoded, Ordering::Relaxed);
        self.blocks.fetch_add(blocks, Ordering::Relaxed);
        self.zero_blocks.fetch_add(zero_blocks, Ordering::Relaxed);
    }

    /// A consistent-enough point read (each counter individually
    /// atomic; the cell only ever grows).
    pub fn stats(&self) -> CellStats {
        CellStats {
            sweeps: self.sweeps.load(Ordering::Relaxed),
            dense_bytes: self.dense_bytes.load(Ordering::Relaxed),
            encoded_bytes: self.encoded_bytes.load(Ordering::Relaxed),
            blocks: self.blocks.load(Ordering::Relaxed),
            zero_blocks: self.zero_blocks.load(Ordering::Relaxed),
        }
    }
}

/// Snapshot of one cell; every derived figure is computed here, on
/// read, so the hot path stores nothing but the four raw counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CellStats {
    pub sweeps: u64,
    pub dense_bytes: u64,
    pub encoded_bytes: u64,
    pub blocks: u64,
    pub zero_blocks: u64,
}

impl CellStats {
    /// Fold another snapshot in (counter addition — associative and
    /// commutative, so cross-worker merge order never matters).
    pub fn merge(&mut self, other: &CellStats) {
        self.sweeps += other.sweeps;
        self.dense_bytes += other.dense_bytes;
        self.encoded_bytes += other.encoded_bytes;
        self.blocks += other.blocks;
        self.zero_blocks += other.zero_blocks;
    }

    /// All-zero blocks per 1000 (matches the `layer.N.prune_encode`
    /// trace span's `aux` convention).
    pub fn zero_permille(&self) -> u64 {
        if self.blocks == 0 {
            return 0;
        }
        self.zero_blocks * 1000 / self.blocks
    }

    /// Achieved savings: the fraction of dense traffic that never hit
    /// the channel, from the bytes actually recorded.
    pub fn achieved_savings_pct(&self) -> f64 {
        if self.dense_bytes == 0 {
            return 0.0;
        }
        100.0
            * (self.dense_bytes.saturating_sub(self.encoded_bytes)) as f64
            / self.dense_bytes as f64
    }

    /// Eq. 2–3 analytic encoded bytes for this mix of blocks: kept
    /// blocks at the cell's mean bytes-per-block, plus a 1-bit-per-
    /// block index rounded up to whole bytes.
    pub fn analytic_bytes(&self) -> u64 {
        if self.blocks == 0 {
            return 0;
        }
        let kept = self.blocks - self.zero_blocks.min(self.blocks);
        let payload =
            (self.dense_bytes as f64 * kept as f64 / self.blocks as f64)
                .round() as u64;
        payload + self.blocks.div_ceil(8)
    }

    /// What Eq. 2–3 predicts the savings should be for the observed
    /// zero fraction. `achieved - analytic` is the drift the autotune
    /// roadmap item will steer on.
    pub fn analytic_savings_pct(&self) -> f64 {
        if self.dense_bytes == 0 {
            return 0.0;
        }
        100.0
            * (self.dense_bytes.saturating_sub(self.analytic_bytes())) as f64
            / self.dense_bytes as f64
    }

    /// DRAM channel time `(dense_us, encoded_us)` this cell's traffic
    /// costs under a HAL target's sustained bandwidth — the envelope-
    /// denominated view of the same savings.
    pub fn channel_us(&self, target: &TargetManifest) -> (f64, f64) {
        let gbps = target.sustained_gbps();
        if gbps <= 0.0 {
            return (0.0, 0.0);
        }
        // bytes / (gbps * 1e9 B/s) * 1e6 us/s
        let us = |b: u64| b as f64 / gbps / 1e3;
        (us(self.dense_bytes), us(self.encoded_bytes))
    }
}

/// The live registry: `(layer, codec) -> Arc<LedgerCell>`. Cells are
/// created on first touch and handed out as `Arc`s so hot paths hold
/// a direct pointer and never re-lock the map.
#[derive(Debug, Default)]
pub struct Ledger {
    cells: Mutex<BTreeMap<(String, String), Arc<LedgerCell>>>,
}

impl Ledger {
    pub fn new() -> Arc<Ledger> {
        Arc::new(Ledger::default())
    }

    /// Get-or-create the cell for `(layer, codec)`. Dots are the
    /// stage-label field separator, so they are rewritten to `-`
    /// (same defensive move as telemetry's label discipline).
    pub fn cell(&self, layer: &str, codec: &str) -> Arc<LedgerCell> {
        let key = (sanitize(layer), sanitize(codec));
        let mut map = self.cells.lock().unwrap();
        Arc::clone(map.entry(key).or_default())
    }

    pub fn snapshot(&self) -> LedgerSnapshot {
        let map = self.cells.lock().unwrap();
        LedgerSnapshot {
            cells: map
                .iter()
                .map(|(k, c)| (k.clone(), c.stats()))
                .collect(),
        }
    }
}

fn sanitize(s: &str) -> String {
    s.replace('.', "-")
}

/// A point-in-time, mergeable view of a [`Ledger`] (or of several,
/// merged label-wise across workers).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LedgerSnapshot {
    /// `(layer, codec) -> stats`, sorted for stable rendering.
    pub cells: BTreeMap<(String, String), CellStats>,
}

impl LedgerSnapshot {
    /// Label-wise counter merge (associative + commutative).
    pub fn merge(&mut self, other: &LedgerSnapshot) {
        for (key, stats) in &other.cells {
            self.cells.entry(key.clone()).or_default().merge(stats);
        }
    }

    /// Pack every cell into a telemetry snapshot as two synthetic
    /// stages, so ledgers ride the v3 MetricsResp telemetry block
    /// unchanged:
    ///
    /// ```text
    /// ledger.<layer>.<codec>.dense  {nanos: blocks,      calls: sweeps, bytes: dense_bytes}
    /// ledger.<layer>.<codec>.enc    {nanos: zero_blocks, calls: sweeps, bytes: encoded_bytes}
    /// ```
    ///
    /// The field abuse (nanos carrying a block count) stays inside
    /// this module: [`from_telemetry`](Self::from_telemetry) is the
    /// only reader, and the export plane renders ledger stages
    /// through it, never as raw `zebra_stage_*`.
    pub fn to_stages(&self, telemetry: &mut TelemetrySnapshot) {
        for ((layer, codec), s) in &self.cells {
            telemetry.stages.insert(
                format!("{LEDGER_STAGE_PREFIX}{layer}.{codec}.dense"),
                StageStats {
                    nanos: s.blocks,
                    calls: s.sweeps,
                    bytes: s.dense_bytes,
                },
            );
            telemetry.stages.insert(
                format!("{LEDGER_STAGE_PREFIX}{layer}.{codec}.enc"),
                StageStats {
                    nanos: s.zero_blocks,
                    calls: s.sweeps,
                    bytes: s.encoded_bytes,
                },
            );
        }
    }

    /// Reassemble a snapshot from the `ledger.*` stages of a
    /// (possibly cross-worker-merged) telemetry snapshot. Sweeps are
    /// taken from the `.dense` stage only, so telemetry-merge →
    /// parse gives the same answer as parse → ledger-merge.
    /// Malformed labels are skipped — stage blocks come off the wire.
    pub fn from_telemetry(telemetry: &TelemetrySnapshot) -> LedgerSnapshot {
        let mut out = LedgerSnapshot::default();
        for (label, stats) in &telemetry.stages {
            let Some(rest) = label.strip_prefix(LEDGER_STAGE_PREFIX) else {
                continue;
            };
            let parts: Vec<&str> = rest.split('.').collect();
            let [layer, codec, kind] = parts[..] else {
                continue;
            };
            if kind != "dense" && kind != "enc" {
                continue;
            }
            let cell = out
                .cells
                .entry((layer.to_string(), codec.to_string()))
                .or_default();
            if kind == "dense" {
                cell.sweeps += stats.calls;
                cell.blocks += stats.nanos;
                cell.dense_bytes += stats.bytes;
            } else {
                cell.zero_blocks += stats.nanos;
                cell.encoded_bytes += stats.bytes;
            }
        }
        out
    }

    /// Whole-ledger totals (every cell merged into one).
    pub fn total(&self) -> CellStats {
        let mut t = CellStats::default();
        for s in self.cells.values() {
            t.merge(s);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(cells: &[(&str, &str, [u64; 5])]) -> LedgerSnapshot {
        let mut s = LedgerSnapshot::default();
        for (layer, codec, [sw, d, e, b, z]) in cells {
            s.cells.insert(
                (layer.to_string(), codec.to_string()),
                CellStats {
                    sweeps: *sw,
                    dense_bytes: *d,
                    encoded_bytes: *e,
                    blocks: *b,
                    zero_blocks: *z,
                },
            );
        }
        s
    }

    #[test]
    fn cell_records_and_derives() {
        let ledger = Ledger::new();
        let cell = ledger.cell("l0", "zero-block");
        // 128 blocks of 16 B each; half zero. Encoded = 64 kept
        // blocks * 16 B + 128/8 index bytes.
        cell.record(2048, 1040, 128, 64);
        let s = cell.stats();
        assert_eq!(s.sweeps, 1);
        assert_eq!(s.zero_permille(), 500);
        // Achieved == analytic when the payload carries no overhead.
        assert_eq!(s.analytic_bytes(), 1040);
        assert!(
            (s.achieved_savings_pct() - s.analytic_savings_pct()).abs()
                < 1e-9
        );
        assert!((s.achieved_savings_pct() - 49.21875).abs() < 1e-6);
        // Same key → same cell; dots sanitize to dashes.
        cell.record(2048, 1040, 128, 64);
        assert_eq!(ledger.cell("l0", "zero-block").stats().sweeps, 2);
        let weird = ledger.cell("layer.0", "zero.block");
        weird.record(1, 1, 1, 0);
        assert!(ledger
            .snapshot()
            .cells
            .contains_key(&("layer-0".into(), "zero-block".into())));
    }

    #[test]
    fn empty_cells_never_divide_by_zero() {
        let s = CellStats::default();
        assert_eq!(s.zero_permille(), 0);
        assert_eq!(s.achieved_savings_pct(), 0.0);
        assert_eq!(s.analytic_bytes(), 0);
        assert_eq!(s.analytic_savings_pct(), 0.0);
        let t = TargetManifest::default();
        assert_eq!(s.channel_us(&t), (0.0, 0.0));
    }

    #[test]
    fn channel_time_uses_the_sustained_envelope() {
        let t = TargetManifest {
            dram_gbps: 10.0,
            sustained_fraction: 0.5,
            ..TargetManifest::default()
        };
        let s = CellStats {
            dense_bytes: 5_000_000_000, // 1 s at 5 GB/s sustained
            encoded_bytes: 2_500_000_000,
            ..CellStats::default()
        };
        let (d, e) = s.channel_us(&t);
        assert!((d - 1e6).abs() < 1.0, "{d}");
        assert!((e - 5e5).abs() < 1.0, "{e}");
    }

    #[test]
    fn snapshot_merge_is_associative_across_three_workers() {
        // Three workers with overlapping and disjoint cells — the
        // shape a router aggregation actually sees.
        let a = snap(&[
            ("l0", "zero-block", [3, 300, 120, 30, 18]),
            ("l1", "zero-block", [3, 600, 200, 15, 9]),
        ]);
        let b = snap(&[
            ("l0", "zero-block", [5, 500, 210, 50, 29]),
            ("spill_out", "zero-block", [2, 900, 400, 45, 20]),
        ]);
        let c = snap(&[
            ("l1", "zero-block", [7, 1400, 480, 35, 22]),
            ("spill_in", "rle-zero", [1, 111, 44, 0, 0]),
        ]);
        // (a+b)+c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a+(b+c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right);
        // and commutes: c+(b+a)
        let mut rev = c.clone();
        rev.merge(&b);
        rev.merge(&a);
        assert_eq!(left, rev);
        // Totals fold every cell.
        assert_eq!(left.total().dense_bytes, 300 + 600 + 500 + 900 + 1400 + 111);
    }

    #[test]
    fn stage_packing_roundtrips_and_merges_commute() {
        let a = snap(&[
            ("l0", "zero-block", [3, 300, 120, 30, 18]),
            ("l1", "zero-block", [3, 600, 200, 15, 9]),
        ]);
        let b = snap(&[("l0", "zero-block", [5, 500, 210, 50, 29])]);
        // Roundtrip through a telemetry snapshot.
        let mut tele = TelemetrySnapshot::default();
        a.to_stages(&mut tele);
        assert_eq!(LedgerSnapshot::from_telemetry(&tele), a);
        // Telemetry-merge then parse == parse then ledger-merge.
        let mut tele_b = TelemetrySnapshot::default();
        b.to_stages(&mut tele_b);
        tele.merge(&tele_b);
        let via_telemetry = LedgerSnapshot::from_telemetry(&tele);
        let mut via_ledger = a.clone();
        via_ledger.merge(&b);
        assert_eq!(via_telemetry, via_ledger);
    }

    #[test]
    fn malformed_ledger_stages_are_skipped() {
        let mut tele = TelemetrySnapshot::default();
        for label in [
            "ledger.too.many.parts.dense",
            "ledger.short",
            "ledger.l0.codec.unknown",
            "serve.execute",
        ] {
            tele.stages
                .insert(label.into(), StageStats { nanos: 1, calls: 1, bytes: 1 });
        }
        assert!(LedgerSnapshot::from_telemetry(&tele)
            .cells
            .is_empty());
    }
}
