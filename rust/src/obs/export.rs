//! The unified metrics-export plane: one registry merging the serving
//! counters ([`crate::coordinator::Metrics`] frozen as a
//! [`MetricsSnapshot`]), the router's cluster-wide [`ClusterStats`],
//! and the stage-level [`TelemetrySnapshot`] — exposed as Prometheus
//! text exposition and as JSON.
//!
//! This is also the home of the telemetry wire codec: wire v3's
//! `MetricsResp` appends an [`encode_telemetry`] block after the
//! stats, so `zebra obs` / loadgen can scrape stage timings from live
//! nodes instead of waiting for the exit-time report.

use std::collections::BTreeMap;

use crate::cluster::metrics::{ClusterStats, MetricsSnapshot};
use crate::cluster::wire::FrameError;
use crate::telemetry::{StageStats, TelemetrySnapshot};
use crate::util::json::Value;

/// Cap on stages in one telemetry wire block (far above any real
/// registry; bounds allocation from a hostile count).
const MAX_STAGES: usize = 4096;

/// Cap on a stage label's wire length.
const MAX_STAGE_LABEL: usize = 256;

/// Wire encoding of a telemetry snapshot: `[n_stages: u16]` then per
/// stage `[label_len: u16][label][nanos: u64][calls: u64][bytes:
/// u64]`, little-endian, labels in BTreeMap (sorted) order so the
/// encoding is canonical.
pub fn encode_telemetry(snap: &TelemetrySnapshot) -> Vec<u8> {
    let n = snap.stages.len().min(MAX_STAGES);
    let mut out = Vec::with_capacity(2 + n * 40);
    out.extend_from_slice(&(n as u16).to_le_bytes());
    for (label, s) in snap.stages.iter().take(n) {
        let bytes = label.as_bytes();
        let len = bytes.len().min(MAX_STAGE_LABEL);
        let mut cut = len;
        while !label.is_char_boundary(cut) {
            cut -= 1;
        }
        out.extend_from_slice(&(cut as u16).to_le_bytes());
        out.extend_from_slice(&bytes[..cut]);
        out.extend_from_slice(&s.nanos.to_le_bytes());
        out.extend_from_slice(&s.calls.to_le_bytes());
        out.extend_from_slice(&s.bytes.to_le_bytes());
    }
    out
}

/// Parse one telemetry block off the front of `payload`; returns the
/// snapshot and the remaining bytes. Strictly bounds-checked.
pub fn parse_telemetry_prefix(
    payload: &[u8],
) -> Result<(TelemetrySnapshot, &[u8]), FrameError> {
    if payload.len() < 2 {
        return Err(FrameError::Malformed("telemetry block too short"));
    }
    let n = u16::from_le_bytes([payload[0], payload[1]]) as usize;
    if n > MAX_STAGES {
        return Err(FrameError::Malformed(
            "telemetry block declares an absurd stage count",
        ));
    }
    let mut stages = BTreeMap::new();
    let mut off = 2usize;
    for _ in 0..n {
        if payload.len() < off + 2 {
            return Err(FrameError::Malformed(
                "telemetry stage shorter than its label length",
            ));
        }
        let label_len =
            u16::from_le_bytes([payload[off], payload[off + 1]]) as usize;
        if label_len > MAX_STAGE_LABEL {
            return Err(FrameError::Malformed(
                "telemetry stage label over the length cap",
            ));
        }
        off += 2;
        if payload.len() < off + label_len + 24 {
            return Err(FrameError::Malformed(
                "telemetry stage shorter than its declared fields",
            ));
        }
        let label = std::str::from_utf8(&payload[off..off + label_len])
            .map_err(|_| {
                FrameError::Malformed("telemetry stage label not UTF-8")
            })?
            .to_string();
        off += label_len;
        let u64_at = |o: usize| {
            u64::from_le_bytes(payload[o..o + 8].try_into().expect("8"))
        };
        stages.insert(
            label,
            StageStats {
                nanos: u64_at(off),
                calls: u64_at(off + 8),
                bytes: u64_at(off + 16),
            },
        );
        off += 24;
    }
    Ok((TelemetrySnapshot { stages }, &payload[off..]))
}

/// Strict parse of [`encode_telemetry`] output (trailing bytes error).
pub fn parse_telemetry(
    payload: &[u8],
) -> Result<TelemetrySnapshot, FrameError> {
    let (snap, rest) = parse_telemetry_prefix(payload)?;
    if !rest.is_empty() {
        return Err(FrameError::Malformed(
            "telemetry block has trailing bytes",
        ));
    }
    Ok(snap)
}

/// Everything one scrape knows: the counter/histogram plane and the
/// stage-timing plane, merged from however many nodes answered.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsReport {
    /// Aggregate + router counters. A single node (bare worker,
    /// in-process server) reports with the router counters zeroed and
    /// `workers_total == 0`.
    pub stats: ClusterStats,
    pub telemetry: TelemetrySnapshot,
}

impl ObsReport {
    /// Wrap one node's snapshot (no router in the picture).
    pub fn single_node(
        snapshot: MetricsSnapshot,
        telemetry: TelemetrySnapshot,
    ) -> ObsReport {
        ObsReport {
            stats: ClusterStats { aggregate: snapshot, ..Default::default() },
            telemetry,
        }
    }

    /// Decode a `MetricsResp` payload from any node kind and wire
    /// version: a router's [`ClusterStats`] or a worker's
    /// [`MetricsSnapshot`], with the v3 telemetry block appended when
    /// the responder saw a v3 request. Strict about trailing bytes in
    /// every combination.
    pub fn parse_wire(
        version: u16,
        payload: &[u8],
    ) -> Result<ObsReport, FrameError> {
        let telemetry_tail =
            |rest: &[u8]| -> Result<TelemetrySnapshot, FrameError> {
                if rest.is_empty() {
                    Ok(TelemetrySnapshot::default())
                } else if version >= 3 {
                    parse_telemetry(rest)
                } else {
                    Err(FrameError::Malformed(
                        "metrics payload has trailing bytes",
                    ))
                }
            };
        if let Ok((stats, rest)) = ClusterStats::parse_prefix(payload) {
            if let Ok(telemetry) = telemetry_tail(rest) {
                return Ok(ObsReport { stats, telemetry });
            }
        }
        let (snap, rest) = MetricsSnapshot::parse_prefix(payload)?;
        let telemetry = telemetry_tail(rest)?;
        Ok(ObsReport::single_node(snap, telemetry))
    }

    /// Encode as a `MetricsResp` payload for a requester speaking
    /// `version` (the telemetry block only rides on v3+ — older
    /// clients parse the stats strictly and would reject it).
    pub fn encode_wire(&self, version: u16, router: bool) -> Vec<u8> {
        let mut out = if router {
            self.stats.encode()
        } else {
            self.stats.aggregate.encode()
        };
        if version >= 3 {
            out.extend_from_slice(&encode_telemetry(&self.telemetry));
        }
        out
    }

    /// Prometheus text exposition
    /// (<https://prometheus.io/docs/instrumenting/exposition_formats/>):
    /// one stable name per counter, classes/quantiles/stages as
    /// labels. Names are documented in `rust/docs/observability.md`.
    pub fn prometheus(&self) -> String {
        let a = &self.stats.aggregate;
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: u64| {
            out.push_str(&format!(
                "# HELP zebra_{name} {help}\n# TYPE zebra_{name} counter\n\
                 zebra_{name} {v}\n"
            ));
        };
        counter("requests_total", "Requests submitted", a.requests);
        counter("responses_total", "Requests answered", a.responses);
        counter("batches_total", "Batches executed", a.batches);
        counter(
            "batched_items_total",
            "Real items across executed batches",
            a.batched_items,
        );
        counter("padded_slots_total", "Padding slots executed", a.padded_slots);
        counter("dense_bytes_total", "Eq. 2 dense activation bytes", a.dense_bytes);
        counter("stored_bytes_total", "Eq. 2 stored activation bytes", a.stored_bytes);
        counter("index_bytes_total", "Eq. 3 block-index bytes", a.index_bytes);
        counter(
            "shipped_spill_bytes_total",
            "Shipped .zspill frame bytes",
            a.shipped_spill_bytes,
        );
        counter("deadline_miss_total", "Requests served past deadline", a.deadline_miss);
        counter("failed_total", "Admitted requests that failed", a.failed);
        out.push_str(&format!(
            "# HELP zebra_shed_total Requests shed by admission control\n\
             # TYPE zebra_shed_total counter\n\
             zebra_shed_total{{class=\"low\"}} {}\n\
             zebra_shed_total{{class=\"normal\"}} {}\n\
             zebra_shed_total{{class=\"high\"}} {}\n",
            a.shed_low, a.shed_normal, a.shed_high
        ));
        out.push_str(&format!(
            "# HELP zebra_queue_depth Admission queue occupancy\n\
             # TYPE zebra_queue_depth gauge\nzebra_queue_depth {}\n",
            a.queue_depth
        ));
        out.push_str(&format!(
            "# HELP zebra_exec_threads Compute threads across nodes\n\
             # TYPE zebra_exec_threads gauge\nzebra_exec_threads {}\n",
            a.exec_threads
        ));
        out.push_str(&format!(
            "# HELP zebra_bw_reduction_pct Eq. 2-3 bandwidth reduction\n\
             # TYPE zebra_bw_reduction_pct gauge\n\
             zebra_bw_reduction_pct {:.3}\n",
            a.reduction_pct()
        ));
        out.push_str(
            "# HELP zebra_latency_us Serving latency percentile \
             (bucket upper bound)\n# TYPE zebra_latency_us gauge\n",
        );
        for (q, p) in [("0.5", 0.5), ("0.95", 0.95), ("0.99", 0.99)] {
            out.push_str(&format!(
                "zebra_latency_us{{quantile=\"{q}\"}} {}\n",
                a.latency_percentile_us(p)
            ));
        }
        let s = &self.stats;
        if s.workers_total > 0 {
            let mut g = |name: &str, help: &str, v: u64| {
                out.push_str(&format!(
                    "# HELP zebra_router_{name} {help}\n\
                     # TYPE zebra_router_{name} counter\n\
                     zebra_router_{name} {v}\n"
                ));
            };
            g("workers_total", "Configured workers", s.workers_total);
            g("workers_alive", "Workers answering heartbeats", s.workers_alive);
            g("routed_total", "Submits dispatched", s.routed);
            g("retries_total", "Failover re-dispatches", s.retries);
            g("rejected_total", "Terminal refusals", s.rejected);
            g("failed_total", "Router-side faults", s.failed);
            out.push_str(
                "# HELP zebra_router_latency_us Router dispatch latency \
                 percentile\n# TYPE zebra_router_latency_us gauge\n",
            );
            for (q, p) in [("0.5", 0.5), ("0.95", 0.95), ("0.99", 0.99)] {
                out.push_str(&format!(
                    "zebra_router_latency_us{{quantile=\"{q}\"}} {}\n",
                    s.router_percentile_us(p)
                ));
            }
        }
        if !self.telemetry.stages.is_empty() {
            out.push_str(
                "# HELP zebra_stage_nanos_total Wall time per stage\n\
                 # TYPE zebra_stage_nanos_total counter\n",
            );
            for (label, st) in &self.telemetry.stages {
                out.push_str(&format!(
                    "zebra_stage_nanos_total{{stage=\"{label}\"}} {}\n",
                    st.nanos
                ));
            }
            out.push_str(
                "# HELP zebra_stage_calls_total Invocations per stage\n\
                 # TYPE zebra_stage_calls_total counter\n",
            );
            for (label, st) in &self.telemetry.stages {
                out.push_str(&format!(
                    "zebra_stage_calls_total{{stage=\"{label}\"}} {}\n",
                    st.calls
                ));
            }
            out.push_str(
                "# HELP zebra_stage_bytes_total Bytes per stage\n\
                 # TYPE zebra_stage_bytes_total counter\n",
            );
            for (label, st) in &self.telemetry.stages {
                out.push_str(&format!(
                    "zebra_stage_bytes_total{{stage=\"{label}\"}} {}\n",
                    st.bytes
                ));
            }
        }
        out
    }

    /// The same registry as a JSON document (`zebra obs --json`,
    /// loadgen's scrape samples, `BENCH_PR8.json`'s cluster section).
    pub fn to_json(&self) -> Value {
        let a = &self.stats.aggregate;
        let mut counters = BTreeMap::new();
        for (k, v) in [
            ("requests", a.requests),
            ("responses", a.responses),
            ("batches", a.batches),
            ("batched_items", a.batched_items),
            ("padded_slots", a.padded_slots),
            ("dense_bytes", a.dense_bytes),
            ("stored_bytes", a.stored_bytes),
            ("index_bytes", a.index_bytes),
            ("shipped_spill_bytes", a.shipped_spill_bytes),
            ("exec_threads", a.exec_threads),
            ("shed_low", a.shed_low),
            ("shed_normal", a.shed_normal),
            ("shed_high", a.shed_high),
            ("deadline_miss", a.deadline_miss),
            ("queue_depth", a.queue_depth),
            ("failed", a.failed),
        ] {
            counters.insert(k.to_string(), Value::Num(v as f64));
        }
        let mut latency = BTreeMap::new();
        for (k, p) in [("p50", 0.5), ("p95", 0.95), ("p99", 0.99)] {
            latency.insert(
                format!("{k}_us"),
                Value::Num(a.latency_percentile_us(p) as f64),
            );
        }
        let s = &self.stats;
        let mut router = BTreeMap::new();
        for (k, v) in [
            ("workers_total", s.workers_total),
            ("workers_alive", s.workers_alive),
            ("routed", s.routed),
            ("retries", s.retries),
            ("rejected", s.rejected),
            ("shed_low", s.shed_low),
            ("shed_normal", s.shed_normal),
            ("shed_high", s.shed_high),
            ("failed", s.failed),
            ("spill_frames_in", s.spill_frames_in),
            ("spill_bytes_in", s.spill_bytes_in),
        ] {
            router.insert(k.to_string(), Value::Num(v as f64));
        }
        let mut stages = BTreeMap::new();
        for (label, st) in &self.telemetry.stages {
            let mut m = BTreeMap::new();
            m.insert("nanos".to_string(), Value::Num(st.nanos as f64));
            m.insert("calls".to_string(), Value::Num(st.calls as f64));
            m.insert("bytes".to_string(), Value::Num(st.bytes as f64));
            stages.insert(label.clone(), Value::Object(m));
        }
        let mut o = BTreeMap::new();
        o.insert("counters".to_string(), Value::Object(counters));
        o.insert("latency".to_string(), Value::Object(latency));
        o.insert("router".to_string(), Value::Object(router));
        o.insert(
            "bw_reduction_pct".to_string(),
            Value::Num((a.reduction_pct() * 1000.0).round() / 1000.0),
        );
        o.insert("telemetry".to_string(), Value::Object(stages));
        Value::Object(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_telemetry() -> TelemetrySnapshot {
        let mut t = TelemetrySnapshot::default();
        t.stages.insert(
            "serve.execute".into(),
            StageStats { nanos: 5_000_000, calls: 12, bytes: 0 },
        );
        t.stages.insert(
            "wire.handle".into(),
            StageStats { nanos: 800_000, calls: 40, bytes: 4096 },
        );
        t
    }

    fn sample_snapshot() -> MetricsSnapshot {
        MetricsSnapshot {
            requests: 100,
            responses: 97,
            dense_bytes: 1000,
            stored_bytes: 400,
            index_bytes: 100,
            shed_low: 3,
            latency_buckets: vec![0, 0, 0, 0, 0, 0, 0, 97],
            ..Default::default()
        }
    }

    #[test]
    fn telemetry_block_roundtrips_and_rejects_corruption() {
        let t = sample_telemetry();
        let bytes = encode_telemetry(&t);
        assert_eq!(parse_telemetry(&bytes).unwrap(), t);
        // Empty snapshot roundtrips.
        let e = TelemetrySnapshot::default();
        assert_eq!(parse_telemetry(&encode_telemetry(&e)).unwrap(), e);
        // Every truncation errors.
        for cut in 0..bytes.len() {
            assert!(parse_telemetry(&bytes[..cut]).is_err(), "cut {cut}");
        }
        // Trailing garbage errors strictly, parses via prefix.
        let mut noisy = bytes.clone();
        noisy.extend_from_slice(b"xx");
        assert!(parse_telemetry(&noisy).is_err());
        let (back, rest) = parse_telemetry_prefix(&noisy).unwrap();
        assert_eq!(back, t);
        assert_eq!(rest, b"xx");
        // Absurd stage count errors before allocating.
        let mut bad = bytes.clone();
        bad[0..2].copy_from_slice(&u16::MAX.to_le_bytes());
        assert!(parse_telemetry(&bad).is_err());
    }

    #[test]
    fn wire_payload_dispatches_on_node_kind_and_version() {
        let tel = sample_telemetry();
        // Worker shape, v3: snapshot + telemetry.
        let single =
            ObsReport::single_node(sample_snapshot(), tel.clone());
        let bytes = single.encode_wire(3, false);
        let back = ObsReport::parse_wire(3, &bytes).unwrap();
        assert_eq!(back.stats.aggregate, single.stats.aggregate);
        assert_eq!(back.telemetry, tel);
        assert_eq!(back.stats.workers_total, 0);
        // Worker shape, v2: no telemetry block; old parse stays exact.
        let v2 = single.encode_wire(2, false);
        assert_eq!(
            MetricsSnapshot::parse(&v2).unwrap(),
            single.stats.aggregate
        );
        assert!(ObsReport::parse_wire(2, &v2).unwrap().telemetry.stages.is_empty());
        // Router shape, v3.
        let router = ObsReport {
            stats: ClusterStats {
                aggregate: sample_snapshot(),
                workers_total: 2,
                workers_alive: 2,
                routed: 50,
                ..Default::default()
            },
            telemetry: tel.clone(),
        };
        let bytes = router.encode_wire(3, true);
        let back = ObsReport::parse_wire(3, &bytes).unwrap();
        assert_eq!(back.stats, router.stats);
        assert_eq!(back.telemetry, tel);
        // Router shape, v2 is byte-identical to the legacy encoding.
        assert_eq!(router.encode_wire(2, true), router.stats.encode());
        // Trailing garbage after the telemetry block errors.
        let mut noisy = router.encode_wire(3, true);
        noisy.push(7);
        assert!(ObsReport::parse_wire(3, &noisy).is_err());
        // A v2 reader handed trailing bytes errors (never mis-parses).
        let mut v2noisy = router.stats.encode();
        v2noisy.push(7);
        assert!(ObsReport::parse_wire(2, &v2noisy).is_err());
    }

    #[test]
    fn prometheus_exposition_carries_every_plane() {
        let report = ObsReport {
            stats: ClusterStats {
                aggregate: sample_snapshot(),
                workers_total: 3,
                workers_alive: 2,
                routed: 44,
                ..Default::default()
            },
            telemetry: sample_telemetry(),
        };
        let text = report.prometheus();
        assert!(text.contains("zebra_requests_total 100"), "{text}");
        assert!(
            text.contains("zebra_shed_total{class=\"low\"} 3"),
            "{text}"
        );
        assert!(
            text.contains("zebra_latency_us{quantile=\"0.99\"}"),
            "{text}"
        );
        assert!(text.contains("zebra_router_workers_alive 2"), "{text}");
        assert!(
            text.contains(
                "zebra_stage_nanos_total{stage=\"serve.execute\"} 5000000"
            ),
            "{text}"
        );
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
            assert!(parts.next().unwrap().starts_with("zebra_"), "{line}");
        }
        // Single-node reports omit the router section.
        let single = ObsReport::single_node(
            sample_snapshot(),
            TelemetrySnapshot::default(),
        );
        assert!(!single.prometheus().contains("zebra_router_"), "single");
    }

    #[test]
    fn json_counters_match_the_snapshot() {
        let report = ObsReport::single_node(
            sample_snapshot(),
            sample_telemetry(),
        );
        let v = report.to_json();
        let text = crate::util::json::to_string(&v);
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(
            back.get("counters").get("requests").as_usize(),
            Some(100)
        );
        assert_eq!(
            back.get("counters").get("shed_low").as_usize(),
            Some(3)
        );
        assert_eq!(
            back.get("telemetry")
                .get("serve.execute")
                .get("calls")
                .as_usize(),
            Some(12)
        );
        assert!(back.get("latency").get("p99_us").as_f64().is_some());
        assert!(
            (back.get("bw_reduction_pct").as_f64().unwrap() - 50.0).abs()
                < 1e-9
        );
    }
}
