//! The unified metrics-export plane: one registry merging the serving
//! counters ([`crate::coordinator::Metrics`] frozen as a
//! [`MetricsSnapshot`]), the router's cluster-wide [`ClusterStats`],
//! and the stage-level [`TelemetrySnapshot`] — exposed as Prometheus
//! text exposition and as JSON.
//!
//! This is also the home of the telemetry wire codec: wire v3's
//! `MetricsResp` appends an [`encode_telemetry`] block after the
//! stats, so `zebra obs` / loadgen can scrape stage timings from live
//! nodes instead of waiting for the exit-time report.

use std::collections::BTreeMap;

use super::ledger::{CellStats, LedgerSnapshot, LEDGER_STAGE_PREFIX};
use super::slo::{parse_brownout, parse_slo, SLO_STAGE_PREFIX};
use crate::cluster::metrics::{ClusterStats, MetricsSnapshot};
use crate::cluster::wire::FrameError;
use crate::telemetry::{StageStats, TelemetrySnapshot};
use crate::util::json::Value;

/// Stage-label prefix the router uses for per-worker gauges
/// (`cluster.w<idx>.link` / `cluster.w<idx>.node`).
pub const WORKER_STAGE_PREFIX: &str = "cluster.w";

/// Stage-label prefix the router uses for per-worker circuit-breaker
/// status (`breaker.w<idx>` — `nanos` = state code, `calls` =
/// cumulative transitions).
pub const BREAKER_STAGE_PREFIX: &str = "breaker.w";

/// One worker's row in a gathered report, reassembled from the
/// router-injected `cluster.w<idx>.*` stages (`zebra top`'s per-worker
/// table).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerView {
    /// Answering heartbeats at gather time.
    pub alive: bool,
    /// Router-side in-flight requests on this link.
    pub in_flight: u64,
    /// The worker's admission-queue depth at its last snapshot.
    pub queue_depth: u64,
    pub responses: u64,
    /// Requests this worker shed, all classes.
    pub shed: u64,
}

/// Reassemble per-worker rows from a gathered report's telemetry.
/// Malformed labels are skipped — stage blocks come off the wire.
pub fn parse_workers(
    telemetry: &TelemetrySnapshot,
) -> BTreeMap<u64, WorkerView> {
    let mut out: BTreeMap<u64, WorkerView> = BTreeMap::new();
    for (label, stats) in &telemetry.stages {
        let Some(rest) = label.strip_prefix(WORKER_STAGE_PREFIX) else {
            continue;
        };
        let parts: Vec<&str> = rest.split('.').collect();
        let [idx, kind] = parts[..] else { continue };
        let Ok(idx) = idx.parse::<u64>() else { continue };
        if kind != "link" && kind != "node" {
            continue;
        }
        let view = out.entry(idx).or_default();
        if kind == "link" {
            view.in_flight = stats.nanos;
            view.alive = stats.calls > 0;
        } else {
            view.queue_depth = stats.nanos;
            view.responses = stats.calls;
            view.shed = stats.bytes;
        }
    }
    out
}

/// One worker link's circuit-breaker status off the wire.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BreakerView {
    /// State code: 0 = closed, 1 = open, 2 = half-open.
    pub state: u64,
    /// Cumulative state transitions since the router started.
    pub transitions: u64,
}

impl BreakerView {
    /// Human name for the state code (mirrors
    /// [`crate::faults::BreakerState::name`]).
    pub fn state_name(&self) -> &'static str {
        match self.state {
            0 => "closed",
            1 => "open",
            2 => "half-open",
            _ => "unknown",
        }
    }
}

/// Reassemble per-worker breaker rows from the `breaker.w<idx>`
/// stages of a gathered report. Malformed labels are skipped.
pub fn parse_breakers(
    telemetry: &TelemetrySnapshot,
) -> BTreeMap<u64, BreakerView> {
    let mut out: BTreeMap<u64, BreakerView> = BTreeMap::new();
    for (label, stats) in &telemetry.stages {
        let Some(rest) = label.strip_prefix(BREAKER_STAGE_PREFIX) else {
            continue;
        };
        let Ok(idx) = rest.parse::<u64>() else { continue };
        out.insert(
            idx,
            BreakerView { state: stats.nanos, transitions: stats.calls },
        );
    }
    out
}

/// True for synthetic stages that belong to a dedicated export plane
/// (ledger, SLO, per-worker) — rendered as their own metric families,
/// never as generic `zebra_stage_*` samples.
fn is_plane_stage(label: &str) -> bool {
    label.starts_with(LEDGER_STAGE_PREFIX)
        || label.starts_with(SLO_STAGE_PREFIX)
        || label.starts_with(WORKER_STAGE_PREFIX)
        || label.starts_with(BREAKER_STAGE_PREFIX)
}

/// Cap on stages in one telemetry wire block (far above any real
/// registry; bounds allocation from a hostile count).
const MAX_STAGES: usize = 4096;

/// Cap on a stage label's wire length.
const MAX_STAGE_LABEL: usize = 256;

/// Wire encoding of a telemetry snapshot: `[n_stages: u16]` then per
/// stage `[label_len: u16][label][nanos: u64][calls: u64][bytes:
/// u64]`, little-endian, labels in BTreeMap (sorted) order so the
/// encoding is canonical.
pub fn encode_telemetry(snap: &TelemetrySnapshot) -> Vec<u8> {
    let n = snap.stages.len().min(MAX_STAGES);
    let mut out = Vec::with_capacity(2 + n * 40);
    out.extend_from_slice(&(n as u16).to_le_bytes());
    for (label, s) in snap.stages.iter().take(n) {
        let bytes = label.as_bytes();
        let len = bytes.len().min(MAX_STAGE_LABEL);
        let mut cut = len;
        while !label.is_char_boundary(cut) {
            cut -= 1;
        }
        out.extend_from_slice(&(cut as u16).to_le_bytes());
        out.extend_from_slice(&bytes[..cut]);
        out.extend_from_slice(&s.nanos.to_le_bytes());
        out.extend_from_slice(&s.calls.to_le_bytes());
        out.extend_from_slice(&s.bytes.to_le_bytes());
    }
    out
}

/// Parse one telemetry block off the front of `payload`; returns the
/// snapshot and the remaining bytes. Strictly bounds-checked.
pub fn parse_telemetry_prefix(
    payload: &[u8],
) -> Result<(TelemetrySnapshot, &[u8]), FrameError> {
    if payload.len() < 2 {
        return Err(FrameError::Malformed("telemetry block too short"));
    }
    let n = u16::from_le_bytes([payload[0], payload[1]]) as usize;
    if n > MAX_STAGES {
        return Err(FrameError::Malformed(
            "telemetry block declares an absurd stage count",
        ));
    }
    let mut stages = BTreeMap::new();
    let mut off = 2usize;
    for _ in 0..n {
        if payload.len() < off + 2 {
            return Err(FrameError::Malformed(
                "telemetry stage shorter than its label length",
            ));
        }
        let label_len =
            u16::from_le_bytes([payload[off], payload[off + 1]]) as usize;
        if label_len > MAX_STAGE_LABEL {
            return Err(FrameError::Malformed(
                "telemetry stage label over the length cap",
            ));
        }
        off += 2;
        if payload.len() < off + label_len + 24 {
            return Err(FrameError::Malformed(
                "telemetry stage shorter than its declared fields",
            ));
        }
        let label = std::str::from_utf8(&payload[off..off + label_len])
            .map_err(|_| {
                FrameError::Malformed("telemetry stage label not UTF-8")
            })?
            .to_string();
        off += label_len;
        let u64_at = |o: usize| {
            u64::from_le_bytes(payload[o..o + 8].try_into().expect("8"))
        };
        stages.insert(
            label,
            StageStats {
                nanos: u64_at(off),
                calls: u64_at(off + 8),
                bytes: u64_at(off + 16),
            },
        );
        off += 24;
    }
    Ok((TelemetrySnapshot { stages }, &payload[off..]))
}

/// Strict parse of [`encode_telemetry`] output (trailing bytes error).
pub fn parse_telemetry(
    payload: &[u8],
) -> Result<TelemetrySnapshot, FrameError> {
    let (snap, rest) = parse_telemetry_prefix(payload)?;
    if !rest.is_empty() {
        return Err(FrameError::Malformed(
            "telemetry block has trailing bytes",
        ));
    }
    Ok(snap)
}

/// Everything one scrape knows: the counter/histogram plane and the
/// stage-timing plane, merged from however many nodes answered.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsReport {
    /// Aggregate + router counters. A single node (bare worker,
    /// in-process server) reports with the router counters zeroed and
    /// `workers_total == 0`.
    pub stats: ClusterStats,
    pub telemetry: TelemetrySnapshot,
}

impl ObsReport {
    /// Wrap one node's snapshot (no router in the picture).
    pub fn single_node(
        snapshot: MetricsSnapshot,
        telemetry: TelemetrySnapshot,
    ) -> ObsReport {
        ObsReport {
            stats: ClusterStats { aggregate: snapshot, ..Default::default() },
            telemetry,
        }
    }

    /// Decode a `MetricsResp` payload from any node kind and wire
    /// version: a router's [`ClusterStats`] or a worker's
    /// [`MetricsSnapshot`], with the v3 telemetry block appended when
    /// the responder saw a v3 request. Strict about trailing bytes in
    /// every combination.
    pub fn parse_wire(
        version: u16,
        payload: &[u8],
    ) -> Result<ObsReport, FrameError> {
        let telemetry_tail =
            |rest: &[u8]| -> Result<TelemetrySnapshot, FrameError> {
                if rest.is_empty() {
                    Ok(TelemetrySnapshot::default())
                } else if version >= 3 {
                    parse_telemetry(rest)
                } else {
                    Err(FrameError::Malformed(
                        "metrics payload has trailing bytes",
                    ))
                }
            };
        if let Ok((stats, rest)) = ClusterStats::parse_prefix(payload) {
            if let Ok(telemetry) = telemetry_tail(rest) {
                return Ok(ObsReport { stats, telemetry });
            }
        }
        let (snap, rest) = MetricsSnapshot::parse_prefix(payload)?;
        let telemetry = telemetry_tail(rest)?;
        Ok(ObsReport::single_node(snap, telemetry))
    }

    /// Encode as a `MetricsResp` payload for a requester speaking
    /// `version` (the telemetry block only rides on v3+ — older
    /// clients parse the stats strictly and would reject it).
    pub fn encode_wire(&self, version: u16, router: bool) -> Vec<u8> {
        let mut out = if router {
            self.stats.encode()
        } else {
            self.stats.aggregate.encode()
        };
        if version >= 3 {
            out.extend_from_slice(&encode_telemetry(&self.telemetry));
        }
        out
    }

    /// Prometheus text exposition
    /// (<https://prometheus.io/docs/instrumenting/exposition_formats/>):
    /// one stable name per counter, classes/quantiles/stages as
    /// labels. Names are documented in `rust/docs/observability.md`.
    pub fn prometheus(&self) -> String {
        let a = &self.stats.aggregate;
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: u64| {
            out.push_str(&format!(
                "# HELP zebra_{name} {help}\n# TYPE zebra_{name} counter\n\
                 zebra_{name} {v}\n"
            ));
        };
        counter("requests_total", "Requests submitted", a.requests);
        counter("responses_total", "Requests answered", a.responses);
        counter("batches_total", "Batches executed", a.batches);
        counter(
            "batched_items_total",
            "Real items across executed batches",
            a.batched_items,
        );
        counter("padded_slots_total", "Padding slots executed", a.padded_slots);
        counter("dense_bytes_total", "Eq. 2 dense activation bytes", a.dense_bytes);
        counter("stored_bytes_total", "Eq. 2 stored activation bytes", a.stored_bytes);
        counter("index_bytes_total", "Eq. 3 block-index bytes", a.index_bytes);
        counter(
            "shipped_spill_bytes_total",
            "Shipped .zspill frame bytes",
            a.shipped_spill_bytes,
        );
        counter("deadline_miss_total", "Requests served past deadline", a.deadline_miss);
        counter("failed_total", "Admitted requests that failed", a.failed);
        out.push_str(&format!(
            "# HELP zebra_shed_total Requests shed by admission control\n\
             # TYPE zebra_shed_total counter\n\
             zebra_shed_total{{class=\"low\"}} {}\n\
             zebra_shed_total{{class=\"normal\"}} {}\n\
             zebra_shed_total{{class=\"high\"}} {}\n",
            a.shed_low, a.shed_normal, a.shed_high
        ));
        out.push_str(&format!(
            "# HELP zebra_queue_depth Admission queue occupancy\n\
             # TYPE zebra_queue_depth gauge\nzebra_queue_depth {}\n",
            a.queue_depth
        ));
        out.push_str(&format!(
            "# HELP zebra_exec_threads Compute threads across nodes\n\
             # TYPE zebra_exec_threads gauge\nzebra_exec_threads {}\n",
            a.exec_threads
        ));
        out.push_str(&format!(
            "# HELP zebra_bw_reduction_pct Eq. 2-3 bandwidth reduction\n\
             # TYPE zebra_bw_reduction_pct gauge\n\
             zebra_bw_reduction_pct {:.3}\n",
            a.reduction_pct()
        ));
        out.push_str(
            "# HELP zebra_latency_us Serving latency percentile \
             (bucket upper bound)\n# TYPE zebra_latency_us gauge\n",
        );
        for (q, p) in [("0.5", 0.5), ("0.95", 0.95), ("0.99", 0.99)] {
            out.push_str(&format!(
                "zebra_latency_us{{quantile=\"{q}\"}} {}\n",
                a.latency_percentile_us(p)
            ));
        }
        let s = &self.stats;
        if s.workers_total > 0 {
            let mut g = |name: &str, help: &str, v: u64| {
                out.push_str(&format!(
                    "# HELP zebra_router_{name} {help}\n\
                     # TYPE zebra_router_{name} counter\n\
                     zebra_router_{name} {v}\n"
                ));
            };
            g("workers_total", "Configured workers", s.workers_total);
            g("workers_alive", "Workers answering heartbeats", s.workers_alive);
            g("routed_total", "Submits dispatched", s.routed);
            g("retries_total", "Failover re-dispatches", s.retries);
            g("rejected_total", "Terminal refusals", s.rejected);
            g("failed_total", "Router-side faults", s.failed);
            out.push_str(
                "# HELP zebra_router_latency_us Router dispatch latency \
                 percentile\n# TYPE zebra_router_latency_us gauge\n",
            );
            for (q, p) in [("0.5", 0.5), ("0.95", 0.95), ("0.99", 0.99)] {
                out.push_str(&format!(
                    "zebra_router_latency_us{{quantile=\"{q}\"}} {}\n",
                    s.router_percentile_us(p)
                ));
            }
        }
        let generic: Vec<(&String, &StageStats)> = self
            .telemetry
            .stages
            .iter()
            .filter(|(label, _)| !is_plane_stage(label))
            .collect();
        if !generic.is_empty() {
            out.push_str(
                "# HELP zebra_stage_nanos_total Wall time per stage\n\
                 # TYPE zebra_stage_nanos_total counter\n",
            );
            for (label, st) in &generic {
                out.push_str(&format!(
                    "zebra_stage_nanos_total{{stage=\"{label}\"}} {}\n",
                    st.nanos
                ));
            }
            out.push_str(
                "# HELP zebra_stage_calls_total Invocations per stage\n\
                 # TYPE zebra_stage_calls_total counter\n",
            );
            for (label, st) in &generic {
                out.push_str(&format!(
                    "zebra_stage_calls_total{{stage=\"{label}\"}} {}\n",
                    st.calls
                ));
            }
            out.push_str(
                "# HELP zebra_stage_bytes_total Bytes per stage\n\
                 # TYPE zebra_stage_bytes_total counter\n",
            );
            for (label, st) in &generic {
                out.push_str(&format!(
                    "zebra_stage_bytes_total{{stage=\"{label}\"}} {}\n",
                    st.bytes
                ));
            }
        }
        // Bandwidth-ledger plane: one family per counter, (layer,
        // codec) as labels, reassembled from the `ledger.*` stages.
        let ledger = LedgerSnapshot::from_telemetry(&self.telemetry);
        if !ledger.cells.is_empty() {
            let mut section =
                |name: &str, help: &str, ty: &str, f: &dyn Fn(&CellStats) -> String| {
                    out.push_str(&format!(
                        "# HELP zebra_ledger_{name} {help}\n\
                         # TYPE zebra_ledger_{name} {ty}\n"
                    ));
                    for ((layer, codec), c) in &ledger.cells {
                        out.push_str(&format!(
                            "zebra_ledger_{name}{{layer=\"{layer}\",\
                             codec=\"{codec}\"}} {}\n",
                            f(c)
                        ));
                    }
                };
            section(
                "dense_bytes_total",
                "Dense activation bytes swept",
                "counter",
                &|c| c.dense_bytes.to_string(),
            );
            section(
                "encoded_bytes_total",
                "Encoded payload+index bytes",
                "counter",
                &|c| c.encoded_bytes.to_string(),
            );
            section(
                "blocks_total",
                "Activation blocks swept",
                "counter",
                &|c| c.blocks.to_string(),
            );
            section(
                "zero_blocks_total",
                "All-zero blocks swept",
                "counter",
                &|c| c.zero_blocks.to_string(),
            );
            section("sweeps_total", "Recorded sweeps", "counter", &|c| {
                c.sweeps.to_string()
            });
            section(
                "zero_permille",
                "All-zero blocks per 1000 swept",
                "gauge",
                &|c| c.zero_permille().to_string(),
            );
            section(
                "savings_pct",
                "Achieved bandwidth savings (dense vs encoded)",
                "gauge",
                &|c| format!("{:.3}", c.achieved_savings_pct()),
            );
        }
        // SLO plane: breach transitions + breaching-now, per objective.
        let slo = parse_slo(&self.telemetry);
        if !slo.is_empty() {
            out.push_str(
                "# HELP zebra_slo_breach_total SLO breach transitions\n\
                 # TYPE zebra_slo_breach_total counter\n",
            );
            for (name, v) in &slo {
                out.push_str(&format!(
                    "zebra_slo_breach_total{{objective=\"{name}\"}} {}\n",
                    v.breaches
                ));
            }
            out.push_str(
                "# HELP zebra_slo_active Objective breaching right now\n\
                 # TYPE zebra_slo_active gauge\n",
            );
            for (name, v) in &slo {
                out.push_str(&format!(
                    "zebra_slo_active{{objective=\"{name}\"}} {}\n",
                    v.active as u64
                ));
            }
        }
        // Brownout plane: the level the SLO engine is shedding at.
        if let Some((level, raises)) = parse_brownout(&self.telemetry) {
            out.push_str(&format!(
                "# HELP zebra_brownout_level Current SLO brownout level\n\
                 # TYPE zebra_brownout_level gauge\n\
                 zebra_brownout_level {level}\n\
                 # HELP zebra_brownout_raises_total Brownout level raises\n\
                 # TYPE zebra_brownout_raises_total counter\n\
                 zebra_brownout_raises_total {raises}\n"
            ));
        }
        // Circuit-breaker plane: per-worker link state at the router.
        let breakers = parse_breakers(&self.telemetry);
        if !breakers.is_empty() {
            out.push_str(
                "# HELP zebra_breaker_state Link breaker state \
                 (0=closed 1=open 2=half-open)\n\
                 # TYPE zebra_breaker_state gauge\n",
            );
            for (idx, b) in &breakers {
                out.push_str(&format!(
                    "zebra_breaker_state{{worker=\"{idx}\"}} {}\n",
                    b.state
                ));
            }
            out.push_str(
                "# HELP zebra_breaker_transitions_total Breaker state \
                 transitions\n\
                 # TYPE zebra_breaker_transitions_total counter\n",
            );
            for (idx, b) in &breakers {
                out.push_str(&format!(
                    "zebra_breaker_transitions_total{{worker=\"{idx}\"}} {}\n",
                    b.transitions
                ));
            }
        }
        // Per-worker plane from a gathered (router) report.
        let workers = parse_workers(&self.telemetry);
        if !workers.is_empty() {
            let mut section =
                |name: &str, help: &str, ty: &str, f: &dyn Fn(&WorkerView) -> u64| {
                    out.push_str(&format!(
                        "# HELP zebra_worker_{name} {help}\n\
                         # TYPE zebra_worker_{name} {ty}\n"
                    ));
                    for (idx, w) in &workers {
                        out.push_str(&format!(
                            "zebra_worker_{name}{{worker=\"{idx}\"}} {}\n",
                            f(w)
                        ));
                    }
                };
            section("alive", "Worker answering heartbeats", "gauge", &|w| {
                w.alive as u64
            });
            section(
                "in_flight",
                "Router-side in-flight requests",
                "gauge",
                &|w| w.in_flight,
            );
            section(
                "queue_depth",
                "Worker admission-queue depth",
                "gauge",
                &|w| w.queue_depth,
            );
            section("responses_total", "Requests answered", "counter", &|w| {
                w.responses
            });
            section(
                "shed_total",
                "Requests shed by the worker",
                "counter",
                &|w| w.shed,
            );
        }
        out
    }

    /// The same registry as a JSON document (`zebra obs --json`,
    /// loadgen's scrape samples, `BENCH_PR8.json`'s cluster section).
    pub fn to_json(&self) -> Value {
        let a = &self.stats.aggregate;
        let mut counters = BTreeMap::new();
        for (k, v) in [
            ("requests", a.requests),
            ("responses", a.responses),
            ("batches", a.batches),
            ("batched_items", a.batched_items),
            ("padded_slots", a.padded_slots),
            ("dense_bytes", a.dense_bytes),
            ("stored_bytes", a.stored_bytes),
            ("index_bytes", a.index_bytes),
            ("shipped_spill_bytes", a.shipped_spill_bytes),
            ("exec_threads", a.exec_threads),
            ("shed_low", a.shed_low),
            ("shed_normal", a.shed_normal),
            ("shed_high", a.shed_high),
            ("deadline_miss", a.deadline_miss),
            ("queue_depth", a.queue_depth),
            ("failed", a.failed),
        ] {
            counters.insert(k.to_string(), Value::Num(v as f64));
        }
        let mut latency = BTreeMap::new();
        for (k, p) in [("p50", 0.5), ("p95", 0.95), ("p99", 0.99)] {
            latency.insert(
                format!("{k}_us"),
                Value::Num(a.latency_percentile_us(p) as f64),
            );
        }
        let s = &self.stats;
        let mut router = BTreeMap::new();
        for (k, v) in [
            ("workers_total", s.workers_total),
            ("workers_alive", s.workers_alive),
            ("routed", s.routed),
            ("retries", s.retries),
            ("rejected", s.rejected),
            ("shed_low", s.shed_low),
            ("shed_normal", s.shed_normal),
            ("shed_high", s.shed_high),
            ("failed", s.failed),
            ("spill_frames_in", s.spill_frames_in),
            ("spill_bytes_in", s.spill_bytes_in),
        ] {
            router.insert(k.to_string(), Value::Num(v as f64));
        }
        let mut stages = BTreeMap::new();
        for (label, st) in &self.telemetry.stages {
            if is_plane_stage(label) {
                continue;
            }
            let mut m = BTreeMap::new();
            m.insert("nanos".to_string(), Value::Num(st.nanos as f64));
            m.insert("calls".to_string(), Value::Num(st.calls as f64));
            m.insert("bytes".to_string(), Value::Num(st.bytes as f64));
            stages.insert(label.clone(), Value::Object(m));
        }
        let round3 = |v: f64| (v * 1000.0).round() / 1000.0;
        let mut ledger_o = BTreeMap::new();
        for ((layer, codec), c) in
            &LedgerSnapshot::from_telemetry(&self.telemetry).cells
        {
            let mut m = BTreeMap::new();
            for (k, v) in [
                ("sweeps", c.sweeps),
                ("dense_bytes", c.dense_bytes),
                ("encoded_bytes", c.encoded_bytes),
                ("blocks", c.blocks),
                ("zero_blocks", c.zero_blocks),
                ("zero_permille", c.zero_permille()),
            ] {
                m.insert(k.to_string(), Value::Num(v as f64));
            }
            m.insert(
                "savings_pct".to_string(),
                Value::Num(round3(c.achieved_savings_pct())),
            );
            m.insert(
                "analytic_savings_pct".to_string(),
                Value::Num(round3(c.analytic_savings_pct())),
            );
            ledger_o.insert(format!("{layer}/{codec}"), Value::Object(m));
        }
        let mut slo_o = BTreeMap::new();
        for (name, v) in parse_slo(&self.telemetry) {
            let mut m = BTreeMap::new();
            m.insert("breaches".to_string(), Value::Num(v.breaches as f64));
            m.insert("active".to_string(), Value::Bool(v.active));
            m.insert(
                "threshold_milli".to_string(),
                Value::Num(v.threshold_milli as f64),
            );
            slo_o.insert(name, Value::Object(m));
        }
        let mut workers_o = BTreeMap::new();
        for (idx, w) in parse_workers(&self.telemetry) {
            let mut m = BTreeMap::new();
            m.insert("alive".to_string(), Value::Bool(w.alive));
            for (k, v) in [
                ("in_flight", w.in_flight),
                ("queue_depth", w.queue_depth),
                ("responses", w.responses),
                ("shed", w.shed),
            ] {
                m.insert(k.to_string(), Value::Num(v as f64));
            }
            workers_o.insert(idx.to_string(), Value::Object(m));
        }
        let mut breakers_o = BTreeMap::new();
        for (idx, b) in parse_breakers(&self.telemetry) {
            let mut m = BTreeMap::new();
            m.insert(
                "state".to_string(),
                Value::Str(b.state_name().to_string()),
            );
            m.insert(
                "transitions".to_string(),
                Value::Num(b.transitions as f64),
            );
            breakers_o.insert(idx.to_string(), Value::Object(m));
        }
        let mut o = BTreeMap::new();
        o.insert("counters".to_string(), Value::Object(counters));
        o.insert("latency".to_string(), Value::Object(latency));
        o.insert("router".to_string(), Value::Object(router));
        o.insert(
            "bw_reduction_pct".to_string(),
            Value::Num((a.reduction_pct() * 1000.0).round() / 1000.0),
        );
        o.insert("telemetry".to_string(), Value::Object(stages));
        o.insert("ledger".to_string(), Value::Object(ledger_o));
        o.insert("slo".to_string(), Value::Object(slo_o));
        o.insert("workers".to_string(), Value::Object(workers_o));
        o.insert("breakers".to_string(), Value::Object(breakers_o));
        if let Some((level, raises)) = parse_brownout(&self.telemetry) {
            let mut m = BTreeMap::new();
            m.insert("level".to_string(), Value::Num(level as f64));
            m.insert("raises".to_string(), Value::Num(raises as f64));
            o.insert("brownout".to_string(), Value::Object(m));
        }
        Value::Object(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_telemetry() -> TelemetrySnapshot {
        let mut t = TelemetrySnapshot::default();
        t.stages.insert(
            "serve.execute".into(),
            StageStats { nanos: 5_000_000, calls: 12, bytes: 0 },
        );
        t.stages.insert(
            "wire.handle".into(),
            StageStats { nanos: 800_000, calls: 40, bytes: 4096 },
        );
        t
    }

    fn sample_snapshot() -> MetricsSnapshot {
        MetricsSnapshot {
            requests: 100,
            responses: 97,
            dense_bytes: 1000,
            stored_bytes: 400,
            index_bytes: 100,
            shed_low: 3,
            latency_buckets: vec![0, 0, 0, 0, 0, 0, 0, 97],
            ..Default::default()
        }
    }

    #[test]
    fn telemetry_block_roundtrips_and_rejects_corruption() {
        let t = sample_telemetry();
        let bytes = encode_telemetry(&t);
        assert_eq!(parse_telemetry(&bytes).unwrap(), t);
        // Empty snapshot roundtrips.
        let e = TelemetrySnapshot::default();
        assert_eq!(parse_telemetry(&encode_telemetry(&e)).unwrap(), e);
        // Every truncation errors.
        for cut in 0..bytes.len() {
            assert!(parse_telemetry(&bytes[..cut]).is_err(), "cut {cut}");
        }
        // Trailing garbage errors strictly, parses via prefix.
        let mut noisy = bytes.clone();
        noisy.extend_from_slice(b"xx");
        assert!(parse_telemetry(&noisy).is_err());
        let (back, rest) = parse_telemetry_prefix(&noisy).unwrap();
        assert_eq!(back, t);
        assert_eq!(rest, b"xx");
        // Absurd stage count errors before allocating.
        let mut bad = bytes.clone();
        bad[0..2].copy_from_slice(&u16::MAX.to_le_bytes());
        assert!(parse_telemetry(&bad).is_err());
    }

    #[test]
    fn wire_payload_dispatches_on_node_kind_and_version() {
        let tel = sample_telemetry();
        // Worker shape, v3: snapshot + telemetry.
        let single =
            ObsReport::single_node(sample_snapshot(), tel.clone());
        let bytes = single.encode_wire(3, false);
        let back = ObsReport::parse_wire(3, &bytes).unwrap();
        assert_eq!(back.stats.aggregate, single.stats.aggregate);
        assert_eq!(back.telemetry, tel);
        assert_eq!(back.stats.workers_total, 0);
        // Worker shape, v2: no telemetry block; old parse stays exact.
        let v2 = single.encode_wire(2, false);
        assert_eq!(
            MetricsSnapshot::parse(&v2).unwrap(),
            single.stats.aggregate
        );
        assert!(ObsReport::parse_wire(2, &v2).unwrap().telemetry.stages.is_empty());
        // Router shape, v3.
        let router = ObsReport {
            stats: ClusterStats {
                aggregate: sample_snapshot(),
                workers_total: 2,
                workers_alive: 2,
                routed: 50,
                ..Default::default()
            },
            telemetry: tel.clone(),
        };
        let bytes = router.encode_wire(3, true);
        let back = ObsReport::parse_wire(3, &bytes).unwrap();
        assert_eq!(back.stats, router.stats);
        assert_eq!(back.telemetry, tel);
        // Router shape, v2 is byte-identical to the legacy encoding.
        assert_eq!(router.encode_wire(2, true), router.stats.encode());
        // Trailing garbage after the telemetry block errors.
        let mut noisy = router.encode_wire(3, true);
        noisy.push(7);
        assert!(ObsReport::parse_wire(3, &noisy).is_err());
        // A v2 reader handed trailing bytes errors (never mis-parses).
        let mut v2noisy = router.stats.encode();
        v2noisy.push(7);
        assert!(ObsReport::parse_wire(2, &v2noisy).is_err());
    }

    #[test]
    fn prometheus_exposition_carries_every_plane() {
        let report = ObsReport {
            stats: ClusterStats {
                aggregate: sample_snapshot(),
                workers_total: 3,
                workers_alive: 2,
                routed: 44,
                ..Default::default()
            },
            telemetry: sample_telemetry(),
        };
        let text = report.prometheus();
        assert!(text.contains("zebra_requests_total 100"), "{text}");
        assert!(
            text.contains("zebra_shed_total{class=\"low\"} 3"),
            "{text}"
        );
        assert!(
            text.contains("zebra_latency_us{quantile=\"0.99\"}"),
            "{text}"
        );
        assert!(text.contains("zebra_router_workers_alive 2"), "{text}");
        assert!(
            text.contains(
                "zebra_stage_nanos_total{stage=\"serve.execute\"} 5000000"
            ),
            "{text}"
        );
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
            assert!(parts.next().unwrap().starts_with("zebra_"), "{line}");
        }
        // Single-node reports omit the router section.
        let single = ObsReport::single_node(
            sample_snapshot(),
            TelemetrySnapshot::default(),
        );
        assert!(!single.prometheus().contains("zebra_router_"), "single");
    }

    /// A telemetry snapshot carrying every synthetic plane: ledger
    /// cells, SLO status, and router-injected per-worker gauges.
    fn plane_telemetry() -> TelemetrySnapshot {
        let ledger = crate::obs::ledger::Ledger::new();
        ledger.cell("l0", "zero-block").record(1000, 400, 64, 32);
        let mut t = sample_telemetry();
        ledger.snapshot().to_stages(&mut t);
        t.stages.insert(
            "slo.shed-rate.breach".into(),
            StageStats { nanos: 500, calls: 2, bytes: 0 },
        );
        t.stages.insert(
            "slo.shed-rate.active".into(),
            StageStats { nanos: 0, calls: 1, bytes: 0 },
        );
        t.stages.insert(
            "cluster.w0.link".into(),
            StageStats { nanos: 7, calls: 1, bytes: 0 },
        );
        t.stages.insert(
            "cluster.w0.node".into(),
            StageStats { nanos: 3, calls: 90, bytes: 5 },
        );
        t.stages.insert(
            "breaker.w0".into(),
            StageStats { nanos: 2, calls: 4, bytes: 0 },
        );
        t.stages.insert(
            super::super::slo::BROWNOUT_STAGE.into(),
            StageStats { nanos: 1, calls: 3, bytes: 0 },
        );
        t
    }

    #[test]
    fn plane_stages_render_as_their_own_families() {
        let report = ObsReport::single_node(sample_snapshot(), plane_telemetry());
        let text = report.prometheus();
        assert!(
            text.contains(
                "zebra_ledger_dense_bytes_total{layer=\"l0\",\
                 codec=\"zero-block\"} 1000"
            ),
            "{text}"
        );
        assert!(
            text.contains(
                "zebra_ledger_zero_permille{layer=\"l0\",\
                 codec=\"zero-block\"} 500"
            ),
            "{text}"
        );
        assert!(
            text.contains("zebra_slo_breach_total{objective=\"shed-rate\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("zebra_slo_active{objective=\"shed-rate\"} 1"),
            "{text}"
        );
        assert!(text.contains("zebra_worker_alive{worker=\"0\"} 1"), "{text}");
        assert!(
            text.contains("zebra_worker_responses_total{worker=\"0\"} 90"),
            "{text}"
        );
        assert!(
            text.contains("zebra_breaker_state{worker=\"0\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("zebra_breaker_transitions_total{worker=\"0\"} 4"),
            "{text}"
        );
        assert!(text.contains("zebra_brownout_level 1"), "{text}");
        assert!(text.contains("zebra_brownout_raises_total 3"), "{text}");
        // Plane stages never leak into the generic stage families;
        // real stages stay there.
        assert!(!text.contains("stage=\"ledger."), "{text}");
        assert!(!text.contains("stage=\"slo."), "{text}");
        assert!(!text.contains("stage=\"cluster.w"), "{text}");
        assert!(!text.contains("stage=\"breaker."), "{text}");
        assert!(text.contains("stage=\"serve.execute\""), "{text}");
        // Exposition discipline holds for the new families too.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
            assert!(parts.next().unwrap().starts_with("zebra_"), "{line}");
        }
        // JSON carries the same planes, stripped of their prefixes.
        let v = report.to_json();
        let back =
            crate::util::json::parse(&crate::util::json::to_string(&v))
                .unwrap();
        let cell = back.get("ledger").get("l0/zero-block");
        assert_eq!(cell.get("encoded_bytes").as_usize(), Some(400));
        assert_eq!(cell.get("zero_permille").as_usize(), Some(500));
        assert!(cell.get("savings_pct").as_f64().unwrap() > 59.0);
        let slo = back.get("slo").get("shed-rate");
        assert_eq!(slo.get("breaches").as_usize(), Some(2));
        assert_eq!(slo.get("active").as_bool(), Some(true));
        let w = back.get("workers").get("0");
        assert_eq!(w.get("in_flight").as_usize(), Some(7));
        assert_eq!(w.get("shed").as_usize(), Some(5));
        let b = back.get("breakers").get("0");
        assert_eq!(b.get("state").as_str(), Some("half-open"));
        assert_eq!(b.get("transitions").as_usize(), Some(4));
        assert_eq!(back.get("brownout").get("level").as_usize(), Some(1));
        assert_eq!(back.get("brownout").get("raises").as_usize(), Some(3));
        assert!(back.get("telemetry").get("slo.shed-rate.breach").is_null());
        assert!(back
            .get("telemetry")
            .get("serve.execute")
            .get("calls")
            .as_usize()
            .is_some());
    }

    #[test]
    fn breaker_parse_skips_malformed_labels() {
        let mut t = TelemetrySnapshot::default();
        for label in ["breaker.wx", "breaker.w", "breaker.w1.extra"] {
            t.stages.insert(
                label.into(),
                StageStats { nanos: 1, calls: 1, bytes: 1 },
            );
        }
        t.stages.insert(
            "breaker.w3".into(),
            StageStats { nanos: 1, calls: 9, bytes: 0 },
        );
        let b = parse_breakers(&t);
        assert_eq!(b.len(), 1);
        assert_eq!(
            b[&3],
            BreakerView { state: 1, transitions: 9 }
        );
        assert_eq!(b[&3].state_name(), "open");
        assert_eq!(BreakerView::default().state_name(), "closed");
    }

    #[test]
    fn json_counters_match_the_snapshot() {
        let report = ObsReport::single_node(
            sample_snapshot(),
            sample_telemetry(),
        );
        let v = report.to_json();
        let text = crate::util::json::to_string(&v);
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(
            back.get("counters").get("requests").as_usize(),
            Some(100)
        );
        assert_eq!(
            back.get("counters").get("shed_low").as_usize(),
            Some(3)
        );
        assert_eq!(
            back.get("telemetry")
                .get("serve.execute")
                .get("calls")
                .as_usize(),
            Some(12)
        );
        assert!(back.get("latency").get("p99_us").as_f64().is_some());
        assert!(
            (back.get("bw_reduction_pct").as_f64().unwrap() - 50.0).abs()
                < 1e-9
        );
    }
}
