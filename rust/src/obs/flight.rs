//! Flight recorder: a fixed-capacity ring of recent trace records and
//! terminal events, dumped as JSON-lines on anything terminal.
//!
//! The ring is lock-cheap by construction: recording is one short
//! `Mutex<VecDeque>` critical section (push + bounded pop), no
//! allocation beyond the entry itself, and nothing on the hot path
//! ever formats JSON — serialization happens only at dump time, which
//! only terminal events (shed, deadline miss, conn error, worker
//! death) trigger. Dumps are latest-wins per node
//! (`<dir>/flight-<node>.jsonl`), so a shed storm rewrites one bounded
//! file instead of filling a disk.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::coordinator::Priority;
use crate::util::json::{self, Value};

use super::trace::TraceRecord;

/// Default ring capacity (entries, traces + events combined).
pub const FLIGHT_CAPACITY: usize = 256;

/// Why a request (or a peer) terminally left the normal path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TerminalKind {
    /// Admission control shed, split by class — `shed_low` is the
    /// event the forced-shed smoke test greps for.
    ShedLow,
    ShedNormal,
    ShedHigh,
    /// Served after its explicit deadline had already passed.
    DeadlineMiss,
    /// A peer connection failed mid-request.
    ConnError,
    /// The router re-dispatched an in-flight request to another worker.
    Redispatch,
    /// A worker went silent / was killed.
    WorkerDeath,
    /// An SLO objective's burn rate crossed 1.0 on both windows
    /// (the detail names the objective; see `obs::slo`).
    SloBreach,
    /// A worker link's circuit breaker tripped Closed -> Open
    /// (consecutive-failure threshold; see `faults::breaker`).
    BreakerOpen,
    /// The breaker's Open interval elapsed; one probe dial admitted.
    BreakerHalfOpen,
    /// A Half-Open probe succeeded; the link is healthy again.
    BreakerClosed,
    /// An encoded spill failed its post-checksum decode; the dense
    /// fallback (or drop-and-count on ingest) handled it.
    SpillCorrupt,
    /// SLO-driven brownout raised to the level in the detail.
    BrownoutEnter,
    /// Burn recovered; brownout stepped back to the level in the
    /// detail (0 = fully exited).
    BrownoutExit,
}

impl TerminalKind {
    /// The shed event for a priority class.
    pub fn shed(p: Priority) -> TerminalKind {
        match p {
            Priority::Low => TerminalKind::ShedLow,
            Priority::Normal => TerminalKind::ShedNormal,
            Priority::High => TerminalKind::ShedHigh,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TerminalKind::ShedLow => "shed_low",
            TerminalKind::ShedNormal => "shed_normal",
            TerminalKind::ShedHigh => "shed_high",
            TerminalKind::DeadlineMiss => "deadline_miss",
            TerminalKind::ConnError => "conn_error",
            TerminalKind::Redispatch => "redispatch",
            TerminalKind::WorkerDeath => "worker_death",
            TerminalKind::SloBreach => "slo_breach",
            TerminalKind::BreakerOpen => "breaker_open",
            TerminalKind::BreakerHalfOpen => "breaker_half_open",
            TerminalKind::BreakerClosed => "breaker_closed",
            TerminalKind::SpillCorrupt => "spill_corrupt",
            TerminalKind::BrownoutEnter => "brownout_enter",
            TerminalKind::BrownoutExit => "brownout_exit",
        }
    }

    pub fn parse(s: &str) -> Option<TerminalKind> {
        Some(match s {
            "shed_low" => TerminalKind::ShedLow,
            "shed_normal" => TerminalKind::ShedNormal,
            "shed_high" => TerminalKind::ShedHigh,
            "deadline_miss" => TerminalKind::DeadlineMiss,
            "conn_error" => TerminalKind::ConnError,
            "redispatch" => TerminalKind::Redispatch,
            "worker_death" => TerminalKind::WorkerDeath,
            "slo_breach" => TerminalKind::SloBreach,
            "breaker_open" => TerminalKind::BreakerOpen,
            "breaker_half_open" => TerminalKind::BreakerHalfOpen,
            "breaker_closed" => TerminalKind::BreakerClosed,
            "spill_corrupt" => TerminalKind::SpillCorrupt,
            "brownout_enter" => TerminalKind::BrownoutEnter,
            "brownout_exit" => TerminalKind::BrownoutExit,
            _ => return None,
        })
    }
}

/// One ring entry: a completed sampled trace, or a terminal event.
#[derive(Debug, Clone, PartialEq)]
pub enum FlightEntry {
    Trace(TraceRecord),
    Event {
        /// [`super::now_ns`] at record time.
        at_ns: u64,
        /// 0 when the event is not attributable to one request
        /// (e.g. a worker death).
        trace_id: u64,
        kind: TerminalKind,
        detail: String,
    },
}

impl FlightEntry {
    fn to_json(&self) -> Value {
        match self {
            FlightEntry::Trace(rec) => rec.to_json(),
            FlightEntry::Event { at_ns, trace_id, kind, detail } => {
                let mut o = std::collections::BTreeMap::new();
                o.insert("type".into(), Value::Str("event".into()));
                o.insert("at_ns".into(), Value::Str(at_ns.to_string()));
                o.insert(
                    "trace_id".into(),
                    Value::Str(format!("{trace_id:#018x}")),
                );
                o.insert("kind".into(), Value::Str(kind.name().into()));
                o.insert("detail".into(), Value::Str(detail.clone()));
                Value::Object(o)
            }
        }
    }

    fn from_json(v: &Value) -> Option<FlightEntry> {
        match v.get("type").as_str()? {
            "trace" => TraceRecord::from_json(v).map(FlightEntry::Trace),
            "event" => Some(FlightEntry::Event {
                at_ns: v.get("at_ns").as_str()?.parse().ok()?,
                trace_id: u64::from_str_radix(
                    v.get("trace_id").as_str()?.strip_prefix("0x")?,
                    16,
                )
                .ok()?,
                kind: TerminalKind::parse(v.get("kind").as_str()?)?,
                detail: v.get("detail").as_str()?.to_string(),
            }),
            _ => None,
        }
    }
}

/// The ring itself. Shared as `Arc<FlightRecorder>` between the
/// serving hot loop (records) and the node front (dumps).
#[derive(Debug)]
pub struct FlightRecorder {
    /// Node label in the dump filename (`worker-0`, `router`, ...).
    node: String,
    cap: usize,
    dir: Option<PathBuf>,
    ring: Mutex<VecDeque<FlightEntry>>,
}

impl FlightRecorder {
    /// `dir = None` keeps the ring in memory only (events still
    /// recorded; nothing written).
    pub fn new(
        node: &str,
        cap: usize,
        dir: Option<PathBuf>,
    ) -> FlightRecorder {
        FlightRecorder {
            node: node.to_string(),
            cap: cap.max(1),
            dir,
            ring: Mutex::new(VecDeque::new()),
        }
    }

    fn push(&self, e: FlightEntry) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(e);
    }

    /// Record one completed sampled trace (no dump — traces are the
    /// normal path).
    pub fn record_trace(&self, rec: TraceRecord) {
        self.push(FlightEntry::Trace(rec));
    }

    /// Record a terminal event and, when a `--flight-dir` is
    /// configured, dump the ring for post-mortem. Dump failures are
    /// reported on stderr, never propagated into the serving path.
    pub fn record_event(
        &self,
        trace_id: u64,
        kind: TerminalKind,
        detail: &str,
    ) {
        self.push(FlightEntry::Event {
            at_ns: super::now_ns(),
            trace_id,
            kind,
            detail: detail.to_string(),
        });
        if let Some(Err(e)) = self.dump() {
            eprintln!("flight[{}]: dump failed: {e}", self.node);
        }
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entries oldest-first (a copy; the ring keeps running).
    pub fn entries(&self) -> Vec<FlightEntry> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// The ring as JSON-lines text (one `util::json` object per line).
    pub fn jsonl(&self) -> String {
        let mut out = String::new();
        for e in self.ring.lock().unwrap().iter() {
            out.push_str(&json::to_string(&e.to_json()));
            out.push('\n');
        }
        out
    }

    /// Write the ring to `<dir>/flight-<node>.jsonl` (latest wins).
    /// `None` when no directory is configured. The write is atomic —
    /// `<name>.jsonl.tmp` then rename — so a node killed mid-dump
    /// never leaves a torn file for `zebra obs replay` to reject.
    pub fn dump(&self) -> Option<std::io::Result<PathBuf>> {
        let dir = self.dir.as_ref()?;
        let path = dir.join(format!("flight-{}.jsonl", self.node));
        let tmp = dir.join(format!("flight-{}.jsonl.tmp", self.node));
        let res = std::fs::create_dir_all(dir)
            .and_then(|()| std::fs::write(&tmp, self.jsonl()))
            .and_then(|()| std::fs::rename(&tmp, &path))
            .map(|()| path);
        Some(res)
    }
}

/// Parse a JSON-lines flight dump back into entries — the `zebra obs
/// replay` path. Errors name the offending line; blank lines are
/// skipped.
pub fn parse_jsonl(text: &str) -> Result<Vec<FlightEntry>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line)
            .map_err(|e| format!("flight line {}: {e}", i + 1))?;
        let entry = FlightEntry::from_json(&v).ok_or_else(|| {
            format!("flight line {}: not a trace or event object", i + 1)
        })?;
        out.push(entry);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64) -> TraceRecord {
        let mut r = TraceRecord::new(id);
        r.push("serve.execute", 100, 900, 0, 2);
        r
    }

    #[test]
    fn ring_caps_at_capacity_oldest_first_out() {
        let f = FlightRecorder::new("t", 3, None);
        for i in 1..=5u64 {
            f.record_trace(rec(i));
        }
        let e = f.entries();
        assert_eq!(e.len(), 3);
        match &e[0] {
            FlightEntry::Trace(r) => assert_eq!(r.trace_id, 3),
            other => panic!("expected trace, got {other:?}"),
        }
    }

    #[test]
    fn ring_wraps_at_exactly_default_capacity() {
        // The boundary case: the ring filled to FLIGHT_CAPACITY
        // exactly, then one more entry. Length must hold at the cap
        // and the window must slide by one (oldest out, newest in).
        let f = FlightRecorder::new("t", FLIGHT_CAPACITY, None);
        for i in 1..=FLIGHT_CAPACITY as u64 {
            f.record_trace(rec(i));
        }
        assert_eq!(f.len(), FLIGHT_CAPACITY);
        match f.entries().first() {
            Some(FlightEntry::Trace(r)) => assert_eq!(r.trace_id, 1),
            other => panic!("expected trace, got {other:?}"),
        }
        f.record_trace(rec(FLIGHT_CAPACITY as u64 + 1));
        assert_eq!(f.len(), FLIGHT_CAPACITY);
        let e = f.entries();
        match &e[0] {
            FlightEntry::Trace(r) => assert_eq!(r.trace_id, 2),
            other => panic!("expected trace, got {other:?}"),
        }
        match e.last().unwrap() {
            FlightEntry::Trace(r) => {
                assert_eq!(r.trace_id, FLIGHT_CAPACITY as u64 + 1)
            }
            other => panic!("expected trace, got {other:?}"),
        }
    }

    #[test]
    fn jsonl_roundtrips_traces_and_events() {
        let f = FlightRecorder::new("t", 16, None);
        f.record_trace(rec(u64::MAX - 7));
        f.record_event(42, TerminalKind::ShedLow, "over cap");
        f.record_event(0, TerminalKind::WorkerDeath, "hb silence");
        let text = f.jsonl();
        // Every line parses as standalone JSON.
        for line in text.lines() {
            json::parse(line).unwrap();
        }
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(back, f.entries());
        match &back[1] {
            FlightEntry::Event { trace_id, kind, detail, .. } => {
                assert_eq!(*trace_id, 42);
                assert_eq!(*kind, TerminalKind::ShedLow);
                assert_eq!(detail, "over cap");
            }
            other => panic!("expected event, got {other:?}"),
        }
        // Garbage lines error with the line number.
        let err = parse_jsonl("{\"type\":\"trace\"").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = parse_jsonl("{\"type\":\"nope\"}").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn terminal_kinds_roundtrip_names() {
        for k in [
            TerminalKind::ShedLow,
            TerminalKind::ShedNormal,
            TerminalKind::ShedHigh,
            TerminalKind::DeadlineMiss,
            TerminalKind::ConnError,
            TerminalKind::Redispatch,
            TerminalKind::WorkerDeath,
            TerminalKind::SloBreach,
            TerminalKind::BreakerOpen,
            TerminalKind::BreakerHalfOpen,
            TerminalKind::BreakerClosed,
            TerminalKind::SpillCorrupt,
            TerminalKind::BrownoutEnter,
            TerminalKind::BrownoutExit,
        ] {
            assert_eq!(TerminalKind::parse(k.name()), Some(k));
        }
        assert_eq!(TerminalKind::parse("nope"), None);
        assert_eq!(
            TerminalKind::shed(Priority::Low),
            TerminalKind::ShedLow
        );
        assert_eq!(
            TerminalKind::shed(Priority::High),
            TerminalKind::ShedHigh
        );
    }

    #[test]
    fn dump_writes_latest_wins_file() {
        let dir = std::env::temp_dir()
            .join(format!("zebra-flight-test-{}", std::process::id()));
        let f = FlightRecorder::new(
            "unit",
            8,
            Some(dir.clone()),
        );
        f.record_event(9, TerminalKind::DeadlineMiss, "late");
        let path = f.dump().unwrap().unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        assert_eq!(parse_jsonl(&first).unwrap().len(), 1);
        // A second terminal event rewrites the same file.
        f.record_event(10, TerminalKind::ConnError, "reset");
        let second = std::fs::read_to_string(&path).unwrap();
        assert_eq!(parse_jsonl(&second).unwrap().len(), 2);
        assert_ne!(first, second);
        // Atomic write: no .tmp sibling survives a successful dump,
        // and a stale garbage .tmp (a simulated torn write) never
        // reaches readers — the next dump just replaces it.
        let tmp = dir.join("flight-unit.jsonl.tmp");
        assert!(!tmp.exists(), "tmp file must be renamed away");
        std::fs::write(&tmp, "{torn").unwrap();
        let path = f.dump().unwrap().unwrap();
        let third = std::fs::read_to_string(&path).unwrap();
        parse_jsonl(&third).expect("dump after torn tmp must be clean");
        assert!(!tmp.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn no_dir_means_no_dump() {
        let f = FlightRecorder::new("mem", 4, None);
        f.record_event(1, TerminalKind::ShedHigh, "x");
        assert!(f.dump().is_none());
        assert_eq!(f.len(), 1);
    }
}
