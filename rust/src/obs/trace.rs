//! Per-request distributed tracing: spans, the wire codec, and the
//! deterministic sampler.
//!
//! A [`TraceRecord`] is built cooperatively: the client assigns the
//! trace id and decides sampling, the worker appends its ingest /
//! queue-wait / execute / per-layer spans, the router appends its
//! dispatch span on the way back, and the client appends the
//! round-trip span on receipt. Span timestamps are nanoseconds since
//! the UNIX epoch ([`now_ns`]) so records assembled across processes
//! on one machine line up in a single waterfall; durations only ever
//! use same-process pairs, so clock skew between nodes can stretch the
//! rendering but never corrupts a span's length.

use std::time::{SystemTime, UNIX_EPOCH};

use crate::cluster::wire::FrameError;
use crate::telemetry::{StageStats, TelemetrySnapshot};
use crate::util::json::Value;
use crate::util::prng::Rng;

/// Hard cap on spans per record — a hop that loops forever appending
/// spans cannot balloon a response frame (parse rejects more).
pub const MAX_SPANS: usize = 1024;

/// Hard cap on a span label's byte length on the wire.
pub const MAX_LABEL: usize = 256;

/// Nanoseconds since the UNIX epoch, saturating into u64 (good until
/// the year 2554). The one wall-clock read the trace plane uses —
/// everything else is monotonic `Instant` pairs.
pub fn now_ns() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos().min(u64::MAX as u128) as u64)
        .unwrap_or(0)
}

/// Deterministic 1-in-`n` sampling from the trace id: `n = 0` samples
/// nothing, `n = 1` everything. Every node answers identically for the
/// same id (the id seeds the repo's xoshiro PRNG; no wall-clock
/// randomness anywhere), so a record is either assembled at every hop
/// or at none.
pub fn sampled(trace_id: u64, n: usize) -> bool {
    match n {
        0 => false,
        1 => true,
        n => Rng::new(trace_id).below(n as u64) == 0,
    }
}

/// Deterministic trace id for the `i`-th request of a run seeded with
/// `seed`. Never 0 (0 means "untraced" on the wire).
pub fn trace_id_for(seed: u64, i: u64) -> u64 {
    let id = Rng::new(seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .next_u64();
    if id == 0 {
        1
    } else {
        id
    }
}

/// One labeled interval inside a request's lifetime.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    /// `component.stage` label, same convention as telemetry
    /// (`router.dispatch`, `queue.wait`, `layer.2.prune_encode`, ...).
    pub label: String,
    /// Start / end in [`now_ns`] time.
    pub start_ns: u64,
    pub end_ns: u64,
    /// Bytes the span moved (0 when not meaningful).
    pub bytes: u64,
    /// Label-dependent auxiliary value: batch-mates for
    /// `serve.execute`, zero-block permille for `layer.*.prune_encode`
    /// spans, 0 otherwise.
    pub aux: u64,
}

impl Span {
    /// Span duration in nanoseconds (0 when the clock stepped).
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

/// Everywhere a sampled request went: the trace id plus every span the
/// hops appended, in append order.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TraceRecord {
    pub trace_id: u64,
    pub spans: Vec<Span>,
}

impl TraceRecord {
    pub fn new(trace_id: u64) -> TraceRecord {
        TraceRecord { trace_id, spans: Vec::new() }
    }

    /// Append a span (silently capped at [`MAX_SPANS`]; labels are
    /// truncated to [`MAX_LABEL`] bytes so the record always encodes).
    pub fn push(
        &mut self,
        label: &str,
        start_ns: u64,
        end_ns: u64,
        bytes: u64,
        aux: u64,
    ) {
        if self.spans.len() >= MAX_SPANS {
            return;
        }
        let mut label = label.to_string();
        if label.len() > MAX_LABEL {
            let mut cut = MAX_LABEL;
            while !label.is_char_boundary(cut) {
                cut -= 1;
            }
            label.truncate(cut);
        }
        self.spans.push(Span { label, start_ns, end_ns, bytes, aux });
    }

    /// First span with this exact label.
    pub fn span(&self, label: &str) -> Option<&Span> {
        self.spans.iter().find(|s| s.label == label)
    }

    /// Spans whose label starts with `prefix` (e.g. `layer.`).
    pub fn spans_with_prefix(&self, prefix: &str) -> Vec<&Span> {
        self.spans.iter().filter(|s| s.label.starts_with(prefix)).collect()
    }

    /// The record viewed as a telemetry snapshot (one stage per span
    /// label; repeated labels sum) — this is what lets trace tests
    /// reuse [`TelemetrySnapshot::coverage`]'s ≥95% contract verbatim.
    pub fn as_telemetry(&self) -> TelemetrySnapshot {
        let mut snap = TelemetrySnapshot::default();
        for s in &self.spans {
            let e = snap.stages.entry(s.label.clone()).or_insert(
                StageStats { nanos: 0, calls: 0, bytes: 0 },
            );
            e.nanos += s.duration_ns();
            e.calls += 1;
            e.bytes += s.bytes;
        }
        snap
    }

    /// Wire encoding: `[trace_id: u64][n_spans: u16]` then per span
    /// `[label_len: u16][label][start: u64][end: u64][bytes: u64]
    /// [aux: u64]`, all little-endian.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(10 + self.spans.len() * 40);
        out.extend_from_slice(&self.trace_id.to_le_bytes());
        out.extend_from_slice(
            &(self.spans.len().min(MAX_SPANS) as u16).to_le_bytes(),
        );
        for s in self.spans.iter().take(MAX_SPANS) {
            let label = s.label.as_bytes();
            out.extend_from_slice(&(label.len() as u16).to_le_bytes());
            out.extend_from_slice(label);
            out.extend_from_slice(&s.start_ns.to_le_bytes());
            out.extend_from_slice(&s.end_ns.to_le_bytes());
            out.extend_from_slice(&s.bytes.to_le_bytes());
            out.extend_from_slice(&s.aux.to_le_bytes());
        }
        out
    }

    /// Parse one record off the front of `payload`; returns the record
    /// and the remaining bytes. Declared counts and label lengths are
    /// validated against the available bytes before any slicing — the
    /// same never-panicking discipline as the rest of the wire.
    pub fn parse_prefix(
        payload: &[u8],
    ) -> Result<(TraceRecord, &[u8]), FrameError> {
        if payload.len() < 10 {
            return Err(FrameError::Malformed("trace record too short"));
        }
        let trace_id =
            u64::from_le_bytes(payload[..8].try_into().expect("8 bytes"));
        let n = u16::from_le_bytes([payload[8], payload[9]]) as usize;
        if n > MAX_SPANS {
            return Err(FrameError::Malformed(
                "trace record declares an absurd span count",
            ));
        }
        let mut rec = TraceRecord::new(trace_id);
        let mut off = 10usize;
        for _ in 0..n {
            if payload.len() < off + 2 {
                return Err(FrameError::Malformed(
                    "trace span shorter than its label length",
                ));
            }
            let label_len =
                u16::from_le_bytes([payload[off], payload[off + 1]]) as usize;
            if label_len > MAX_LABEL {
                return Err(FrameError::Malformed(
                    "trace span label over the length cap",
                ));
            }
            off += 2;
            let need = label_len + 32;
            if payload.len() < off + need {
                return Err(FrameError::Malformed(
                    "trace span shorter than its declared fields",
                ));
            }
            let label = std::str::from_utf8(&payload[off..off + label_len])
                .map_err(|_| {
                    FrameError::Malformed("trace span label not UTF-8")
                })?
                .to_string();
            off += label_len;
            let u64_at = |o: usize| {
                u64::from_le_bytes(
                    payload[o..o + 8].try_into().expect("8 bytes"),
                )
            };
            rec.spans.push(Span {
                label,
                start_ns: u64_at(off),
                end_ns: u64_at(off + 8),
                bytes: u64_at(off + 16),
                aux: u64_at(off + 24),
            });
            off += 32;
        }
        Ok((rec, &payload[off..]))
    }

    /// Strict parse: trailing bytes are an error.
    pub fn parse(payload: &[u8]) -> Result<TraceRecord, FrameError> {
        let (rec, rest) = Self::parse_prefix(payload)?;
        if !rest.is_empty() {
            return Err(FrameError::Malformed(
                "trace record has trailing bytes",
            ));
        }
        Ok(rec)
    }

    /// JSON shape for flight-recorder dumps. Large u64s (the trace id,
    /// the absolute epoch anchor) are strings — JSON numbers are f64
    /// and would silently round them; span offsets/bytes stay numeric.
    pub fn to_json(&self) -> Value {
        let t0 = self.spans.iter().map(|s| s.start_ns).min().unwrap_or(0);
        let mut o = std::collections::BTreeMap::new();
        o.insert("type".to_string(), Value::Str("trace".to_string()));
        o.insert(
            "trace_id".to_string(),
            Value::Str(format!("{:#018x}", self.trace_id)),
        );
        o.insert("t0_ns".to_string(), Value::Str(t0.to_string()));
        let spans = self
            .spans
            .iter()
            .map(|s| {
                let mut m = std::collections::BTreeMap::new();
                m.insert(
                    "label".to_string(),
                    Value::Str(s.label.clone()),
                );
                m.insert(
                    "start_ns".to_string(),
                    Value::Num(s.start_ns.saturating_sub(t0) as f64),
                );
                m.insert(
                    "end_ns".to_string(),
                    Value::Num(s.end_ns.saturating_sub(t0) as f64),
                );
                m.insert("bytes".to_string(), Value::Num(s.bytes as f64));
                m.insert("aux".to_string(), Value::Num(s.aux as f64));
                Value::Object(m)
            })
            .collect();
        o.insert("spans".to_string(), Value::Array(spans));
        Value::Object(o)
    }

    /// Rebuild from [`TraceRecord::to_json`] output (replay path).
    pub fn from_json(v: &Value) -> Option<TraceRecord> {
        if v.get("type").as_str() != Some("trace") {
            return None;
        }
        let id_str = v.get("trace_id").as_str()?;
        let trace_id =
            u64::from_str_radix(id_str.strip_prefix("0x")?, 16).ok()?;
        let t0: u64 = v.get("t0_ns").as_str()?.parse().ok()?;
        let mut rec = TraceRecord::new(trace_id);
        for s in v.get("spans").as_array()? {
            rec.spans.push(Span {
                label: s.get("label").as_str()?.to_string(),
                start_ns: t0
                    .saturating_add(s.get("start_ns").as_f64()? as u64),
                end_ns: t0.saturating_add(s.get("end_ns").as_f64()? as u64),
                bytes: s.get("bytes").as_f64()? as u64,
                aux: s.get("aux").as_f64()? as u64,
            });
        }
        Some(rec)
    }
}

/// Render one record as a per-request waterfall — what `zebra obs
/// replay` prints:
///
/// ```text
/// trace 0x00000000deadbeef (4 spans, 1.234 ms)
///   router.dispatch   |========================| 1200.0us
///   queue.wait          |==|                      130.0us
/// ```
pub fn render_waterfall(rec: &TraceRecord) -> String {
    const WIDTH: usize = 32;
    let t0 = rec.spans.iter().map(|s| s.start_ns).min().unwrap_or(0);
    let t1 = rec.spans.iter().map(|s| s.end_ns).max().unwrap_or(t0);
    let total = t1.saturating_sub(t0).max(1);
    let wide = rec
        .spans
        .iter()
        .map(|s| s.label.len())
        .max()
        .unwrap_or(5)
        .max(5);
    let mut out = format!(
        "trace {:#018x} ({} spans, {:.3} ms)\n",
        rec.trace_id,
        rec.spans.len(),
        total as f64 / 1e6
    );
    for s in &rec.spans {
        let lo = (s.start_ns.saturating_sub(t0) as u128 * WIDTH as u128
            / total as u128) as usize;
        let hi = (s.end_ns.saturating_sub(t0) as u128 * WIDTH as u128
            / total as u128) as usize;
        let hi = hi.clamp(lo + 1, WIDTH);
        let bar: String = (0..WIDTH)
            .map(|i| if i >= lo && i < hi { '=' } else { ' ' })
            .collect();
        let aux = if s.aux > 0 {
            format!(" aux={}", s.aux)
        } else {
            String::new()
        };
        let bytes = if s.bytes > 0 {
            format!(" {}B", s.bytes)
        } else {
            String::new()
        };
        out.push_str(&format!(
            "  {:<wide$}  |{bar}| {:>10.1}us{bytes}{aux}\n",
            s.label,
            s.duration_ns() as f64 / 1e3,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_record() -> TraceRecord {
        let mut r = TraceRecord::new(0xDEAD_BEEF_0BAD_F00D);
        r.push("client.rtt", 1_000, 9_000, 2048, 0);
        r.push("router.dispatch", 1_500, 8_500, 0, 0);
        r.push("queue.wait", 2_000, 3_000, 0, 0);
        r.push("serve.execute", 3_000, 8_000, 0, 4);
        r.push("layer.0.prune_encode", 3_100, 4_000, 64, 500);
        r
    }

    #[test]
    fn record_roundtrips_on_the_wire() {
        let r = sample_record();
        assert_eq!(TraceRecord::parse(&r.encode()).unwrap(), r);
        // An empty record is legal.
        let e = TraceRecord::new(7);
        assert_eq!(TraceRecord::parse(&e.encode()).unwrap(), e);
        // parse_prefix hands back the remainder.
        let mut bytes = r.encode();
        bytes.extend_from_slice(b"rest");
        let (back, rest) = TraceRecord::parse_prefix(&bytes).unwrap();
        assert_eq!(back, r);
        assert_eq!(rest, b"rest");
        // ... which the strict parse rejects.
        assert!(TraceRecord::parse(&bytes).is_err());
    }

    #[test]
    fn truncations_and_corruption_error_never_panic() {
        let bytes = sample_record().encode();
        for cut in 0..bytes.len() {
            assert!(
                TraceRecord::parse(&bytes[..cut]).is_err(),
                "prefix of {cut} bytes must not parse"
            );
        }
        // Absurd span count.
        let mut bad = bytes.clone();
        bad[8..10].copy_from_slice(&u16::MAX.to_le_bytes());
        assert!(TraceRecord::parse(&bad).is_err());
        // Label length lying past the buffer.
        let mut bad = bytes.clone();
        bad[10..12].copy_from_slice(&500u16.to_le_bytes());
        assert!(TraceRecord::parse(&bad).is_err());
        // Non-UTF-8 label bytes.
        let mut bad = bytes.clone();
        bad[12] = 0xFF;
        bad[13] = 0xC0;
        assert!(TraceRecord::parse(&bad).is_err());
    }

    #[test]
    fn sampler_is_deterministic_and_roughly_one_in_n() {
        assert!(!sampled(123, 0), "0 disables sampling");
        for id in 0..64u64 {
            assert!(sampled(id, 1), "1 samples everything");
            // Same id, same answer — every node agrees.
            assert_eq!(sampled(id, 4), sampled(id, 4));
        }
        let hits = (0..4000u64)
            .filter(|&i| sampled(trace_id_for(9, i), 4))
            .count();
        // 1-in-4 over 4000 distinct ids: loose 2-sided bound.
        assert!((700..=1300).contains(&hits), "{hits} of 4000 sampled");
    }

    #[test]
    fn trace_ids_are_nonzero_and_seed_dependent() {
        let a: Vec<u64> = (0..32).map(|i| trace_id_for(1, i)).collect();
        let b: Vec<u64> = (0..32).map(|i| trace_id_for(2, i)).collect();
        assert!(a.iter().all(|&id| id != 0));
        assert_ne!(a, b);
        // Deterministic per (seed, i).
        assert_eq!(a, (0..32).map(|i| trace_id_for(1, i)).collect::<Vec<_>>());
    }

    #[test]
    fn telemetry_view_supports_the_coverage_contract() {
        let r = sample_record();
        let mut snap = r.as_telemetry();
        assert_eq!(snap.get("client.rtt").nanos, 8_000);
        assert_eq!(snap.get("queue.wait").calls, 1);
        // Pose the acceptance question exactly as telemetry does.
        snap.stages.insert(
            "wall".to_string(),
            StageStats { nanos: 8_200, calls: 1, bytes: 0 },
        );
        let c = snap.coverage("wall", &["client.rtt"]).unwrap();
        assert!(c >= 0.95, "coverage {c}");
    }

    #[test]
    fn waterfall_renders_every_span() {
        let r = sample_record();
        let w = render_waterfall(&r);
        for s in &r.spans {
            assert!(w.contains(&s.label), "{w}");
        }
        assert!(w.starts_with("trace 0x"), "{w}");
        assert!(w.contains("aux=4"), "{w}");
        // Degenerate: an empty record still renders a header line.
        assert!(render_waterfall(&TraceRecord::new(1)).starts_with("trace"));
    }

    #[test]
    fn json_roundtrip_preserves_full_u64_ids() {
        // An id above 2^53 would be silently rounded by a JSON number;
        // the string encoding must carry it exactly.
        let mut r = TraceRecord::new(u64::MAX - 1);
        r.push("client.rtt", now_ns(), now_ns() + 5_000, 10, 2);
        let v = r.to_json();
        let text = crate::util::json::to_string(&v);
        let back =
            TraceRecord::from_json(&crate::util::json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(back.trace_id, r.trace_id);
        assert_eq!(back.spans.len(), 1);
        assert_eq!(back.spans[0].duration_ns(), r.spans[0].duration_ns());
        assert_eq!(back.spans[0].bytes, 10);
        assert_eq!(back.spans[0].aux, 2);
    }

    #[test]
    fn span_caps_hold() {
        let mut r = TraceRecord::new(1);
        for i in 0..MAX_SPANS + 10 {
            r.push(&format!("s{i}"), 0, 1, 0, 0);
        }
        assert_eq!(r.spans.len(), MAX_SPANS);
        let mut r = TraceRecord::new(2);
        r.push(&"x".repeat(MAX_LABEL + 50), 0, 1, 0, 0);
        assert_eq!(r.spans[0].label.len(), MAX_LABEL);
        // Both still encode/parse cleanly.
        assert!(TraceRecord::parse(&r.encode()).is_ok());
    }
}
