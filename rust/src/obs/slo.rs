//! SLO burn-rate engine — declarative objectives over the serving
//! metrics and the bandwidth ledger.
//!
//! Four objectives ship by default (all always-on; `--slo
//! name=threshold,...` re-thresholds them):
//!
//! | name            | breach condition (over both windows)           |
//! |-----------------|------------------------------------------------|
//! | `shed-rate`     | shed / submitted requests ≥ threshold          |
//! | `deadline-miss` | deadline misses / responses ≥ threshold        |
//! | `p99-latency`   | p99 latency ≥ threshold µs                     |
//! | `savings-floor` | ledger bandwidth savings % ≤ threshold (0 = off)|
//!
//! Evaluation is the classic two-window burn rate: each
//! [`SloEngine::observe`] call appends a timestamped sample of
//! cumulative counters to a bounded ring, and every objective
//! computes its rate over a **fast** window (is it burning *now*?)
//! and a **slow** window (has it been burning long enough to
//! matter?). Only when both burns reach 1.0 does the objective
//! breach; a breach *transition* (inactive → active) bumps the
//! cumulative breach counter and records a
//! [`TerminalKind::SloBreach`] flight event naming the objective —
//! steady-state breach does not re-fire, so a storm costs one event,
//! not one per tick.
//!
//! The engine is wall-clock free: `now_ms` is an input, so tests
//! drive it deterministically. Samplers (one thread per serving
//! node) feed it from a monotonic clock. Status rides the telemetry
//! block as `slo.<name>.breach` / `slo.<name>.active` stages — same
//! no-wire-bump trick as the ledger.
//!
//! With a [`BrownoutConfig`] (`--brownout`), a breach can *act*:
//! after `raise_after` consecutive burning observations the engine
//! raises a brownout level (up to `max_level`), and after
//! `lower_after` consecutive clear observations it lowers one level.
//! The level is advisory — callers apply it (the batch manager
//! shrinks Low/Normal admission caps, the trace sampler thins) — and
//! every transition records a [`TerminalKind::BrownoutEnter`] /
//! [`TerminalKind::BrownoutExit`] flight event and rides telemetry
//! as the [`BROWNOUT_STAGE`] stage.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::obs::flight::{FlightRecorder, TerminalKind};
use crate::telemetry::{StageStats, TelemetrySnapshot};

/// Stage-label prefix SLO status uses inside a telemetry snapshot.
pub const SLO_STAGE_PREFIX: &str = "slo.";

/// Stage label the brownout level rides under (`nanos` = current
/// level, `calls` = cumulative level raises). The `.level` suffix is
/// deliberately not `breach`/`active`, so [`parse_slo`] skips it.
pub const BROWNOUT_STAGE: &str = "slo.brownout.level";

/// What an objective measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloKind {
    /// Shed fraction of submitted requests (threshold: fraction).
    ShedRate,
    /// Deadline-missed fraction of responses (threshold: fraction).
    DeadlineMiss,
    /// p99 latency ceiling (threshold: microseconds).
    P99Latency,
    /// Ledger savings floor (threshold: percent; 0 disables).
    SavingsFloor,
}

/// One declarative objective.
#[derive(Debug, Clone, PartialEq)]
pub struct Objective {
    /// Kebab-case, dot-free (dots are the stage-label separator).
    pub name: &'static str,
    pub kind: SloKind,
    pub threshold: f64,
}

/// The brownout policy: how sustained burn translates into load
/// shedding. All counts are in observation ticks (one per
/// [`SloEngine::observe`] call), so the policy inherits the
/// sampler's cadence and stays wall-clock free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BrownoutConfig {
    /// Deepest brownout level (each level sheds harder).
    pub max_level: u32,
    /// Consecutive burning observations before raising one level.
    pub raise_after: u32,
    /// Consecutive clear observations before lowering one level.
    pub lower_after: u32,
}

impl Default for BrownoutConfig {
    fn default() -> BrownoutConfig {
        BrownoutConfig { max_level: 3, raise_after: 3, lower_after: 5 }
    }
}

impl BrownoutConfig {
    /// Parse `--brownout max=L,raise=N,lower=M` (each key optional,
    /// overriding the defaults). Strict: unknown keys and zero
    /// counts error — a brownout that can never raise or lower is a
    /// misconfiguration, not a policy.
    pub fn parse(spec: &str) -> Result<BrownoutConfig> {
        let mut cfg = BrownoutConfig::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty())
        {
            let Some((key, value)) = part.split_once('=') else {
                bail!("--brownout wants key=value, got {part:?}");
            };
            let Ok(n) = value.trim().parse::<u32>() else {
                bail!("--brownout {key}: bad count {value:?}");
            };
            if n == 0 {
                bail!("--brownout {key}: count must be >= 1");
            }
            match key.trim() {
                "max" => cfg.max_level = n,
                "raise" => cfg.raise_after = n,
                "lower" => cfg.lower_after = n,
                other => bail!(
                    "--brownout: unknown key {other:?} (max|raise|lower)"
                ),
            }
        }
        Ok(cfg)
    }
}

/// The engine's configuration: objectives + the two burn windows.
#[derive(Debug, Clone, PartialEq)]
pub struct SloConfig {
    pub objectives: Vec<Objective>,
    /// "Is it burning now?" window.
    pub fast_window_ms: u64,
    /// "Has it been burning long enough to matter?" window.
    pub slow_window_ms: u64,
    /// Brownout policy; `None` means breaches only report.
    pub brownout: Option<BrownoutConfig>,
}

impl Default for SloConfig {
    fn default() -> SloConfig {
        SloConfig {
            objectives: vec![
                Objective {
                    name: "shed-rate",
                    kind: SloKind::ShedRate,
                    threshold: 0.5,
                },
                Objective {
                    name: "deadline-miss",
                    kind: SloKind::DeadlineMiss,
                    threshold: 0.5,
                },
                Objective {
                    name: "p99-latency",
                    kind: SloKind::P99Latency,
                    threshold: 1_000_000.0,
                },
                Objective {
                    name: "savings-floor",
                    kind: SloKind::SavingsFloor,
                    threshold: 0.0,
                },
            ],
            fast_window_ms: 60_000,
            slow_window_ms: 600_000,
            brownout: None,
        }
    }
}

impl SloConfig {
    /// Parse `--slo name=threshold[,name=threshold...]` as overrides
    /// on the default objective set. Unknown names error listing the
    /// valid ones; thresholds must be finite and non-negative.
    pub fn parse_overrides(spec: &str) -> Result<SloConfig> {
        let mut cfg = SloConfig::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty())
        {
            let Some((name, value)) = part.split_once('=') else {
                bail!("--slo wants name=threshold, got {part:?}");
            };
            let Ok(threshold) = value.trim().parse::<f64>() else {
                bail!("--slo {name}: bad threshold {value:?}");
            };
            if !threshold.is_finite() || threshold < 0.0 {
                bail!("--slo {name}: threshold must be >= 0");
            }
            let Some(obj) = cfg
                .objectives
                .iter_mut()
                .find(|o| o.name == name.trim())
            else {
                bail!(
                    "--slo: unknown objective {name:?} \
                     (shed-rate|deadline-miss|p99-latency|savings-floor)"
                );
            };
            obj.threshold = threshold;
        }
        Ok(cfg)
    }
}

/// One sample of cumulative counters, fed by a node's sampler.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SloInput {
    pub requests: u64,
    pub shed: u64,
    pub responses: u64,
    pub deadline_miss: u64,
    /// Current p99 latency in microseconds (a level, not a counter).
    pub p99_latency_us: u64,
    /// Ledger totals (cumulative), for the savings floor.
    pub dense_bytes: u64,
    pub encoded_bytes: u64,
}

#[derive(Debug, Default, Clone, Copy)]
struct ObjState {
    breaches: u64,
    active: bool,
}

#[derive(Debug, Default)]
struct State {
    samples: VecDeque<(u64, SloInput)>,
    status: BTreeMap<&'static str, ObjState>,
    brownout_level: u32,
    breach_streak: u32,
    clear_streak: u32,
    brownout_raises: u64,
}

/// Sample-ring hard cap (a 100 ms sampler fills the slow window with
/// 6000; anything past pruning is a runaway guard, not a budget).
const MAX_SAMPLES: usize = 8192;

/// The burn-rate evaluator. Thread-safe; one per serving node.
#[derive(Debug)]
pub struct SloEngine {
    cfg: SloConfig,
    flight: Option<Arc<FlightRecorder>>,
    state: Mutex<State>,
}

impl SloEngine {
    pub fn new(
        cfg: SloConfig,
        flight: Option<Arc<FlightRecorder>>,
    ) -> Arc<SloEngine> {
        Arc::new(SloEngine {
            cfg,
            flight,
            state: Mutex::new(State::default()),
        })
    }

    pub fn config(&self) -> &SloConfig {
        &self.cfg
    }

    /// Feed one sample and evaluate every objective. Returns the
    /// names of objectives that newly breached on this observation
    /// (transitions only); each also records an `slo_breach` flight
    /// event when the node has a recorder.
    pub fn observe(&self, now_ms: u64, input: &SloInput) -> Vec<&'static str> {
        let mut st = self.state.lock().unwrap();
        st.samples.push_back((now_ms, *input));
        // Prune: keep one sample at-or-before the slow boundary so
        // the window lookup always has an anchor.
        let cutoff = now_ms.saturating_sub(self.cfg.slow_window_ms);
        while st.samples.len() > 1
            && (st.samples[1].0 <= cutoff || st.samples.len() > MAX_SAMPLES)
        {
            st.samples.pop_front();
        }
        let fast = window_base(&st.samples, now_ms, self.cfg.fast_window_ms);
        let slow = window_base(&st.samples, now_ms, self.cfg.slow_window_ms);
        let mut fired = Vec::new();
        for obj in &self.cfg.objectives {
            let burning = burn(obj, &fast, input) >= 1.0
                && burn(obj, &slow, input) >= 1.0;
            let entry = st.status.entry(obj.name).or_default();
            if burning && !entry.active {
                entry.active = true;
                entry.breaches += 1;
                fired.push(obj.name);
                if let Some(f) = &self.flight {
                    f.record_event(
                        0,
                        TerminalKind::SloBreach,
                        &format!(
                            "objective {} breached (threshold {})",
                            obj.name, obj.threshold
                        ),
                    );
                }
            } else if !burning {
                entry.active = false;
            }
        }
        self.step_brownout(&mut st);
        fired
    }

    /// Advance the brownout level state machine after one
    /// observation. Any active objective counts as burning; streaks
    /// reset on every level change so sustained burn keeps deepening
    /// one `raise_after` interval at a time.
    fn step_brownout(&self, st: &mut State) {
        let Some(bo) = &self.cfg.brownout else { return };
        let burning = st.status.values().any(|s| s.active);
        if burning {
            st.clear_streak = 0;
            st.breach_streak += 1;
            if st.breach_streak >= bo.raise_after
                && st.brownout_level < bo.max_level
            {
                st.breach_streak = 0;
                st.brownout_level += 1;
                st.brownout_raises += 1;
                if let Some(f) = &self.flight {
                    f.record_event(
                        0,
                        TerminalKind::BrownoutEnter,
                        &format!(
                            "brownout level {}/{} (slo burning)",
                            st.brownout_level, bo.max_level
                        ),
                    );
                }
            }
        } else {
            st.breach_streak = 0;
            if st.brownout_level > 0 {
                st.clear_streak += 1;
                if st.clear_streak >= bo.lower_after {
                    st.clear_streak = 0;
                    st.brownout_level -= 1;
                    if let Some(f) = &self.flight {
                        f.record_event(
                            0,
                            TerminalKind::BrownoutExit,
                            &format!(
                                "brownout level {}/{} (burn recovered)",
                                st.brownout_level, bo.max_level
                            ),
                        );
                    }
                }
            }
        }
    }

    /// Current brownout level: 0 = normal service, each level above
    /// sheds harder (admission caps shrink, trace sampling thins).
    pub fn brownout_level(&self) -> u32 {
        self.state.lock().unwrap().brownout_level
    }

    /// Pack status into a telemetry snapshot:
    ///
    /// ```text
    /// slo.<name>.breach {calls: cumulative breaches, nanos: threshold*1000}
    /// slo.<name>.active {calls: 1 if active else 0}
    /// ```
    pub fn to_stages(&self, telemetry: &mut TelemetrySnapshot) {
        let st = self.state.lock().unwrap();
        for obj in &self.cfg.objectives {
            let s = st.status.get(obj.name).copied().unwrap_or_default();
            telemetry.stages.insert(
                format!("{SLO_STAGE_PREFIX}{}.breach", obj.name),
                StageStats {
                    nanos: (obj.threshold * 1000.0).round() as u64,
                    calls: s.breaches,
                    bytes: 0,
                },
            );
            telemetry.stages.insert(
                format!("{SLO_STAGE_PREFIX}{}.active", obj.name),
                StageStats {
                    nanos: 0,
                    calls: s.active as u64,
                    bytes: 0,
                },
            );
        }
        if self.cfg.brownout.is_some() {
            telemetry.stages.insert(
                BROWNOUT_STAGE.to_string(),
                StageStats {
                    nanos: st.brownout_level as u64,
                    calls: st.brownout_raises,
                    bytes: 0,
                },
            );
        }
    }
}

/// The baseline sample a window measures deltas against: the newest
/// sample at or before `now - window`, else the oldest we have.
fn window_base(
    samples: &VecDeque<(u64, SloInput)>,
    now_ms: u64,
    window_ms: u64,
) -> SloInput {
    let boundary = now_ms.saturating_sub(window_ms);
    let mut base = samples.front().map(|(_, s)| *s).unwrap_or_default();
    for (at, s) in samples {
        if *at <= boundary {
            base = *s;
        } else {
            break;
        }
    }
    base
}

/// Burn rate of one objective over one window: observed rate divided
/// by threshold. ≥ 1.0 = the error budget is burning at or beyond
/// the allowed rate.
fn burn(obj: &Objective, base: &SloInput, now: &SloInput) -> f64 {
    match obj.kind {
        SloKind::ShedRate => {
            let shed = now.shed.saturating_sub(base.shed) as f64;
            let req = now.requests.saturating_sub(base.requests).max(1) as f64;
            ratio(shed / req, obj.threshold)
        }
        SloKind::DeadlineMiss => {
            let miss =
                now.deadline_miss.saturating_sub(base.deadline_miss) as f64;
            let resp =
                now.responses.saturating_sub(base.responses).max(1) as f64;
            ratio(miss / resp, obj.threshold)
        }
        SloKind::P99Latency => {
            ratio(now.p99_latency_us as f64, obj.threshold)
        }
        SloKind::SavingsFloor => {
            if obj.threshold <= 0.0 {
                return 0.0;
            }
            let dense = now.dense_bytes.saturating_sub(base.dense_bytes);
            if dense == 0 {
                // No ledger traffic in the window: nothing to judge.
                return 0.0;
            }
            let enc = now.encoded_bytes.saturating_sub(base.encoded_bytes);
            let savings =
                100.0 * dense.saturating_sub(enc) as f64 / dense as f64;
            // A *floor*: burn ≥ 1 exactly when savings ≤ threshold.
            obj.threshold / savings.max(1e-9)
        }
    }
}

fn ratio(observed: f64, threshold: f64) -> f64 {
    if threshold <= 0.0 {
        // Zero-threshold rate objectives: any observation breaches.
        return if observed > 0.0 { f64::INFINITY } else { 0.0 };
    }
    observed / threshold
}

/// One objective's parsed wire status (see [`parse_slo`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SloView {
    /// Cumulative breach transitions (summed across merged nodes).
    pub breaches: u64,
    /// Breaching right now on ≥ 1 merged node.
    pub active: bool,
    /// Configured threshold × 1000 (from the reporting node).
    pub threshold_milli: u64,
}

/// Reassemble per-objective status from the `slo.*` stages of a
/// (possibly cross-node-merged) telemetry snapshot. Malformed labels
/// are skipped — stage blocks come off the wire.
pub fn parse_slo(telemetry: &TelemetrySnapshot) -> BTreeMap<String, SloView> {
    let mut out: BTreeMap<String, SloView> = BTreeMap::new();
    for (label, stats) in &telemetry.stages {
        let Some(rest) = label.strip_prefix(SLO_STAGE_PREFIX) else {
            continue;
        };
        let parts: Vec<&str> = rest.split('.').collect();
        let [name, kind] = parts[..] else { continue };
        if kind != "breach" && kind != "active" {
            continue;
        }
        let view = out.entry(name.to_string()).or_default();
        if kind == "breach" {
            view.breaches += stats.calls;
            view.threshold_milli = view.threshold_milli.max(stats.nanos);
        } else {
            view.active |= stats.calls > 0;
        }
    }
    out
}

/// Brownout status parsed back off the wire: `(level, raises)`.
/// On cross-node-merged snapshots both numbers are sums — a
/// total-pressure view. `None` when no node runs a brownout policy.
pub fn parse_brownout(telemetry: &TelemetrySnapshot) -> Option<(u64, u64)> {
    telemetry
        .stages
        .get(BROWNOUT_STAGE)
        .map(|s| (s.nanos, s.calls))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loaded(requests: u64, shed: u64) -> SloInput {
        SloInput { requests, shed, ..SloInput::default() }
    }

    #[test]
    fn override_list_parses_and_rejects_garbage() {
        let cfg = SloConfig::parse_overrides(
            "shed-rate=0.1, p99-latency=250000",
        )
        .unwrap();
        let get = |n: &str| {
            cfg.objectives.iter().find(|o| o.name == n).unwrap().threshold
        };
        assert_eq!(get("shed-rate"), 0.1);
        assert_eq!(get("p99-latency"), 250_000.0);
        // Untouched objectives keep their defaults.
        assert_eq!(get("deadline-miss"), 0.5);
        let e = SloConfig::parse_overrides("warp-speed=1")
            .unwrap_err()
            .to_string();
        assert!(e.contains("savings-floor"), "{e}");
        assert!(SloConfig::parse_overrides("shed-rate").is_err());
        assert!(SloConfig::parse_overrides("shed-rate=-1").is_err());
        assert!(SloConfig::parse_overrides("shed-rate=much").is_err());
    }

    #[test]
    fn breach_fires_once_per_transition_and_names_the_objective() {
        let flight =
            Arc::new(FlightRecorder::new("slo-test", 16, None));
        let engine =
            SloEngine::new(SloConfig::default(), Some(Arc::clone(&flight)));
        // Baseline, then a 60 % shed rate one fast-window later: both
        // windows resolve to the same baseline sample, so both burn
        // at 0.6/0.5 = 1.2.
        assert!(engine.observe(0, &loaded(0, 0)).is_empty());
        let fired = engine.observe(60_000, &loaded(100, 60));
        assert_eq!(fired, vec!["shed-rate"]);
        // Steady-state breach does not re-fire.
        assert!(engine.observe(61_000, &loaded(101, 61)).is_empty());
        // The flight ring got exactly one slo_breach naming it.
        let events: Vec<String> = flight
            .entries()
            .into_iter()
            .filter_map(|e| match e {
                crate::obs::FlightEntry::Event { kind, detail, .. } => {
                    assert_eq!(kind, TerminalKind::SloBreach);
                    Some(detail)
                }
                _ => None,
            })
            .collect();
        assert_eq!(events.len(), 1);
        assert!(events[0].contains("shed-rate"), "{}", events[0]);
        // Recovery (no sheds in the fast window) clears active, and
        // a later breach fires a second transition.
        assert!(engine.observe(121_000, &loaded(300, 61)).is_empty());
        // (261 sheds of 400 keeps the slow window burning too: the
        // slow base is still the t=0 sample.)
        let again = engine.observe(181_000, &loaded(400, 261));
        assert_eq!(again, vec!["shed-rate"]);
        let mut tele = TelemetrySnapshot::default();
        engine.to_stages(&mut tele);
        let view = parse_slo(&tele);
        assert_eq!(view["shed-rate"].breaches, 2);
        assert!(view["shed-rate"].active);
        assert_eq!(view["shed-rate"].threshold_milli, 500);
        assert!(!view["deadline-miss"].active);
    }

    #[test]
    fn p99_objective_tracks_the_level_not_a_delta() {
        let engine = SloEngine::new(SloConfig::default(), None);
        let slow = SloInput {
            requests: 10,
            responses: 10,
            p99_latency_us: 2_000_000,
            ..SloInput::default()
        };
        let fired = engine.observe(0, &slow);
        assert_eq!(fired, vec!["p99-latency"]);
        let fast = SloInput { p99_latency_us: 10, ..slow };
        assert!(engine.observe(1_000, &fast).is_empty());
    }

    #[test]
    fn savings_floor_breaches_only_below_the_floor_with_traffic() {
        let cfg =
            SloConfig::parse_overrides("savings-floor=40").unwrap();
        let engine = SloEngine::new(cfg, None);
        // No ledger traffic: silent.
        assert!(engine.observe(0, &SloInput::default()).is_empty());
        // 50 % savings over the window: above the 40 % floor.
        let good = SloInput {
            dense_bytes: 1000,
            encoded_bytes: 500,
            ..SloInput::default()
        };
        assert!(engine.observe(60_000, &good).is_empty());
        // Collapses to 10 % savings: breach.
        let bad = SloInput {
            dense_bytes: 3000,
            encoded_bytes: 2300,
            ..SloInput::default()
        };
        let fired = engine.observe(120_000, &bad);
        assert_eq!(fired, vec!["savings-floor"]);
    }

    #[test]
    fn default_savings_floor_is_disabled() {
        let engine = SloEngine::new(SloConfig::default(), None);
        let zero_savings = SloInput {
            dense_bytes: 1000,
            encoded_bytes: 1000,
            ..SloInput::default()
        };
        assert!(engine.observe(0, &zero_savings).is_empty());
    }

    #[test]
    fn parse_skips_malformed_slo_stages() {
        let mut tele = TelemetrySnapshot::default();
        for label in ["slo.x", "slo.a.b.c", "slo.a.unknown", "serve.execute"]
        {
            tele.stages.insert(
                label.into(),
                StageStats { nanos: 1, calls: 1, bytes: 1 },
            );
        }
        assert!(parse_slo(&tele).is_empty());
    }

    #[test]
    fn brownout_spec_parses_and_rejects_garbage() {
        assert_eq!(
            BrownoutConfig::parse("").unwrap(),
            BrownoutConfig::default()
        );
        let cfg = BrownoutConfig::parse("max=2, raise=1,lower=4").unwrap();
        assert_eq!(
            cfg,
            BrownoutConfig { max_level: 2, raise_after: 1, lower_after: 4 }
        );
        for bad in ["max", "max=0", "max=much", "dim=1"] {
            let e = BrownoutConfig::parse(bad).unwrap_err().to_string();
            assert!(e.contains("--brownout"), "{bad}: {e}");
        }
        assert!(BrownoutConfig::parse("dim=1")
            .unwrap_err()
            .to_string()
            .contains("max|raise|lower"));
    }

    #[test]
    fn brownout_raises_under_sustained_burn_and_lowers_on_recovery() {
        let flight = Arc::new(FlightRecorder::new("bo-test", 32, None));
        let cfg = SloConfig {
            brownout: Some(BrownoutConfig {
                max_level: 2,
                raise_after: 2,
                lower_after: 2,
            }),
            ..SloConfig::default()
        };
        let engine = SloEngine::new(cfg, Some(Arc::clone(&flight)));
        assert_eq!(engine.brownout_level(), 0);
        // Baseline, then sustained 60 % shed rate: the shed-rate
        // objective stays active every tick.
        engine.observe(0, &loaded(0, 0));
        engine.observe(60_000, &loaded(100, 60)); // streak 1
        assert_eq!(engine.brownout_level(), 0);
        engine.observe(61_000, &loaded(101, 61)); // streak 2 -> level 1
        assert_eq!(engine.brownout_level(), 1);
        engine.observe(62_000, &loaded(102, 62)); // streak 1
        engine.observe(63_000, &loaded(103, 63)); // streak 2 -> level 2
        assert_eq!(engine.brownout_level(), 2);
        // Already at max: further burn never overshoots.
        engine.observe(64_000, &loaded(104, 64));
        engine.observe(65_000, &loaded(105, 65));
        assert_eq!(engine.brownout_level(), 2);
        // Recovery: no sheds inside the fast window clears the
        // objective; two clear ticks lower one level each pair.
        engine.observe(131_000, &loaded(300, 65)); // clear 1
        assert_eq!(engine.brownout_level(), 2);
        engine.observe(132_000, &loaded(301, 65)); // clear 2 -> level 1
        assert_eq!(engine.brownout_level(), 1);
        engine.observe(133_000, &loaded(302, 65)); // clear 1
        engine.observe(134_000, &loaded(303, 65)); // clear 2 -> level 0
        assert_eq!(engine.brownout_level(), 0);
        // Flight ring saw exactly 2 enters and 2 exits, in order.
        let kinds: Vec<TerminalKind> = flight
            .entries()
            .into_iter()
            .filter_map(|e| match e {
                crate::obs::FlightEntry::Event { kind, .. } => Some(kind),
                _ => None,
            })
            .filter(|k| {
                matches!(
                    k,
                    TerminalKind::BrownoutEnter | TerminalKind::BrownoutExit
                )
            })
            .collect();
        assert_eq!(
            kinds,
            vec![
                TerminalKind::BrownoutEnter,
                TerminalKind::BrownoutEnter,
                TerminalKind::BrownoutExit,
                TerminalKind::BrownoutExit,
            ]
        );
    }

    #[test]
    fn brownout_stage_packs_level_and_raises() {
        let cfg = SloConfig {
            brownout: Some(BrownoutConfig {
                max_level: 3,
                raise_after: 1,
                lower_after: 8,
            }),
            ..SloConfig::default()
        };
        let engine = SloEngine::new(cfg, None);
        engine.observe(0, &loaded(0, 0));
        engine.observe(60_000, &loaded(100, 60)); // -> level 1
        let mut tele = TelemetrySnapshot::default();
        engine.to_stages(&mut tele);
        assert_eq!(parse_brownout(&tele), Some((1, 1)));
        // The .level suffix never leaks into the objective view.
        assert!(!parse_slo(&tele).contains_key("brownout"));
        // Engines without a policy pack nothing.
        let plain = SloEngine::new(SloConfig::default(), None);
        let mut tele2 = TelemetrySnapshot::default();
        plain.to_stages(&mut tele2);
        assert_eq!(parse_brownout(&tele2), None);
    }

    #[test]
    fn cross_node_merge_sums_breaches_and_ors_active() {
        let engine_a = SloEngine::new(SloConfig::default(), None);
        let engine_b = SloEngine::new(SloConfig::default(), None);
        engine_a.observe(0, &loaded(0, 0));
        engine_a.observe(60_000, &loaded(100, 90));
        engine_b.observe(0, &loaded(0, 0));
        let mut tele = TelemetrySnapshot::default();
        engine_a.to_stages(&mut tele);
        let mut tele_b = TelemetrySnapshot::default();
        engine_b.to_stages(&mut tele_b);
        tele.merge(&tele_b);
        let view = parse_slo(&tele);
        assert_eq!(view["shed-rate"].breaches, 1);
        assert!(view["shed-rate"].active);
    }
}
