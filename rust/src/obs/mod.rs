//! Observability: request-level tracing, a flight recorder, and the
//! unified metrics-export plane (`rust/docs/observability.md`).
//!
//! PR 6's telemetry answers "where does the *process* spend its
//! time?"; this module answers "where did *this request's*
//! microseconds and bytes go, and why was it shed?" — the request-level
//! truth the codec-autotune and zero-prediction roadmap items need.
//!
//! Three parts, one discipline (strict never-panicking parsing, no
//! wall-clock randomness, `util::json` for interchange):
//!
//! - [`trace`] — a 64-bit trace id assigned at the edge (client /
//!   loadgen) rides wire v3 through router dispatch, worker ingest,
//!   batch assembly and kernel execution; every hop appends [`Span`]s
//!   into the request's [`TraceRecord`], returned with the response
//!   when the id is sampled ([`sampled`] is deterministic from the id
//!   — same id, same answer, on every node).
//! - [`flight`] — a fixed-capacity ring of recent records plus
//!   terminal events (shed class, deadline miss, conn error, failover
//!   re-dispatch, worker death). Terminal events dump the ring as
//!   JSON-lines to `--flight-dir` for post-mortems; `zebra obs replay`
//!   renders the per-request waterfall.
//! - [`export`] — one registry merging `coordinator::Metrics`, the
//!   cluster [`MetricsSnapshot`](crate::cluster::MetricsSnapshot), and
//!   [`TelemetrySnapshot`](crate::telemetry::TelemetrySnapshot),
//!   exposed as Prometheus text exposition and JSON (`zebra obs`,
//!   `MetricsResp` v3, loadgen's `--scrape-ms` time series).
//!
//! PR 9 adds the *bandwidth* planes on the same discipline:
//!
//! - [`ledger`] — per-layer, per-codec atomic accounting of dense vs
//!   encoded bytes and zero blocks, recorded at the fused
//!   `relu_prune_encode` sweep and at spill ship/ingest; snapshots
//!   merge label-wise and ride the v3 telemetry block as synthetic
//!   `ledger.*` stages (no wire bump).
//! - [`slo`] — declarative objectives (shed rate, deadline-miss
//!   rate, p99 latency, bandwidth-savings floor) burned over
//!   fast/slow windows; breach transitions record
//!   [`TerminalKind::SloBreach`] flight events and export as
//!   `zebra_slo_breach`.
//!
//! PR 10 closes the loop (`rust/docs/robustness.md`): a
//! [`BrownoutConfig`] lets sustained burn *act* — progressively
//! shrinking Low/Normal admission caps and thinning trace sampling
//! until the burn clears — and the flight recorder gains circuit
//! breaker / spill-corruption / brownout terminal kinds fed by the
//! [`faults`](crate::faults) chaos engine's self-healing plane.

pub mod export;
pub mod flight;
pub mod ledger;
pub mod slo;
pub mod trace;

pub use export::{
    encode_telemetry, parse_breakers, parse_telemetry, parse_workers,
    BreakerView, ObsReport, WorkerView,
};
pub use flight::{FlightEntry, FlightRecorder, TerminalKind};
pub use ledger::{CellStats, Ledger, LedgerCell, LedgerSnapshot};
pub use slo::{
    parse_brownout, parse_slo, BrownoutConfig, SloConfig, SloEngine,
    SloInput, SloView,
};
pub use trace::{
    now_ns, render_waterfall, sampled, trace_id_for, Span, TraceRecord,
};
