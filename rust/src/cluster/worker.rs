//! A worker node: the single-process [`coordinator::Server`] wrapped
//! behind a TCP listener speaking the [`super::wire`] protocol.
//!
//! One `WorkerNode` owns one coordinator server (continuous batch
//! manager + executor threads over any [`BatchExecutor`]) and any
//! number of inbound connections — a router, several routers, or bare
//! clients. Each connection is two threads (reader + writer) plus one
//! response pump that funnels every coordinator reply for that
//! connection through [`Server::submit`]'s multiplexed channel, so a
//! connection's requests are pipelined without a thread per request.
//! A submit the coordinator sheds comes back as an explicit
//! `Overloaded` wire frame carrying the class and queue depth — the
//! router retries it on a peer or forwards it; it is never dropped
//! silently.
//!
//! With spill shipping configured ([`ShipSpills`] + an upstream
//! address), the coordinator's workers hand each executed batch's
//! `.zspill` frame to an upstream pump that ships it as a `SpillShip`
//! wire frame — the distributed analogue of the paper's DRAM-bandwidth
//! accounting, metered identically on both ends.
//!
//! Robustness (PR 10, `rust/docs/robustness.md`): inbound connections
//! get the server's read timeout (`--io-timeout-ms`; timeouts between
//! frames just loop — clients are legitimately idle), outbound frames
//! pass the chaos injector's `wire.worker` site when one is
//! configured, and the `worker.crash_after=N` fault kills this node
//! abruptly after its N-th `Submit` — the router-side failover and
//! breaker machinery's test dummy.

use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::metrics::MetricsSnapshot;
use super::wire::{self, Frame, FrameType, WireResponse};
use crate::coordinator::server::{BatchExecutor, Response};
use crate::coordinator::{
    Metrics, Server, ServerConfig, SubmitOutcome, SubmitRequest,
};
use crate::obs::{now_ns, ObsReport};
use crate::telemetry::{Stage, Telemetry};

/// How often the accept loop polls its shutdown flag.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Reconnect backoff for the upstream spill pump.
const UPSTREAM_RETRY: Duration = Duration::from_millis(200);

/// Live connection handles (clones keyed by a connection id), so
/// `kill` can sever them; each entry is pruned when its connection's
/// reader exits, so long-lived nodes don't accumulate dead fds.
type ConnTable = Arc<Mutex<Vec<(u64, TcpStream)>>>;

/// The abrupt-death closure the chaos `worker.crash_after` fault
/// fires (shared by every connection thread).
type CrashFn = Arc<dyn Fn() + Send + Sync>;

/// A running worker node.
pub struct WorkerNode {
    server: Arc<Server>,
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: ConnTable,
}

impl WorkerNode {
    /// Build the coordinator server from an executor and expose it on
    /// `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port — read the
    /// bound address back with [`WorkerNode::local_addr`]).
    ///
    /// `ship_upstream` names a peer (normally the router) that receives
    /// every executed batch's `.zspill` frame as a `SpillShip` wire
    /// frame; it requires `server_cfg.ship_spills` to be set.
    pub fn start(
        exec: Arc<dyn BatchExecutor>,
        listen: &str,
        mut server_cfg: ServerConfig,
        ship_upstream: Option<String>,
    ) -> Result<WorkerNode> {
        let upstream = match ship_upstream {
            Some(addr) => {
                anyhow::ensure!(
                    server_cfg.ship_spills.is_some(),
                    "--ship-upstream needs spill shipping configured \
                     (ship_spills / --ship-codec)"
                );
                let (tx, rx) = channel::<Vec<u8>>();
                server_cfg.spill_sink = Some(tx);
                Some((addr, rx))
            }
            None => None,
        };
        let hw = exec.image_hw();
        let server = Arc::new(Server::start(exec, server_cfg));
        Self::attach(server, hw, listen, upstream)
    }

    /// Expose an already-started coordinator server over TCP (`zebra
    /// serve --port` uses this: same server, network front optional).
    /// `upstream` pairs a destination address with the receiving end
    /// of the server's `spill_sink` channel.
    pub fn attach(
        server: Arc<Server>,
        image_hw: usize,
        listen: &str,
        upstream: Option<(String, Receiver<Vec<u8>>)>,
    ) -> Result<WorkerNode> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("cluster worker cannot bind {listen}"))?;
        let addr = listener.local_addr()?;
        listener
            .set_nonblocking(true)
            .context("worker listener nonblocking")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: ConnTable = Arc::new(Mutex::new(Vec::new()));
        // The chaos `worker.crash_after` fault dies like a real crash:
        // intake closed, flight ring dumped (the post-mortem), every
        // connection severed mid-stream — peers observe a reset, not a
        // goodbye.
        let crash: CrashFn = {
            let server = server.clone();
            let sd = shutdown.clone();
            let conns = conns.clone();
            Arc::new(move || {
                eprintln!(
                    "[cluster-worker] chaos crash_after fired; dying \
                     abruptly"
                );
                sd.store(true, Ordering::SeqCst);
                server.close();
                if let Some(f) = &server.flight {
                    if let Some(Err(e)) = f.dump() {
                        eprintln!(
                            "[cluster-worker] flight dump failed: {e}"
                        );
                    }
                }
                for (_, c) in conns.lock().unwrap().drain(..) {
                    let _ = c.shutdown(std::net::Shutdown::Both);
                }
            })
        };
        if let Some((peer, rx)) = upstream {
            let sd = shutdown.clone();
            let st = server.telemetry.stage("wire.ship_upstream");
            std::thread::spawn(move || upstream_pump(peer, rx, sd, st));
        }
        let accept = {
            let server = server.clone();
            let sd = shutdown.clone();
            let conns = conns.clone();
            std::thread::spawn(move || {
                accept_loop(listener, server, image_hw, sd, conns, crash)
            })
        };
        Ok(WorkerNode {
            server,
            addr,
            shutdown,
            accept: Some(accept),
            conns,
        })
    }

    /// The bound listen address (resolves `--port 0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// This node's live serving metrics.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.server.metrics.clone()
    }

    /// This node's wall-time/byte telemetry (the coordinator's stages
    /// plus the wire-layer `wire.*` stages this module records).
    pub fn telemetry(&self) -> Arc<Telemetry> {
        self.server.telemetry.clone()
    }

    /// The wrapped coordinator server.
    pub fn server(&self) -> &Server {
        &self.server
    }

    /// Abrupt stop, usable from a shared reference: stop accepting,
    /// close the coordinator intake, and sever every open connection
    /// mid-stream. Peers observe an EOF/reset — this is what the
    /// failover tests use to "kill" a worker. A configured flight
    /// recorder dumps its ring on the way down (the post-mortem a dead
    /// worker leaves behind).
    pub fn kill(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.server.close();
        if let Some(f) = &self.server.flight {
            if let Some(Err(e)) = f.dump() {
                eprintln!("[cluster-worker] flight dump failed: {e}");
            }
        }
        for (_, c) in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
    }

    /// Graceful stop: [`WorkerNode::kill`] + join the accept loop.
    pub fn shutdown(mut self) {
        self.kill();
        if let Some(h) = self.accept.take() {
            h.join().ok();
        }
    }
}

impl Drop for WorkerNode {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.server.close();
    }
}

fn accept_loop(
    listener: TcpListener,
    server: Arc<Server>,
    image_hw: usize,
    shutdown: Arc<AtomicBool>,
    conns: ConnTable,
    crash: CrashFn,
) {
    let mut next_conn = 0u64;
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nodelay(true);
                let conn_id = next_conn;
                next_conn += 1;
                if let Ok(clone) = stream.try_clone() {
                    conns.lock().unwrap().push((conn_id, clone));
                }
                let server = server.clone();
                let sd = shutdown.clone();
                let conns = conns.clone();
                let crash = crash.clone();
                std::thread::spawn(move || {
                    serve_conn(server, image_hw, stream, sd, crash);
                    // The connection is over: drop our severing handle
                    // so long-lived nodes don't accumulate dead fds.
                    conns.lock().unwrap().retain(|(id, _)| *id != conn_id);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => break,
        }
    }
}

/// What the response pump needs to answer one in-flight wire request:
/// the wire id to echo, the requester's wire version (replies are
/// stamped with — and shaped for — it), and, for sampled requests, the
/// `worker.ingest` span endpoints captured at frame-handling time.
struct PendingResp {
    wire_id: u64,
    version: u16,
    /// `(start_ns, end_ns, payload_bytes)` of the ingest span; `None`
    /// for unsampled requests.
    ingest: Option<(u64, u64, u64)>,
}

/// One connection: reader (this thread) + writer thread + response
/// pump thread. The pump owns the coordinator-id -> wire-id map shared
/// with the reader; holding its lock across `Server::submit` closes
/// the insert/response race for even the fastest executor.
fn serve_conn(
    server: Arc<Server>,
    image_hw: usize,
    stream: TcpStream,
    shutdown: Arc<AtomicBool>,
    crash: CrashFn,
) {
    let mut rd = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // Socket hygiene: a silent peer must not pin this reader forever.
    // Timeouts between frames just loop (peers are legitimately idle
    // between requests) — the loop re-checks the shutdown flag.
    let _ = rd.set_read_timeout(server.io_timeout);
    let (out_tx, out_rx) = channel::<Vec<u8>>();
    let faults = server.faults.clone();
    let writer =
        std::thread::spawn(move || writer_loop(stream, out_rx, faults));
    let idmap: Arc<Mutex<HashMap<u64, PendingResp>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let (resp_tx, resp_rx) = channel::<Response>();
    let pump = {
        let idmap = idmap.clone();
        let out_tx = out_tx.clone();
        let st = server.telemetry.stage("wire.respond");
        std::thread::spawn(move || response_pump(resp_rx, idmap, out_tx, st))
    };

    // Wire-layer accounting: inbound frame dispatch time + payload
    // bytes, per connection-reader thread (handles resolved once).
    let st_handle = server.telemetry.stage("wire.handle");
    while !shutdown.load(Ordering::SeqCst) {
        let frame = match Frame::read_from(&mut rd) {
            Ok(f) => f,
            Err(e) if e.is_timeout() => continue,
            Err(e) => {
                if !e.is_clean_eof() && !shutdown.load(Ordering::SeqCst) {
                    eprintln!("[cluster-worker] closing connection: {e}");
                }
                break;
            }
        };
        st_handle.add_bytes(frame.payload.len() as u64);
        let _t = st_handle.time();
        let is_submit = frame.ty == FrameType::Submit;
        let reply = handle_frame(&server, image_hw, &idmap, &resp_tx, frame);
        if let Some(bytes) = reply {
            if out_tx.send(bytes).is_err() {
                break;
            }
        }
        // Chaos `worker.crash_after=N`: die abruptly once the N-th
        // submit has been handled — the request may or may not have
        // been answered, exactly like a real mid-stream crash.
        if is_submit {
            if let Some(fi) = &server.faults {
                if fi.crash_now() {
                    crash();
                    break;
                }
            }
        }
    }
    // Reader is done: drop our senders so the pump (once the
    // coordinator answers everything outstanding) and then the writer
    // wind down on their own.
    drop(out_tx);
    drop(resp_tx);
    let _ = pump.join();
    let _ = writer.join();
}

/// Dispatch one inbound frame; returns an immediate reply frame's
/// bytes if one is due (submit responses flow through the pump
/// instead).
fn handle_frame(
    server: &Server,
    image_hw: usize,
    idmap: &Mutex<HashMap<u64, PendingResp>>,
    resp_tx: &Sender<Response>,
    frame: Frame,
) -> Option<Vec<u8>> {
    // Every reply is stamped with the requester's wire version, so a
    // v1/v2 peer never sees a frame above what it can parse.
    let version = frame.version;
    match frame.ty {
        FrameType::Submit => {
            let ingest_start_ns = now_ns();
            let sub =
                match wire::parse_submit(frame.version, &frame.payload) {
                    Ok(x) => x,
                    Err(e) => {
                        return Some(error_frame(
                            version,
                            frame.id,
                            &e.to_string(),
                        ))
                    }
                };
            if sub.image.shape() != [3, image_hw, image_hw] {
                return Some(error_frame(
                    version,
                    frame.id,
                    &format!(
                        "image shape {:?} does not match this worker's \
                         (3, {image_hw}, {image_hw})",
                        sub.image.shape()
                    ),
                ));
            }
            let ingest = sub.trace.then(|| {
                (ingest_start_ns, now_ns(), frame.payload.len() as u64)
            });
            let req = SubmitRequest::new(sub.image)
                .with_key(sub.key)
                .with_priority(sub.priority)
                .with_trace(sub.trace_id, sub.trace);
            let req = match sub.deadline {
                Some(d) => req.with_deadline(d),
                None => req,
            };
            // Holding the map lock across submit guarantees the wire
            // id is registered before the pump can see the reply.
            let mut map = idmap.lock().unwrap();
            match server.submit(req, resp_tx.clone()) {
                SubmitOutcome::Enqueued { id } => {
                    map.insert(
                        id,
                        PendingResp { wire_id: frame.id, version, ingest },
                    );
                    None
                }
                SubmitOutcome::Shed { priority, queued } => {
                    drop(map);
                    let f = Frame::overloaded(
                        frame.id,
                        priority,
                        queued as u64,
                        &format!(
                            "worker shed {} class request \
                             ({queued} queued)",
                            priority.name()
                        ),
                    );
                    Some(Frame { version, ..f }.encode())
                }
                SubmitOutcome::Closed => {
                    drop(map);
                    Some(error_frame(
                        version,
                        frame.id,
                        "worker is shutting down",
                    ))
                }
            }
        }
        FrameType::Heartbeat => Some(frame.encode()),
        FrameType::MetricsReq => {
            // v3 requesters get the telemetry block appended — with
            // the node's ledger and SLO planes folded in as synthetic
            // `ledger.*` / `slo.*` stages; older requesters get the
            // bare snapshot their strict parse expects.
            let report = ObsReport::single_node(
                MetricsSnapshot::from_metrics(&server.metrics),
                server.obs_telemetry(),
            );
            let payload = report.encode_wire(version, false);
            let f = Frame::new(FrameType::MetricsResp, frame.id, payload);
            Some(Frame { version, ..f }.encode())
        }
        other => Some(error_frame(
            version,
            frame.id,
            &format!("worker cannot serve frame type {other:?}"),
        )),
    }
}

fn error_frame(version: u16, id: u64, msg: &str) -> Vec<u8> {
    let f = Frame::new(FrameType::Error, id, msg.as_bytes().to_vec());
    Frame { version, ..f }.encode()
}

fn writer_loop(
    mut stream: TcpStream,
    rx: Receiver<Vec<u8>>,
    faults: Option<Arc<crate::faults::FaultInjector>>,
) {
    while let Ok(bytes) = rx.recv() {
        // Chaos taps outbound frames at the `wire.worker` site —
        // responses, heartbeat echoes, and metrics alike, the same
        // way a flaky NIC would not discriminate.
        let mut bytes = bytes;
        if let Some(fi) = &faults {
            if !fi.on_wire_frame("wire.worker", &mut bytes) {
                continue; // injected drop
            }
        }
        if stream.write_all(&bytes).is_err() {
            break;
        }
    }
}

fn response_pump(
    rx: Receiver<Response>,
    idmap: Arc<Mutex<HashMap<u64, PendingResp>>>,
    out_tx: Sender<Vec<u8>>,
    st_respond: Arc<Stage>,
) {
    while let Ok(mut resp) = rx.recv() {
        let _t = st_respond.time();
        let pending = idmap.lock().unwrap().remove(&resp.id);
        let Some(pending) = pending else { continue };
        // Sampled requests: append this node's ingest span (frame
        // receipt -> coordinator submit) to the coordinator-assembled
        // record before it goes back on the wire.
        if let (Some(rec), Some((start, end, bytes))) =
            (resp.trace.as_mut(), pending.ingest)
        {
            rec.push("worker.ingest", start, end, bytes, 0);
        }
        let payload = wire::encode_response(
            pending.version,
            &WireResponse::from_response(&resp),
            resp.trace.as_ref(),
        );
        let f = Frame::new(FrameType::Response, pending.wire_id, payload);
        let bytes = Frame { version: pending.version, ..f }.encode();
        st_respond.add_bytes(bytes.len() as u64);
        if out_tx.send(bytes).is_err() {
            break;
        }
    }
}

/// Ships `.zspill` frames (already metered by the coordinator worker
/// that produced them) to `addr` as `SpillShip` wire frames. Holds on
/// to frames across reconnects so a late-starting or briefly-absent
/// upstream loses nothing; exits when the server side hangs up (all
/// sink senders dropped) or the node shuts down.
fn upstream_pump(
    addr: String,
    rx: Receiver<Vec<u8>>,
    shutdown: Arc<AtomicBool>,
    st_ship: Arc<Stage>,
) {
    let mut conn: Option<TcpStream> = None;
    let mut seq = 0u64;
    while let Ok(spill) = rx.recv() {
        let _t = st_ship.time();
        let bytes = Frame::new(FrameType::SpillShip, seq, spill).encode();
        st_ship.add_bytes(bytes.len() as u64);
        seq += 1;
        loop {
            if shutdown.load(Ordering::SeqCst) {
                return;
            }
            if conn.is_none() {
                match TcpStream::connect(&addr) {
                    Ok(s) => {
                        let _ = s.set_nodelay(true);
                        conn = Some(s);
                    }
                    Err(_) => {
                        std::thread::sleep(UPSTREAM_RETRY);
                        continue;
                    }
                }
            }
            match conn.as_mut().unwrap().write_all(&bytes) {
                Ok(()) => break,
                Err(_) => conn = None,
            }
        }
    }
}
