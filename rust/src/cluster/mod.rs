//! Multi-node Zebra serving over TCP: the coordinator, scaled out.
//!
//! The single-process [`coordinator`](crate::coordinator) already
//! frames executed batches as versioned `.zspill` bytes — "the wire
//! bytes a multi-node deployment ships between coordinator nodes".
//! This module is that deployment:
//!
//! - [`wire`] — the length-prefixed, versioned, FNV-checksummed frame
//!   protocol (magic `ZCLU`, version 3 with version-1/2 peers still
//!   accepted and answered in their own version), carrying Submit /
//!   Response / Heartbeat / SpillShip / Error / Metrics / Overloaded
//!   frames with the same strict never-panicking parse guarantees as
//!   `.zspill` itself. v2 submits carry a priority class and an
//!   optional deadline; v3 adds an edge-assigned trace id + sampling
//!   flag on submits, an optional `TraceRecord` tail on responses,
//!   and a telemetry block on `MetricsResp`
//!   (see `rust/docs/observability.md`).
//! - [`worker`] — a [`WorkerNode`]: the coordinator server behind a
//!   TCP listener, executing on any
//!   [`BatchExecutor`](crate::coordinator::server::BatchExecutor)
//!   (reference backend in every build, PJRT under the feature gate),
//!   optionally shipping its `.zspill` batch frames upstream.
//! - [`router`] — a [`Router`]: shards client requests across workers
//!   (round-robin or consistent-hash-by-key), enforces per-worker
//!   priority-class admission caps (shed lowest class first, answered
//!   with explicit `Overloaded` frames), retries a failed worker's
//!   in-flight requests on its peers, and tracks liveness via
//!   heartbeats.
//! - [`client`] — a [`ClusterClient`]: one pipelined connection with
//!   the same submit/response ergonomics as the in-process server.
//! - [`metrics`] — wire-portable [`MetricsSnapshot`]s of each node's
//!   [`coordinator::Metrics`](crate::coordinator::Metrics) and the
//!   router's cluster-wide [`ClusterStats`] aggregation (histograms
//!   merged bucket-wise; Eq. 2–3 byte totals summed).
//!
//! Zebra's thesis — prune zero blocks so fewer bytes cross the
//! memory interface — applies one tier up unchanged: the bytes a
//! worker ships per batch are exactly its `.zspill` frame sizes, so
//! the cluster's inter-node bandwidth enjoys the same Eq. 2–3 savings
//! the paper claims for DRAM, and both ends meter it identically.
//!
//! Topology, protocol tables, and failover semantics are documented
//! in `rust/docs/cluster.md`; `zebra cluster-worker`,
//! `zebra cluster-router`, and `zebra loadgen` are the CLI entry
//! points. Everything is std threads + channels (tokio is not in the
//! offline vendor set — DESIGN.md §7), matching the coordinator.

pub mod client;
pub mod metrics;
pub mod router;
pub mod wire;
pub mod worker;

pub use client::{ClusterClient, ClusterError, ClusterResponse, Delivery};
pub use metrics::{ClusterStats, MetricsSnapshot};
pub use router::{Router, RouterConfig, ShardMode};
pub use wire::{Frame, FrameError, FrameType, WireResponse, WireSubmit};
pub use worker::WorkerNode;
