//! The cluster wire protocol: length-prefixed, versioned, checksummed
//! frames over TCP — the `.zspill` header discipline (`compress`,
//! `rust/docs/zspill.md`) applied one tier up, to the bytes cluster
//! nodes exchange.
//!
//! Frame layout (all integers little-endian; table in
//! `rust/docs/cluster.md`):
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "ZCLU"
//! 4       2     version (3; versions 1–2 still accepted — see below)
//! 6       2     frame type (FrameType)
//! 8       8     request id (client-chosen; echoed on responses)
//! 16      4     FNV-1a checksum of the whole frame, this field zeroed
//! 20      8     payload length
//! 28      ...   payload
//! ```
//!
//! Versioning: this build emits [`CLUSTER_VERSION`] (3) and accepts
//! any version in [`MIN_CLUSTER_VERSION`]`..=`[`CLUSTER_VERSION`], so
//! v1 (PR 4–6 builds) and v2 (PR 7) peers keep working through a
//! rolling upgrade. The parsed version rides on [`Frame::version`];
//! payload codecs that changed shape across versions
//! ([`parse_submit`], [`parse_response`]) take it as an argument and
//! dispatch on it. Frames *answering* a peer are stamped with the
//! requester's version, so replies never outrun what the peer can
//! parse (a v1/v2 build rejects frames above its own version).
//!
//! Parsing guarantees mirror `.zspill`: strictly bounds-checked, the
//! declared payload length is capped at [`MAX_PAYLOAD`] *before* any
//! allocation, the checksum (same FNV-1a bijection argument as the
//! spill codec's) catches every single-bit corruption, and every
//! malformed input returns a [`FrameError`] — [`Frame::parse`] and
//! [`Frame::read_from`] never panic. Fuzz tests below drive
//! truncation, bit flips, wrong frame types, and absurd length
//! prefixes through both entry points.
//!
//! Payload conventions:
//! - `Submit` (v3): an 8-byte shard key, a 1-byte [`Priority`] class,
//!   an 8-byte deadline in microseconds (0 = none), an 8-byte trace id
//!   (0 = untraced), a flags byte (bit 0 = sampled: return the
//!   [`TraceRecord`](crate::obs::TraceRecord) with the response), then
//!   a dense `.zspill` frame of the `(3, H, W)` image
//!   ([`encode_submit_traced`] / [`parse_submit`]) — image bytes cross
//!   the wire in the same self-describing format spills do. A v2
//!   `Submit` omits the trace id/flags (parses untraced); a v1
//!   `Submit` additionally omits priority/deadline (parses as `Normal`
//!   with no deadline).
//! - `Response`: a packed [`WireResponse`] ([`WireResponse::encode`]);
//!   on v3, a sampled request's response carries its encoded
//!   `TraceRecord` after the logits ([`encode_response`] /
//!   [`parse_response`]). v1/v2 requesters always get the bare body —
//!   their strict parsers reject trailing bytes.
//! - `Error`: UTF-8 message.
//! - `Overloaded`: admission control's explicit refusal for the id —
//!   the shed request's 1-byte priority class, the 8-byte queue depth
//!   observed at shed time, then a UTF-8 detail message
//!   ([`Frame::overloaded`] / [`parse_overloaded`]). Distinct from
//!   `Error` so clients and the router can count sheds separately from
//!   failures — a shed is a policy outcome, not a fault.
//! - `Heartbeat`: empty; the receiver echoes the frame back verbatim.
//! - `SpillShip`: a raw `.zspill` frame — a worker's executed batch,
//!   shipped upstream. The payload length is exactly the
//!   `shipped_spill_bytes` the worker metered for it.
//! - `MetricsReq` / `MetricsResp`: empty request; the response payload
//!   is a [`super::metrics::MetricsSnapshot`] (worker) or
//!   [`super::metrics::ClusterStats`] (router).

use std::io::{Read, Write};
use std::time::Duration;

use crate::compress::{self, fnv1a, Codec, DenseCodec, FNV_SEED};
use crate::coordinator::batch_manager::Priority;
use crate::tensor::Tensor;

/// Cluster frame magic.
pub const CLUSTER_MAGIC: [u8; 4] = *b"ZCLU";

/// Wire protocol version this build emits. v2 added the priority +
/// deadline fields on `Submit` and the `Overloaded` frame type; v3
/// added the trace id + flags on `Submit`, the optional appended
/// `TraceRecord` on `Response`, and the appended telemetry block on
/// `MetricsResp`.
pub const CLUSTER_VERSION: u16 = 3;

/// Oldest wire version this build still accepts (rolling upgrades:
/// v1/v2 peers' frames parse; their submits get defaults for the
/// fields their version lacks, and replies to them are stamped with
/// — and shaped for — their version).
pub const MIN_CLUSTER_VERSION: u16 = 1;

/// Fixed header length in bytes.
pub const HDR_LEN: usize = 28;

/// Byte offset of the checksum field inside the header.
const CK_OFF: usize = 16;

/// Hard cap on a frame's declared payload length: nothing a node ever
/// legitimately ships (images, batch spills, metrics) approaches this,
/// and capping *before* allocation means a hostile length prefix can
/// never balloon memory.
pub const MAX_PAYLOAD: usize = 1 << 26; // 64 MiB

/// Frame kinds carried on cluster connections.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u16)]
pub enum FrameType {
    /// Client/router -> worker: classify one image.
    Submit = 0,
    /// Worker/router -> client: the answer for a `Submit`'s id.
    Response = 1,
    /// Liveness probe; echoed back verbatim by the receiver.
    Heartbeat = 2,
    /// Worker -> upstream: one executed batch's `.zspill` frame.
    SpillShip = 3,
    /// Terminal failure for the id (message in the payload).
    Error = 4,
    /// Ask a node for its metrics.
    MetricsReq = 5,
    /// Metrics answer (snapshot or cluster-wide stats).
    MetricsResp = 6,
    /// Admission control shed the id (priority + queue depth + detail
    /// in the payload). A policy outcome, not a fault — never silent.
    Overloaded = 7,
}

impl FrameType {
    pub fn as_u16(self) -> u16 {
        self as u16
    }

    pub fn from_u16(v: u16) -> Option<FrameType> {
        match v {
            0 => Some(FrameType::Submit),
            1 => Some(FrameType::Response),
            2 => Some(FrameType::Heartbeat),
            3 => Some(FrameType::SpillShip),
            4 => Some(FrameType::Error),
            5 => Some(FrameType::MetricsReq),
            6 => Some(FrameType::MetricsResp),
            7 => Some(FrameType::Overloaded),
            _ => None,
        }
    }
}

/// One wire frame: version + type + request id + payload bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// Wire version the frame was built with (or parsed from) —
    /// payload codecs that changed shape dispatch on this.
    pub version: u16,
    pub ty: FrameType,
    pub id: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn new(ty: FrameType, id: u64, payload: Vec<u8>) -> Frame {
        Frame { version: CLUSTER_VERSION, ty, id, payload }
    }

    /// Serialize: header (checksum backfilled) + payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HDR_LEN + self.payload.len());
        out.extend_from_slice(&CLUSTER_MAGIC);
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&self.ty.as_u16().to_le_bytes());
        out.extend_from_slice(&self.id.to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes()); // checksum backfill
        out.extend_from_slice(&(self.payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&self.payload);
        let ck = frame_checksum(&out);
        out[CK_OFF..CK_OFF + 4].copy_from_slice(&ck.to_le_bytes());
        out
    }

    /// Parse exactly one frame from `bytes` (trailing bytes are an
    /// error). Never panics; never allocates from unverified lengths.
    pub fn parse(bytes: &[u8]) -> Result<Frame, FrameError> {
        let have = bytes.len();
        if have < HDR_LEN {
            return Err(FrameError::Truncated { need: HDR_LEN, have });
        }
        let mut hdr = [0u8; HDR_LEN];
        hdr.copy_from_slice(&bytes[..HDR_LEN]);
        let (version, ty, id, payload_len) = validate_header(&hdr)?;
        let declared = HDR_LEN as u64 + payload_len as u64;
        if declared != have as u64 {
            return Err(FrameError::SectionMismatch {
                declared,
                have: have as u64,
            });
        }
        check_checksum(&hdr, &bytes[HDR_LEN..])?;
        Ok(Frame { version, ty, id, payload: bytes[HDR_LEN..].to_vec() })
    }

    /// Read one frame off a stream. Truncated streams, bad headers,
    /// oversized length prefixes, and checksum mismatches all return
    /// errors — a peer can close or corrupt the connection at any byte
    /// without ever panicking this side.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Frame, FrameError> {
        let mut hdr = [0u8; HDR_LEN];
        r.read_exact(&mut hdr).map_err(FrameError::Io)?;
        let (version, ty, id, payload_len) = validate_header(&hdr)?;
        let mut payload = vec![0u8; payload_len];
        r.read_exact(&mut payload).map_err(FrameError::Io)?;
        check_checksum(&hdr, &payload)?;
        Ok(Frame { version, ty, id, payload })
    }

    /// Build an `Overloaded` frame: the shed request's priority class,
    /// the queue depth observed at shed time, and a human-readable
    /// detail for the client's error surface.
    pub fn overloaded(
        id: u64,
        priority: Priority,
        queued: u64,
        detail: &str,
    ) -> Frame {
        let mut payload = Vec::with_capacity(9 + detail.len());
        payload.push(priority.as_u8());
        payload.extend_from_slice(&queued.to_le_bytes());
        payload.extend_from_slice(detail.as_bytes());
        Frame::new(FrameType::Overloaded, id, payload)
    }

    /// Write the encoded frame to a stream.
    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        w.write_all(&self.encode())
    }
}

/// Validate the fixed header; returns (version, type, id, payload_len)
/// with the payload length already capped at [`MAX_PAYLOAD`].
fn validate_header(
    hdr: &[u8; HDR_LEN],
) -> Result<(u16, FrameType, u64, usize), FrameError> {
    if hdr[0..4] != CLUSTER_MAGIC {
        return Err(FrameError::BadMagic([hdr[0], hdr[1], hdr[2], hdr[3]]));
    }
    let version = u16::from_le_bytes([hdr[4], hdr[5]]);
    if !(MIN_CLUSTER_VERSION..=CLUSTER_VERSION).contains(&version) {
        return Err(FrameError::BadVersion(version));
    }
    let ty_raw = u16::from_le_bytes([hdr[6], hdr[7]]);
    let ty = FrameType::from_u16(ty_raw)
        .ok_or(FrameError::BadFrameType(ty_raw))?;
    let id = u64::from_le_bytes(hdr[8..16].try_into().expect("8 bytes"));
    let payload_len =
        u64::from_le_bytes(hdr[20..28].try_into().expect("8 bytes"));
    if payload_len > MAX_PAYLOAD as u64 {
        return Err(FrameError::Oversized { declared: payload_len });
    }
    Ok((version, ty, id, payload_len as usize))
}

/// Frame checksum: FNV-1a over header (checksum field zeroed) +
/// payload — the same discipline `.zspill` uses, with the same
/// single-bit-corruption detection argument.
fn frame_checksum(frame: &[u8]) -> u32 {
    let h = fnv1a(FNV_SEED, &frame[..CK_OFF]);
    let h = fnv1a(h, &[0u8; 4]);
    fnv1a(h, &frame[CK_OFF + 4..])
}

fn check_checksum(
    hdr: &[u8; HDR_LEN],
    payload: &[u8],
) -> Result<(), FrameError> {
    let stored =
        u32::from_le_bytes(hdr[CK_OFF..CK_OFF + 4].try_into().unwrap());
    let h = fnv1a(FNV_SEED, &hdr[..CK_OFF]);
    let h = fnv1a(h, &[0u8; 4]);
    let h = fnv1a(h, &hdr[CK_OFF + 4..]);
    let computed = fnv1a(h, payload);
    if stored != computed {
        return Err(FrameError::Checksum { stored, computed });
    }
    Ok(())
}

/// Cluster frame failure. Every variant is terminal for the frame; IO
/// variants are usually terminal for the connection.
#[derive(Debug)]
pub enum FrameError {
    /// The underlying stream failed (includes EOF mid-frame).
    Io(std::io::Error),
    /// A whole-buffer parse was handed fewer bytes than a header.
    Truncated { need: usize, have: usize },
    BadMagic([u8; 4]),
    BadVersion(u16),
    BadFrameType(u16),
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversized { declared: u64 },
    /// Whole-buffer parse where declared length != buffer length.
    SectionMismatch { declared: u64, have: u64 },
    Checksum { stored: u32, computed: u32 },
    /// The frame was well-formed but its payload wasn't (bad submit
    /// image, short response, inconsistent metrics block).
    Malformed(&'static str),
}

impl FrameError {
    /// True when the error is a clean end-of-stream before any header
    /// byte arrived — an orderly peer disconnect, not corruption.
    pub fn is_clean_eof(&self) -> bool {
        matches!(self, FrameError::Io(e)
            if e.kind() == std::io::ErrorKind::UnexpectedEof)
    }

    /// True when the error is a socket read-timeout expiry (the
    /// `--io-timeout-ms` hygiene timers), not data corruption — an
    /// idle-but-healthy peer, distinguishable from a wedged one only
    /// by whether work is outstanding. Both `WouldBlock` and
    /// `TimedOut` appear depending on platform.
    pub fn is_timeout(&self) -> bool {
        matches!(self, FrameError::Io(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut)
    }
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "cluster frame io: {e}"),
            FrameError::Truncated { need, have } => write!(
                f,
                "cluster frame truncated: need {need} bytes, have {have}"
            ),
            FrameError::BadMagic(m) => {
                write!(f, "cluster frame bad magic {m:02x?} (want \"ZCLU\")")
            }
            FrameError::BadVersion(v) => write!(
                f,
                "cluster frame version {v} (this build speaks \
                 {MIN_CLUSTER_VERSION}..={CLUSTER_VERSION})"
            ),
            FrameError::BadFrameType(t) => {
                write!(f, "cluster frame unknown type {t}")
            }
            FrameError::Oversized { declared } => write!(
                f,
                "cluster frame declares {declared} payload bytes (cap \
                 {MAX_PAYLOAD})"
            ),
            FrameError::SectionMismatch { declared, have } => write!(
                f,
                "cluster frame declares {declared} bytes, buffer has {have}"
            ),
            FrameError::Checksum { stored, computed } => write!(
                f,
                "cluster frame checksum mismatch: stored {stored:#010x}, \
                 computed {computed:#010x}"
            ),
            FrameError::Malformed(why) => {
                write!(f, "cluster frame malformed payload: {why}")
            }
        }
    }
}

impl std::error::Error for FrameError {}

// ---------------------------------------------------------------------
// Submit payload: key [+ priority + deadline [+ trace]] + dense .zspill
// ---------------------------------------------------------------------

/// Fixed bytes before the image spill in a v2 `Submit` payload:
/// key (8) + priority (1) + deadline_us (8).
const SUBMIT_V2_HDR: usize = 17;

/// Fixed bytes before the image spill in a v3 `Submit` payload:
/// the v2 fields + trace_id (8) + flags (1).
const SUBMIT_V3_HDR: usize = SUBMIT_V2_HDR + 9;

/// Flags bit 0: the request is sampled — every hop appends spans and
/// the response carries the assembled `TraceRecord`. Other bits are
/// reserved (ignored on parse, emitted as 0) so future flags stay
/// compatible in both directions.
const SUBMIT_FLAG_SAMPLED: u8 = 1;

/// The decoded fields of a `Submit` payload, version differences
/// already normalized away (a v1 submit is `Normal` with no deadline;
/// v1/v2 submits are untraced).
#[derive(Debug, Clone, PartialEq)]
pub struct WireSubmit {
    pub key: u64,
    pub priority: Priority,
    /// Client-requested completion deadline, measured from arrival at
    /// the serving node.
    pub deadline: Option<Duration>,
    /// Edge-assigned trace id (0 = untraced). Nonzero ids ride into
    /// flight-recorder events even when the request isn't sampled.
    pub trace_id: u64,
    /// Sampled: assemble and return a `TraceRecord` with the response.
    pub trace: bool,
    pub image: Tensor,
}

/// Encode an untraced `Submit` payload (trace id 0, not sampled) —
/// the pre-v3 call shape, kept for everything that doesn't trace.
pub fn encode_submit(
    key: u64,
    priority: Priority,
    deadline: Option<Duration>,
    image: &Tensor,
) -> Vec<u8> {
    encode_submit_traced(key, priority, deadline, 0, false, image)
}

/// Encode a v3 `Submit` payload: the 8-byte shard key, the priority
/// class byte, the deadline in microseconds (0 = none), the 8-byte
/// trace id, the flags byte, then the image as a dense `.zspill`
/// frame.
pub fn encode_submit_traced(
    key: u64,
    priority: Priority,
    deadline: Option<Duration>,
    trace_id: u64,
    sampled: bool,
    image: &Tensor,
) -> Vec<u8> {
    let spill = DenseCodec.encode(image).to_bytes();
    let mut out = Vec::with_capacity(SUBMIT_V3_HDR + spill.len());
    out.extend_from_slice(&key.to_le_bytes());
    out.push(priority.as_u8());
    let deadline_us =
        deadline.map(|d| (d.as_micros() as u64).max(1)).unwrap_or(0);
    out.extend_from_slice(&deadline_us.to_le_bytes());
    out.extend_from_slice(&trace_id.to_le_bytes());
    out.push(if sampled { SUBMIT_FLAG_SAMPLED } else { 0 });
    out.extend_from_slice(&spill);
    out
}

/// Read just the shard key off a `Submit` payload — the router's
/// fast path: sharding must not pay for an image decode. The key sits
/// at offset 0 in both wire versions.
pub fn submit_key(payload: &[u8]) -> Result<u64, FrameError> {
    if payload.len() < 8 {
        return Err(FrameError::Malformed("submit payload shorter than key"));
    }
    Ok(u64::from_le_bytes(payload[..8].try_into().expect("8 bytes")))
}

/// Read the priority class off a `Submit` payload without decoding the
/// image — the router's admission check. v1 submits are `Normal`.
pub fn submit_priority(
    version: u16,
    payload: &[u8],
) -> Result<Priority, FrameError> {
    if version < 2 {
        submit_key(payload)?; // shape check only
        return Ok(Priority::Normal);
    }
    let hdr = if version >= 3 { SUBMIT_V3_HDR } else { SUBMIT_V2_HDR };
    if payload.len() < hdr {
        return Err(FrameError::Malformed("submit payload too short"));
    }
    Priority::from_u8(payload[8])
        .ok_or(FrameError::Malformed("submit priority byte out of range"))
}

/// Read the trace id + sampled flag off a `Submit` payload without
/// decoding the image — the router's trace fast path. v1/v2 submits
/// are untraced (`(0, false)`).
pub fn submit_trace(
    version: u16,
    payload: &[u8],
) -> Result<(u64, bool), FrameError> {
    if version < 3 {
        submit_priority(version, payload)?; // shape check only
        return Ok((0, false));
    }
    if payload.len() < SUBMIT_V3_HDR {
        return Err(FrameError::Malformed("v3 submit payload too short"));
    }
    let trace_id = u64::from_le_bytes(
        payload[SUBMIT_V2_HDR..SUBMIT_V2_HDR + 8]
            .try_into()
            .expect("8 bytes"),
    );
    let sampled =
        payload[SUBMIT_V3_HDR - 1] & SUBMIT_FLAG_SAMPLED != 0;
    Ok((trace_id, sampled))
}

/// Rewrite a v1/v2 `Submit` payload into v3 shape (insert the fields
/// the older version lacks, with their defaults) so everything past
/// the router speaks one format. v3 payloads pass through unchanged
/// after a shape check.
pub fn normalize_submit(
    version: u16,
    payload: &[u8],
) -> Result<Vec<u8>, FrameError> {
    if version >= 3 {
        submit_priority(version, payload)?;
        return Ok(payload.to_vec());
    }
    // Bring a v1 payload up to v2 shape first, then append-insert the
    // v3 trace fields (id 0, no flags) before the image.
    let v2 = if version >= 2 {
        submit_priority(version, payload)?;
        payload.to_vec()
    } else {
        if payload.len() < 8 {
            return Err(FrameError::Malformed(
                "submit payload shorter than key",
            ));
        }
        let mut out = Vec::with_capacity(payload.len() + 9);
        out.extend_from_slice(&payload[..8]);
        out.push(Priority::Normal.as_u8());
        out.extend_from_slice(&0u64.to_le_bytes());
        out.extend_from_slice(&payload[8..]);
        out
    };
    let mut out = Vec::with_capacity(v2.len() + 9);
    out.extend_from_slice(&v2[..SUBMIT_V2_HDR]);
    out.extend_from_slice(&0u64.to_le_bytes());
    out.push(0);
    out.extend_from_slice(&v2[SUBMIT_V2_HDR..]);
    Ok(out)
}

/// Decode a `Submit` payload for the frame's wire `version`. The
/// embedded `.zspill` goes through the strict `compress` parser, so a
/// corrupt or adversarial image section errors instead of panicking.
pub fn parse_submit(
    version: u16,
    payload: &[u8],
) -> Result<WireSubmit, FrameError> {
    if payload.len() < 8 {
        return Err(FrameError::Malformed("submit payload shorter than key"));
    }
    let key = u64::from_le_bytes(payload[..8].try_into().expect("8 bytes"));
    let (priority, deadline, trace_id, trace, image_bytes) = if version >= 2
    {
        if payload.len() < SUBMIT_V2_HDR {
            return Err(FrameError::Malformed("v2 submit payload too short"));
        }
        let priority = Priority::from_u8(payload[8]).ok_or(
            FrameError::Malformed("submit priority byte out of range"),
        )?;
        let deadline_us = u64::from_le_bytes(
            payload[9..SUBMIT_V2_HDR].try_into().expect("8 bytes"),
        );
        let deadline =
            (deadline_us > 0).then(|| Duration::from_micros(deadline_us));
        let (trace_id, trace, image_bytes) = if version >= 3 {
            if payload.len() < SUBMIT_V3_HDR {
                return Err(FrameError::Malformed(
                    "v3 submit payload too short",
                ));
            }
            let trace_id = u64::from_le_bytes(
                payload[SUBMIT_V2_HDR..SUBMIT_V2_HDR + 8]
                    .try_into()
                    .expect("8 bytes"),
            );
            let sampled =
                payload[SUBMIT_V3_HDR - 1] & SUBMIT_FLAG_SAMPLED != 0;
            (trace_id, sampled, &payload[SUBMIT_V3_HDR..])
        } else {
            (0, false, &payload[SUBMIT_V2_HDR..])
        };
        (priority, deadline, trace_id, trace, image_bytes)
    } else {
        (Priority::Normal, None, 0, false, &payload[8..])
    };
    let image = compress::decode_frame(image_bytes).map_err(|_| {
        FrameError::Malformed("submit image is not a valid .zspill")
    })?;
    Ok(WireSubmit { key, priority, deadline, trace_id, trace, image })
}

// ---------------------------------------------------------------------
// Overloaded payload: priority + queue depth + detail
// ---------------------------------------------------------------------

/// Decode an `Overloaded` payload into (shed priority, queue depth at
/// shed time, detail message). Strict: short payloads, bad priority
/// bytes, and non-UTF-8 detail all error.
pub fn parse_overloaded(
    payload: &[u8],
) -> Result<(Priority, u64, String), FrameError> {
    if payload.len() < 9 {
        return Err(FrameError::Malformed("overloaded payload too short"));
    }
    let priority = Priority::from_u8(payload[0]).ok_or(
        FrameError::Malformed("overloaded priority byte out of range"),
    )?;
    let queued =
        u64::from_le_bytes(payload[1..9].try_into().expect("8 bytes"));
    let detail = std::str::from_utf8(&payload[9..])
        .map_err(|_| FrameError::Malformed("overloaded detail not UTF-8"))?
        .to_string();
    Ok((priority, queued, detail))
}

// ---------------------------------------------------------------------
// Response payload
// ---------------------------------------------------------------------

/// The packed `Response` payload — everything
/// [`crate::coordinator::server::Response`] carries, minus the id
/// (frame header) and the reply channel.
#[derive(Debug, Clone, PartialEq)]
pub struct WireResponse {
    pub predicted: u32,
    pub dense_bytes: u64,
    pub stored_bytes: u64,
    pub index_bytes: u64,
    pub spill_frame_bytes: u64,
    /// Worker-side latency (enqueue -> response) in microseconds.
    pub latency_us: u64,
    pub logits: Vec<f32>,
}

impl WireResponse {
    /// Build from a coordinator response.
    pub fn from_response(
        r: &crate::coordinator::server::Response,
    ) -> WireResponse {
        WireResponse {
            predicted: r.predicted as u32,
            dense_bytes: r.dense_bytes,
            stored_bytes: r.stored_bytes,
            index_bytes: r.index_bytes,
            spill_frame_bytes: r.spill_frame_bytes,
            latency_us: r.latency.as_micros() as u64,
            logits: r.logits.clone(),
        }
    }

    /// Eq. 2–3 reduction for this response.
    pub fn reduction_pct(&self) -> f64 {
        crate::coordinator::metrics::reduction_pct_of(
            self.dense_bytes,
            self.stored_bytes,
            self.index_bytes,
        )
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(48 + 4 * self.logits.len());
        out.extend_from_slice(&self.predicted.to_le_bytes());
        out.extend_from_slice(&self.dense_bytes.to_le_bytes());
        out.extend_from_slice(&self.stored_bytes.to_le_bytes());
        out.extend_from_slice(&self.index_bytes.to_le_bytes());
        out.extend_from_slice(&self.spill_frame_bytes.to_le_bytes());
        out.extend_from_slice(&self.latency_us.to_le_bytes());
        out.extend_from_slice(&(self.logits.len() as u32).to_le_bytes());
        for &v in &self.logits {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Strict parse: the declared logit count must match the remaining
    /// bytes exactly.
    pub fn parse(payload: &[u8]) -> Result<WireResponse, FrameError> {
        let (resp, rest) = Self::parse_prefix(payload)?;
        if !rest.is_empty() {
            return Err(FrameError::Malformed(
                "response payload has trailing bytes",
            ));
        }
        Ok(resp)
    }

    /// Parse one response body off the front of `payload`, returning
    /// the remaining bytes — on wire v3, a sampled request's
    /// `TraceRecord` follows the logits ([`parse_response`]).
    pub fn parse_prefix(
        payload: &[u8],
    ) -> Result<(WireResponse, &[u8]), FrameError> {
        const FIXED: usize = 4 + 5 * 8 + 4;
        if payload.len() < FIXED {
            return Err(FrameError::Malformed("response payload too short"));
        }
        let u64_at = |off: usize| {
            u64::from_le_bytes(payload[off..off + 8].try_into().expect("8"))
        };
        let predicted =
            u32::from_le_bytes(payload[0..4].try_into().expect("4"));
        let n_logits =
            u32::from_le_bytes(payload[44..48].try_into().expect("4"))
                as usize;
        let rest = &payload[FIXED..];
        let logit_bytes = n_logits.checked_mul(4).ok_or(
            FrameError::Malformed("response logit count overflows"),
        )?;
        if rest.len() < logit_bytes {
            return Err(FrameError::Malformed(
                "response logit count disagrees with payload length",
            ));
        }
        let logits = rest[..logit_bytes]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let resp = WireResponse {
            predicted,
            dense_bytes: u64_at(4),
            stored_bytes: u64_at(12),
            index_bytes: u64_at(20),
            spill_frame_bytes: u64_at(28),
            latency_us: u64_at(36),
            logits,
        };
        Ok((resp, &rest[logit_bytes..]))
    }
}

/// Encode a `Response` payload for a requester speaking `version`:
/// the packed [`WireResponse`] and — wire v3, sampled requests only —
/// the request's [`TraceRecord`](crate::obs::TraceRecord) appended
/// after the logits. Requesters below v3 always get the bare body
/// (their strict parse rejects trailing bytes).
pub fn encode_response(
    version: u16,
    resp: &WireResponse,
    trace: Option<&crate::obs::TraceRecord>,
) -> Vec<u8> {
    let mut out = resp.encode();
    if version >= 3 {
        if let Some(rec) = trace {
            out.extend_from_slice(&rec.encode());
        }
    }
    out
}

/// Decode a `Response` payload for the frame's wire `version`,
/// returning the optional appended trace record. Below v3, trailing
/// bytes are an error (the pre-trace strict contract); on v3+, the
/// trailing bytes must be exactly one well-formed `TraceRecord`.
pub fn parse_response(
    version: u16,
    payload: &[u8],
) -> Result<(WireResponse, Option<crate::obs::TraceRecord>), FrameError> {
    let (resp, rest) = WireResponse::parse_prefix(payload)?;
    if rest.is_empty() {
        return Ok((resp, None));
    }
    if version < 3 {
        return Err(FrameError::Malformed(
            "response payload has trailing bytes",
        ));
    }
    let rec = crate::obs::TraceRecord::parse(rest)?;
    Ok((resp, Some(rec)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{forall, Config};

    fn sample_frame(rng: &mut Rng) -> Frame {
        let ty = [
            FrameType::Submit,
            FrameType::Response,
            FrameType::Heartbeat,
            FrameType::SpillShip,
            FrameType::Error,
            FrameType::MetricsReq,
            FrameType::MetricsResp,
            FrameType::Overloaded,
        ][rng.range(0, 7)];
        let n = rng.range(0, 96);
        let payload = (0..n).map(|_| rng.below(256) as u8).collect();
        Frame::new(ty, rng.next_u64(), payload)
    }

    #[test]
    fn roundtrips_through_parse_and_read_from() {
        forall(Config::cases(60), |rng| {
            let f = sample_frame(rng);
            let bytes = f.encode();
            assert_eq!(Frame::parse(&bytes).unwrap(), f);
            let mut cursor = std::io::Cursor::new(bytes.clone());
            assert_eq!(Frame::read_from(&mut cursor).unwrap(), f);
            // Two frames back to back stream cleanly.
            let g = sample_frame(rng);
            let mut two = f.encode();
            two.extend_from_slice(&g.encode());
            let mut cursor = std::io::Cursor::new(two);
            assert_eq!(Frame::read_from(&mut cursor).unwrap(), f);
            assert_eq!(Frame::read_from(&mut cursor).unwrap(), g);
        });
    }

    #[test]
    fn truncations_error_never_panic() {
        // Exhaustive prefix sweep on one frame through both parsers.
        let f = Frame::new(FrameType::Submit, 7, vec![1, 2, 3, 4, 5]);
        let bytes = f.encode();
        for cut in 0..bytes.len() {
            assert!(
                Frame::parse(&bytes[..cut]).is_err(),
                "prefix of {cut} bytes must not parse"
            );
            let mut cursor = std::io::Cursor::new(bytes[..cut].to_vec());
            assert!(
                Frame::read_from(&mut cursor).is_err(),
                "stream cut at {cut} bytes must error"
            );
        }
        // Random truncations of random frames.
        forall(Config::cases(40), |rng| {
            let bytes = sample_frame(rng).encode();
            let cut = rng.range(0, bytes.len() - 1);
            assert!(Frame::parse(&bytes[..cut]).is_err());
            let mut cursor = std::io::Cursor::new(bytes[..cut].to_vec());
            assert!(Frame::read_from(&mut cursor).is_err());
        });
    }

    #[test]
    fn single_bit_flips_are_always_detected() {
        forall(Config::cases(120), |rng| {
            let mut bytes = sample_frame(rng).encode();
            let pos = rng.range(0, bytes.len() - 1);
            let bit = rng.range(0, 7);
            bytes[pos] ^= 1 << bit;
            assert!(
                Frame::parse(&bytes).is_err(),
                "bit flip at byte {pos} bit {bit} went undetected"
            );
            let mut cursor = std::io::Cursor::new(bytes);
            assert!(Frame::read_from(&mut cursor).is_err());
        });
    }

    #[test]
    fn wrong_frame_type_errors() {
        let mut bytes =
            Frame::new(FrameType::Heartbeat, 1, Vec::new()).encode();
        bytes[6..8].copy_from_slice(&99u16.to_le_bytes());
        assert!(matches!(
            Frame::parse(&bytes),
            Err(FrameError::BadFrameType(99))
        ));
        // A valid-but-different type is caught by the checksum.
        let mut bytes =
            Frame::new(FrameType::Heartbeat, 1, Vec::new()).encode();
        bytes[6] = FrameType::Submit.as_u16() as u8;
        assert!(Frame::parse(&bytes).is_err());
    }

    #[test]
    fn oversized_length_prefix_errors_before_allocating() {
        let mut bytes = Frame::new(FrameType::Submit, 1, vec![0; 8]).encode();
        bytes[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            Frame::parse(&bytes),
            Err(FrameError::Oversized { .. })
        ));
        // Through the streaming path too: the header alone declares an
        // absurd payload; read_from must reject it without trying to
        // read (or allocate) those bytes.
        let mut cursor = std::io::Cursor::new(bytes[..HDR_LEN].to_vec());
        assert!(matches!(
            Frame::read_from(&mut cursor),
            Err(FrameError::Oversized { .. })
        ));
        // Just over the cap is rejected; the cap itself is a length
        // check, not a checksum failure.
        let mut bytes = Frame::new(FrameType::Submit, 1, vec![0; 8]).encode();
        bytes[20..28]
            .copy_from_slice(&((MAX_PAYLOAD as u64) + 1).to_le_bytes());
        let mut cursor = std::io::Cursor::new(bytes[..HDR_LEN].to_vec());
        assert!(matches!(
            Frame::read_from(&mut cursor),
            Err(FrameError::Oversized { .. })
        ));
    }

    #[test]
    fn foreign_magic_and_versions_error() {
        let good = Frame::new(FrameType::Submit, 3, vec![9; 4]).encode();
        let mut b = good.clone();
        b[0..4].copy_from_slice(b"ZSPL"); // a spill is not a cluster frame
        assert!(matches!(Frame::parse(&b), Err(FrameError::BadMagic(_))));
        let mut b = good.clone();
        b[4..6].copy_from_slice(&9u16.to_le_bytes());
        assert!(matches!(Frame::parse(&b), Err(FrameError::BadVersion(9))));
        assert!(Frame::parse(&[]).is_err());
        // Trailing bytes after a complete frame are an error for the
        // whole-buffer parser.
        let mut b = good.clone();
        b.push(0);
        assert!(matches!(
            Frame::parse(&b),
            Err(FrameError::SectionMismatch { .. })
        ));
    }

    #[test]
    fn clean_eof_is_distinguishable() {
        let mut cursor = std::io::Cursor::new(Vec::<u8>::new());
        let err = Frame::read_from(&mut cursor).unwrap_err();
        assert!(err.is_clean_eof(), "{err}");
        let err = Frame::parse(&[1, 2, 3]).unwrap_err();
        assert!(!err.is_clean_eof());
    }

    #[test]
    fn timeouts_are_distinguishable_from_corruption() {
        for kind in
            [std::io::ErrorKind::WouldBlock, std::io::ErrorKind::TimedOut]
        {
            let err = FrameError::Io(std::io::Error::new(kind, "slow"));
            assert!(err.is_timeout(), "{err}");
            assert!(!err.is_clean_eof());
        }
        assert!(!Frame::parse(&[1, 2, 3]).unwrap_err().is_timeout());
        let mut cursor = std::io::Cursor::new(Vec::<u8>::new());
        assert!(!Frame::read_from(&mut cursor).unwrap_err().is_timeout());
    }

    fn sample_image(rng: &mut Rng) -> Tensor {
        Tensor::from_vec(&[3, 4, 4], (0..48).map(|_| rng.normal()).collect())
    }

    #[test]
    fn submit_payload_roundtrips_and_rejects_corruption() {
        let mut rng = Rng::new(17);
        let img = sample_image(&mut rng);
        let deadline = Some(Duration::from_micros(2500));
        let payload =
            encode_submit(0xDEAD_BEEF, Priority::High, deadline, &img);
        let s = parse_submit(CLUSTER_VERSION, &payload).unwrap();
        assert_eq!(s.key, 0xDEAD_BEEF);
        assert_eq!(s.priority, Priority::High);
        assert_eq!(s.deadline, deadline);
        assert_eq!(s.image, img);
        // No deadline encodes as 0 and parses back as None.
        let p2 = encode_submit(1, Priority::Low, None, &img);
        let s2 = parse_submit(CLUSTER_VERSION, &p2).unwrap();
        assert_eq!(s2.deadline, None);
        assert_eq!(s2.priority, Priority::Low);
        // Fast-path field reads agree with the full parse.
        assert_eq!(submit_key(&payload).unwrap(), 0xDEAD_BEEF);
        assert_eq!(
            submit_priority(CLUSTER_VERSION, &payload).unwrap(),
            Priority::High
        );
        // Too short for the key / the v2 header.
        assert!(parse_submit(CLUSTER_VERSION, &payload[..4]).is_err());
        assert!(parse_submit(CLUSTER_VERSION, &payload[..12]).is_err());
        // A priority byte out of range errors, never panics.
        let mut bad = payload.clone();
        bad[8] = 9;
        assert!(parse_submit(CLUSTER_VERSION, &bad).is_err());
        assert!(submit_priority(CLUSTER_VERSION, &bad).is_err());
        // Corrupt embedded spill.
        let mut bad = payload.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        assert!(parse_submit(CLUSTER_VERSION, &bad).is_err());
        // Truncated embedded spill.
        assert!(
            parse_submit(CLUSTER_VERSION, &payload[..payload.len() - 3])
                .is_err()
        );
    }

    #[test]
    fn v1_submits_still_parse_and_normalize() {
        let mut rng = Rng::new(23);
        let img = sample_image(&mut rng);
        // Hand-build the v1 payload shape: key + dense spill, no
        // priority/deadline fields.
        let mut v1 = Vec::new();
        v1.extend_from_slice(&77u64.to_le_bytes());
        v1.extend_from_slice(&DenseCodec.encode(&img).to_bytes());
        let s = parse_submit(1, &v1).unwrap();
        assert_eq!(s.key, 77);
        assert_eq!(s.priority, Priority::Normal);
        assert_eq!(s.deadline, None);
        assert_eq!(s.image, img);
        assert_eq!(submit_priority(1, &v1).unwrap(), Priority::Normal);
        // Normalizing a v1 payload yields byte-identical v2 encoding.
        let normalized = normalize_submit(1, &v1).unwrap();
        assert_eq!(
            normalized,
            encode_submit(77, Priority::Normal, None, &img)
        );
        assert_eq!(
            parse_submit(CLUSTER_VERSION, &normalized).unwrap().image,
            img
        );
        // A v2 payload normalizes to itself.
        let v2 = encode_submit(5, Priority::High, None, &img);
        assert_eq!(normalize_submit(CLUSTER_VERSION, &v2).unwrap(), v2);
        // And a frame stamped version 1 round-trips through the codec.
        let f = Frame { version: 1, ..Frame::new(FrameType::Submit, 9, v1) };
        let parsed = Frame::parse(&f.encode()).unwrap();
        assert_eq!(parsed.version, 1);
        assert_eq!(parsed, f);
    }

    #[test]
    fn v2_submits_still_parse_and_normalize() {
        let mut rng = Rng::new(29);
        let img = sample_image(&mut rng);
        // Hand-build the v2 payload shape: key + priority + deadline +
        // dense spill, no trace fields.
        let mut v2 = Vec::new();
        v2.extend_from_slice(&88u64.to_le_bytes());
        v2.push(Priority::High.as_u8());
        v2.extend_from_slice(&1500u64.to_le_bytes());
        v2.extend_from_slice(&DenseCodec.encode(&img).to_bytes());
        let s = parse_submit(2, &v2).unwrap();
        assert_eq!(s.key, 88);
        assert_eq!(s.priority, Priority::High);
        assert_eq!(s.deadline, Some(Duration::from_micros(1500)));
        assert_eq!(s.trace_id, 0, "v2 submits are untraced");
        assert!(!s.trace);
        assert_eq!(s.image, img);
        assert_eq!(submit_priority(2, &v2).unwrap(), Priority::High);
        assert_eq!(submit_trace(2, &v2).unwrap(), (0, false));
        // Normalizing a v2 payload yields byte-identical v3 encoding.
        let normalized = normalize_submit(2, &v2).unwrap();
        assert_eq!(
            normalized,
            encode_submit_traced(
                88,
                Priority::High,
                Some(Duration::from_micros(1500)),
                0,
                false,
                &img,
            )
        );
        let s3 = parse_submit(CLUSTER_VERSION, &normalized).unwrap();
        assert_eq!(s3.image, img);
        assert_eq!(s3.deadline, s.deadline);
        // A frame stamped version 2 round-trips through the codec.
        let f = Frame { version: 2, ..Frame::new(FrameType::Submit, 4, v2) };
        let parsed = Frame::parse(&f.encode()).unwrap();
        assert_eq!(parsed.version, 2);
        assert_eq!(parsed, f);
    }

    #[test]
    fn traced_submits_roundtrip_and_fuzz_clean() {
        let mut rng = Rng::new(31);
        let img = sample_image(&mut rng);
        let payload = encode_submit_traced(
            9,
            Priority::Normal,
            None,
            0xFACE_FEED_0123_4567,
            true,
            &img,
        );
        let s = parse_submit(CLUSTER_VERSION, &payload).unwrap();
        assert_eq!(s.trace_id, 0xFACE_FEED_0123_4567);
        assert!(s.trace);
        assert_eq!(s.image, img);
        assert_eq!(
            submit_trace(CLUSTER_VERSION, &payload).unwrap(),
            (0xFACE_FEED_0123_4567, true)
        );
        // A nonzero id with the sampled bit clear propagates untraced.
        let quiet = encode_submit_traced(
            9,
            Priority::Normal,
            None,
            42,
            false,
            &img,
        );
        let s = parse_submit(CLUSTER_VERSION, &quiet).unwrap();
        assert_eq!((s.trace_id, s.trace), (42, false));
        // Reserved flag bits are ignored, not errors (both-direction
        // compatibility for future flags).
        let mut future = payload.clone();
        future[SUBMIT_V3_HDR - 1] |= 0x80;
        let s = parse_submit(CLUSTER_VERSION, &future).unwrap();
        assert!(s.trace);
        // A v3 payload normalizes to itself.
        assert_eq!(
            normalize_submit(CLUSTER_VERSION, &payload).unwrap(),
            payload
        );
        // Every truncation through the v3 header errors, never panics.
        for cut in 0..SUBMIT_V3_HDR {
            assert!(
                parse_submit(CLUSTER_VERSION, &payload[..cut]).is_err(),
                "cut {cut}"
            );
            assert!(
                submit_trace(CLUSTER_VERSION, &payload[..cut]).is_err()
            );
        }
        // Random bit flips anywhere in the payload error or change the
        // decoded fields — they never panic (the frame checksum is the
        // corruption gate; this pins the payload codec's safety).
        forall(Config::cases(60), |rng| {
            let mut bad = payload.clone();
            let pos = rng.range(0, bad.len() - 1);
            bad[pos] ^= 1 << rng.range(0, 7);
            let _ = parse_submit(CLUSTER_VERSION, &bad);
        });
    }

    #[test]
    fn responses_carry_a_trace_record_on_v3_only() {
        use crate::obs::TraceRecord;
        let r = WireResponse {
            predicted: 7,
            dense_bytes: 2000,
            stored_bytes: 900,
            index_bytes: 64,
            spill_frame_bytes: 964,
            latency_us: 420,
            logits: vec![1.0, -2.0],
        };
        let mut rec = TraceRecord::new(0xAB);
        rec.push("queue.wait", 100, 250, 0, 0);
        rec.push("serve.execute", 250, 400, 964, 4);
        // v3 + trace: the record rides behind the logits.
        let payload = encode_response(3, &r, Some(&rec));
        let (back, trace) = parse_response(3, &payload).unwrap();
        assert_eq!(back, r);
        assert_eq!(trace.unwrap(), rec);
        // v3 without a trace and v2 (trace requested but suppressed)
        // are the bare body — byte-identical to the legacy encoding.
        assert_eq!(encode_response(3, &r, None), r.encode());
        assert_eq!(encode_response(2, &r, Some(&rec)), r.encode());
        let (back, trace) = parse_response(2, &r.encode()).unwrap();
        assert_eq!(back, r);
        assert!(trace.is_none());
        // A v2 reader handed a trace-carrying payload errors cleanly
        // (this cannot happen on the wire — responders shape replies
        // per requester version — but the parser must not mis-read).
        assert!(parse_response(2, &payload).is_err());
        assert!(WireResponse::parse(&payload).is_err());
        // Truncating anywhere inside the appended record errors.
        for cut in r.encode().len() + 1..payload.len() {
            assert!(parse_response(3, &payload[..cut]).is_err(), "{cut}");
        }
        // Garbage behind the body errors on v3 too (the tail must be
        // exactly one record).
        let mut noisy = r.encode();
        noisy.extend_from_slice(&[9, 9, 9]);
        assert!(parse_response(3, &noisy).is_err());
    }

    #[test]
    fn overloaded_payload_roundtrips_strictly() {
        let f = Frame::overloaded(42, Priority::Low, 96, "shed: over cap");
        assert_eq!(f.ty, FrameType::Overloaded);
        assert_eq!(f.id, 42);
        let (p, queued, detail) = parse_overloaded(&f.payload).unwrap();
        assert_eq!(p, Priority::Low);
        assert_eq!(queued, 96);
        assert_eq!(detail, "shed: over cap");
        // An empty detail is legal.
        let g = Frame::overloaded(1, Priority::High, 0, "");
        assert_eq!(
            parse_overloaded(&g.payload).unwrap(),
            (Priority::High, 0, String::new())
        );
        // Short payloads and bad priority bytes error.
        for cut in 0..9 {
            assert!(parse_overloaded(&f.payload[..cut]).is_err());
        }
        let mut bad = f.payload.clone();
        bad[0] = 200;
        assert!(parse_overloaded(&bad).is_err());
        // Non-UTF-8 detail errors.
        let mut bad = f.payload.clone();
        bad.push(0xFF);
        bad.push(0xC0);
        assert!(parse_overloaded(&bad).is_err());
    }

    #[test]
    fn response_payload_roundtrips_strictly() {
        let r = WireResponse {
            predicted: 3,
            dense_bytes: 1000,
            stored_bytes: 400,
            index_bytes: 50,
            spill_frame_bytes: 777,
            latency_us: 1234,
            logits: vec![0.25, -1.5, 3.0, 0.0],
        };
        let payload = r.encode();
        assert_eq!(WireResponse::parse(&payload).unwrap(), r);
        assert!((r.reduction_pct() - 55.0).abs() < 1e-9);
        // Every truncation errors.
        for cut in 0..payload.len() {
            assert!(
                WireResponse::parse(&payload[..cut]).is_err(),
                "cut {cut}"
            );
        }
        // A lying logit count errors.
        let mut bad = payload.clone();
        bad[44..48].copy_from_slice(&999u32.to_le_bytes());
        assert!(WireResponse::parse(&bad).is_err());
        // Empty logits are legal (an error-shaped response).
        let e = WireResponse { logits: Vec::new(), ..r };
        assert_eq!(WireResponse::parse(&e.encode()).unwrap(), e);
    }
}
