//! Cluster client: one pipelined TCP connection to a router (or
//! directly to a worker — the wire protocol is the same).
//!
//! Mirrors the in-process [`Server::submit`](crate::coordinator::Server)
//! API: [`ClusterClient::submit`] returns a channel the response
//! arrives on, so callers pipeline as many requests as they like over
//! one connection. Wall-clock latency is stamped by the reader thread
//! the moment each response frame arrives (not when the caller gets
//! around to `recv()`), which is what `zebra loadgen`'s percentiles
//! are built from.

use std::collections::HashMap;
use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::metrics::ClusterStats;
use super::wire::{self, Frame, FrameType, WireResponse};
use crate::coordinator::Priority;
use crate::obs::{ObsReport, TraceRecord};
use crate::tensor::Tensor;

/// How long [`ClusterClient::stats`] waits for the router's answer.
const STATS_WAIT: Duration = Duration::from_secs(5);

/// Default connect + read timeout ([`ClusterClient::connect`]);
/// override (or disable with `None`) via
/// [`ClusterClient::connect_with`] / `--io-timeout-ms`.
const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// One answered request: the worker's response plus the client-side
/// wall latency (submit -> response frame arrival).
#[derive(Debug, Clone)]
pub struct ClusterResponse {
    pub response: WireResponse,
    pub wall: Duration,
    /// The request's trace record, present when the submit carried a
    /// sampled trace id and the serving path was v3 end to end. Spans
    /// from every hop (router dispatch, worker ingest, queue wait,
    /// batch assembly, execution, per-layer prune/encode) — the edge
    /// appends its own `client.rtt` on top.
    pub trace: Option<TraceRecord>,
}

/// Why a submit did not produce a response. `Overloaded` is the
/// admission-control outcome (the cluster explicitly shed the request
/// — retry later, or raise its class); `Failed` is a fault (worker
/// error, lost connection, unparseable payload).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClusterError {
    Overloaded { priority: Priority, queued: u64, detail: String },
    Failed(String),
}

impl ClusterError {
    /// True when the request was shed by admission control (as opposed
    /// to faulting).
    pub fn is_overloaded(&self) -> bool {
        matches!(self, ClusterError::Overloaded { .. })
    }
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::Overloaded { priority, queued, detail } => write!(
                f,
                "overloaded: {} class shed ({queued} queued): {detail}",
                priority.name()
            ),
            ClusterError::Failed(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ClusterError {}

/// What a submit's reply channel delivers: the response, or the
/// terminal [`ClusterError`].
pub type Delivery = Result<ClusterResponse, ClusterError>;

struct PendingEntry {
    tx: Sender<Delivery>,
    sent_at: Instant,
}

type Waiters = Arc<Mutex<HashMap<u64, PendingEntry>>>;
type StatsWaiters =
    Arc<Mutex<HashMap<u64, Sender<Result<ObsReport, String>>>>>;

/// A connected cluster client.
pub struct ClusterClient {
    write: Mutex<TcpStream>,
    pending: Waiters,
    pending_stats: StatsWaiters,
    next_id: AtomicU64,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl ClusterClient {
    /// Connect with the default 30 s connect/read timeout.
    pub fn connect(addr: &str) -> Result<ClusterClient> {
        Self::connect_with(addr, Some(DEFAULT_IO_TIMEOUT))
    }

    /// Connect with an explicit socket timeout (`None` = unbounded,
    /// the pre-PR-10 behaviour; `--io-timeout-ms 0` maps here). The
    /// timeout bounds both the dial and every read the reader thread
    /// makes — a black-holed router cannot wedge the client forever.
    pub fn connect_with(
        addr: &str,
        io_timeout: Option<Duration>,
    ) -> Result<ClusterClient> {
        // Map the two expected unreachable-node outcomes to messages
        // that say what to check, instead of surfacing the raw OS
        // error string (`zebra obs` / `zebra top` show this verbatim
        // to the operator).
        let stream = dial(addr, io_timeout).map_err(|e| {
            use std::io::ErrorKind;
            match e.kind() {
                ErrorKind::ConnectionRefused => anyhow!(
                    "nothing is listening at {addr} (connection refused) — \
                     is the router/worker running, and is the address the \
                     one it printed at startup?"
                ),
                ErrorKind::TimedOut => anyhow!(
                    "connecting to {addr} timed out — host unreachable or \
                     blocked by a firewall"
                ),
                _ => anyhow!(e)
                    .context(format!("cluster client cannot reach {addr}")),
            }
        })?;
        let _ = stream.set_nodelay(true);
        let rd = stream.try_clone().context("clone client stream")?;
        let _ = rd.set_read_timeout(io_timeout);
        let pending: Waiters = Arc::new(Mutex::new(HashMap::new()));
        let pending_stats: StatsWaiters =
            Arc::new(Mutex::new(HashMap::new()));
        let reader = {
            let pending = pending.clone();
            let pending_stats = pending_stats.clone();
            std::thread::spawn(move || {
                reader_loop(rd, pending, pending_stats)
            })
        };
        Ok(ClusterClient {
            write: Mutex::new(stream),
            pending,
            pending_stats,
            next_id: AtomicU64::new(0),
            reader: Some(reader),
        })
    }

    /// Submit one `(3, H, W)` image at `Normal` priority with no
    /// deadline; the shard key defaults to the request id (spreads
    /// keys uniformly in hash mode).
    pub fn submit(&self, image: &Tensor) -> Result<Receiver<Delivery>> {
        self.submit_request(image, None, Priority::Normal, None)
    }

    /// Submit with an explicit shard key (consistent-hash affinity:
    /// equal keys land on the same live worker).
    pub fn submit_keyed(
        &self,
        image: &Tensor,
        key: u64,
    ) -> Result<Receiver<Delivery>> {
        self.submit_request(image, Some(key), Priority::Normal, None)
    }

    /// The full submission surface — the wire-side mirror of the
    /// coordinator's `SubmitRequest`: shard key (defaults to the
    /// request id), priority class, and optional completion deadline.
    pub fn submit_request(
        &self,
        image: &Tensor,
        key: Option<u64>,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Delivery>> {
        self.submit_traced(image, key, priority, deadline, 0, false)
    }

    /// [`ClusterClient::submit_request`] plus the edge-assigned trace
    /// identity: the `trace_id` rides the v3 submit to every hop, and
    /// `sampled` asks the serving path to assemble and return the
    /// request's [`TraceRecord`] with the response.
    pub fn submit_traced(
        &self,
        image: &Tensor,
        key: Option<u64>,
        priority: Priority,
        deadline: Option<Duration>,
        trace_id: u64,
        sampled: bool,
    ) -> Result<Receiver<Delivery>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let key = key.unwrap_or(id);
        let (tx, rx) = channel();
        self.pending
            .lock()
            .unwrap()
            .insert(id, PendingEntry { tx, sent_at: Instant::now() });
        let bytes = Frame::new(
            FrameType::Submit,
            id,
            wire::encode_submit_traced(
                key, priority, deadline, trace_id, sampled, image,
            ),
        )
        .encode();
        if let Err(e) = self.write.lock().unwrap().write_all(&bytes) {
            self.pending.lock().unwrap().remove(&id);
            return Err(anyhow!("cluster submit failed: {e}"));
        }
        Ok(rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn classify(&self, image: &Tensor) -> Result<ClusterResponse> {
        let rx = self.submit(image)?;
        rx.recv()
            .context("cluster connection dropped the request")?
            .map_err(|e| anyhow!("cluster request failed: {e}"))
    }

    /// Fetch cluster-wide stats from the router.
    pub fn stats(&self) -> Result<ClusterStats> {
        Ok(self.obs_report()?.stats)
    }

    /// Fetch the unified observability report (stats + merged
    /// telemetry stages) — what `zebra obs` and loadgen's `--scrape-ms`
    /// poll. Against a v1/v2 node the telemetry section is empty.
    pub fn obs_report(&self) -> Result<ObsReport> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.pending_stats.lock().unwrap().insert(id, tx);
        let bytes =
            Frame::new(FrameType::MetricsReq, id, Vec::new()).encode();
        if let Err(e) = self.write.lock().unwrap().write_all(&bytes) {
            self.pending_stats.lock().unwrap().remove(&id);
            return Err(anyhow!("cluster stats request failed: {e}"));
        }
        rx.recv_timeout(STATS_WAIT)
            .context("router did not answer the stats request")?
            .map_err(|msg| anyhow!("cluster stats failed: {msg}"))
    }

    /// Close the connection; in-flight submits deliver an error.
    pub fn shutdown(mut self) {
        self.close();
        if let Some(h) = self.reader.take() {
            h.join().ok();
        }
    }

    fn close(&self) {
        let _ = self
            .write
            .lock()
            .unwrap()
            .shutdown(std::net::Shutdown::Both);
    }
}

impl Drop for ClusterClient {
    fn drop(&mut self) {
        self.close();
    }
}

/// `TcpStream::connect` with an optional bound, so an unreachable
/// address fails in `io_timeout` instead of the OS default (minutes).
fn dial(addr: &str, timeout: Option<Duration>) -> std::io::Result<TcpStream> {
    match timeout {
        Some(t) => {
            use std::net::ToSocketAddrs;
            let sa = addr.to_socket_addrs()?.next().ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::NotFound,
                    "address resolves to nothing",
                )
            })?;
            TcpStream::connect_timeout(&sa, t)
        }
        None => TcpStream::connect(addr),
    }
}

fn reader_loop(
    mut stream: TcpStream,
    pending: Waiters,
    pending_stats: StatsWaiters,
) {
    loop {
        let frame = match Frame::read_from(&mut stream) {
            Ok(f) => f,
            // A timeout between frames is just an idle connection
            // (the client may legitimately sit quiet for minutes);
            // every other error tears the connection down.
            Err(e) if e.is_timeout() => continue,
            Err(_) => break,
        };
        match frame.ty {
            FrameType::Response => {
                let entry = pending.lock().unwrap().remove(&frame.id);
                if let Some(e) = entry {
                    let wall = e.sent_at.elapsed();
                    let delivery =
                        wire::parse_response(frame.version, &frame.payload)
                            .map(|(response, trace)| ClusterResponse {
                                response,
                                wall,
                                trace,
                            })
                            .map_err(|err| {
                                ClusterError::Failed(err.to_string())
                            });
                    let _ = e.tx.send(delivery);
                }
            }
            FrameType::Error => {
                let msg = String::from_utf8_lossy(&frame.payload)
                    .into_owned();
                let entry = pending.lock().unwrap().remove(&frame.id);
                if let Some(e) = entry {
                    let _ = e.tx.send(Err(ClusterError::Failed(msg)));
                } else if let Some(tx) =
                    pending_stats.lock().unwrap().remove(&frame.id)
                {
                    let _ = tx.send(Err(msg));
                }
            }
            FrameType::Overloaded => {
                let entry = pending.lock().unwrap().remove(&frame.id);
                if let Some(e) = entry {
                    let err = match wire::parse_overloaded(&frame.payload) {
                        Ok((priority, queued, detail)) => {
                            ClusterError::Overloaded {
                                priority,
                                queued,
                                detail,
                            }
                        }
                        Err(bad) => ClusterError::Failed(format!(
                            "malformed overloaded frame: {bad}"
                        )),
                    };
                    let _ = e.tx.send(Err(err));
                }
            }
            FrameType::MetricsResp => {
                let waiter =
                    pending_stats.lock().unwrap().remove(&frame.id);
                if let Some(tx) = waiter {
                    let _ = tx.send(
                        ObsReport::parse_wire(frame.version, &frame.payload)
                            .map_err(|e| e.to_string()),
                    );
                }
            }
            _ => {}
        }
    }
    // Connection is gone: everything still pending fails loudly.
    for (_, e) in pending.lock().unwrap().drain() {
        let _ = e.tx.send(Err(ClusterError::Failed(
            "connection to the cluster lost".into(),
        )));
    }
    for (_, tx) in pending_stats.lock().unwrap().drain() {
        let _ = tx.send(Err("connection to the cluster lost".into()));
    }
}
