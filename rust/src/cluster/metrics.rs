//! Cluster-wide metrics: a wire-portable snapshot of one node's
//! [`coordinator::Metrics`](crate::coordinator::Metrics) plus the
//! router's aggregation over every worker.
//!
//! The snapshot carries the exact counters the single-node serving
//! pipeline already maintains — requests/responses/batches, the
//! Eq. 2–3 byte accounting, shipped `.zspill` bytes — and the full
//! power-of-two latency histogram, so cluster-level percentiles are
//! computed from *merged bucket counts*, not averaged per-node
//! percentiles (averaging percentiles is statistically meaningless).
//!
//! Encoding is self-describing the same way `.zspill` is: counter and
//! bucket counts are declared up front and validated strictly against
//! the payload length, so a malformed `MetricsResp` errors instead of
//! panicking.

use std::sync::atomic::Ordering;

use crate::cluster::wire::FrameError;
use crate::coordinator::metrics::reduction_pct_of;
use crate::coordinator::{percentile_from_buckets, Metrics};

/// Counter order on the wire (stable; append-only by protocol rule —
/// `exec_threads` was appended as counter 9 by the block-sparse
/// execution-engine PR; the continuous-batching PR appended the
/// admission-control set: `shed_low`/`shed_normal`/`shed_high` (10–12),
/// `deadline_miss` (13), `queue_depth` (14), `failed` (15)).
const COUNTERS: usize = 16;

/// Minimum counters a snapshot must carry (the original set). Parsing
/// accepts anything in `COUNTERS_V1..`, defaulting absent appended
/// counters to 0 and ignoring unknown future ones — so from this
/// build on, appends are compatible in both directions. Peers built
/// BEFORE this tolerance landed still parse strictly (exactly 9), so
/// in a mixed cluster spanning that boundary, readers (routers /
/// loadgen) must upgrade before emitters (workers); see
/// rust/docs/cluster.md.
const COUNTERS_V1: usize = 9;

/// One node's serving metrics, frozen for transport and aggregation.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub padded_slots: u64,
    pub dense_bytes: u64,
    pub stored_bytes: u64,
    pub index_bytes: u64,
    pub shipped_spill_bytes: u64,
    /// Compute worker threads per execution on this node (a gauge;
    /// merged snapshots sum it, giving total cluster compute threads).
    pub exec_threads: u64,
    /// Requests shed by admission control, per priority class
    /// (shed-lowest-first; every shed was an explicit refusal to its
    /// caller, never a silent drop).
    pub shed_low: u64,
    pub shed_normal: u64,
    pub shed_high: u64,
    /// Served requests whose explicit deadline had already passed at
    /// flush time.
    pub deadline_miss: u64,
    /// Queue depth at snapshot time (a gauge; merged snapshots sum it,
    /// giving total cluster queue occupancy).
    pub queue_depth: u64,
    /// Admitted requests whose execution failed. Per node,
    /// `requests == responses + shed_total + failed` up to in-queue
    /// work — the no-gaps accounting the flood test pins.
    pub failed: u64,
    /// Latency histogram (bucket `i` covers up to `2^i` us).
    pub latency_buckets: Vec<u64>,
}

impl MetricsSnapshot {
    /// Freeze a live [`Metrics`].
    pub fn from_metrics(m: &Metrics) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: m.requests.load(Ordering::Relaxed),
            responses: m.responses.load(Ordering::Relaxed),
            batches: m.batches.load(Ordering::Relaxed),
            batched_items: m.batched_items.load(Ordering::Relaxed),
            padded_slots: m.padded_slots.load(Ordering::Relaxed),
            dense_bytes: m.dense_bytes.load(Ordering::Relaxed),
            stored_bytes: m.stored_bytes.load(Ordering::Relaxed),
            index_bytes: m.index_bytes.load(Ordering::Relaxed),
            shipped_spill_bytes: m.shipped_spill_bytes.load(Ordering::Relaxed),
            exec_threads: m.exec_threads.load(Ordering::Relaxed),
            shed_low: m.shed_low.load(Ordering::Relaxed),
            shed_normal: m.shed_normal.load(Ordering::Relaxed),
            shed_high: m.shed_high.load(Ordering::Relaxed),
            deadline_miss: m.deadline_miss.load(Ordering::Relaxed),
            queue_depth: m.queue_depth.load(Ordering::Relaxed),
            failed: m.failed.load(Ordering::Relaxed),
            latency_buckets: m.latency_bucket_counts().to_vec(),
        }
    }

    /// Total sheds across all priority classes.
    pub fn shed_total(&self) -> u64 {
        self.shed_low + self.shed_normal + self.shed_high
    }

    fn counters(&self) -> [u64; COUNTERS] {
        [
            self.requests,
            self.responses,
            self.batches,
            self.batched_items,
            self.padded_slots,
            self.dense_bytes,
            self.stored_bytes,
            self.index_bytes,
            self.shipped_spill_bytes,
            self.exec_threads,
            self.shed_low,
            self.shed_normal,
            self.shed_high,
            self.deadline_miss,
            self.queue_depth,
            self.failed,
        ]
    }

    /// Add another node's snapshot into this one (counter sums +
    /// bucket-wise histogram merge).
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        self.requests += other.requests;
        self.responses += other.responses;
        self.batches += other.batches;
        self.batched_items += other.batched_items;
        self.padded_slots += other.padded_slots;
        self.dense_bytes += other.dense_bytes;
        self.stored_bytes += other.stored_bytes;
        self.index_bytes += other.index_bytes;
        self.shipped_spill_bytes += other.shipped_spill_bytes;
        self.exec_threads += other.exec_threads;
        self.shed_low += other.shed_low;
        self.shed_normal += other.shed_normal;
        self.shed_high += other.shed_high;
        self.deadline_miss += other.deadline_miss;
        self.queue_depth += other.queue_depth;
        self.failed += other.failed;
        if self.latency_buckets.len() < other.latency_buckets.len() {
            self.latency_buckets.resize(other.latency_buckets.len(), 0);
        }
        for (a, b) in
            self.latency_buckets.iter_mut().zip(&other.latency_buckets)
        {
            *a += *b;
        }
    }

    /// Latency percentile over the (possibly merged) histogram, in us.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        percentile_from_buckets(&self.latency_buckets, p)
    }

    /// Eq. 2–3 bandwidth reduction across everything this snapshot
    /// covers.
    pub fn reduction_pct(&self) -> f64 {
        reduction_pct_of(self.dense_bytes, self.stored_bytes, self.index_bytes)
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batched_items as f64 / self.batches as f64
    }

    /// Wire encoding: `[n_counters: u16][n_buckets: u16]` then the
    /// values, all u64 LE.
    pub fn encode(&self) -> Vec<u8> {
        let counters = self.counters();
        let mut out = Vec::with_capacity(
            4 + 8 * (counters.len() + self.latency_buckets.len()),
        );
        out.extend_from_slice(&(counters.len() as u16).to_le_bytes());
        out.extend_from_slice(
            &(self.latency_buckets.len() as u16).to_le_bytes(),
        );
        for v in counters {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &self.latency_buckets {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Strict parse of [`MetricsSnapshot::encode`] output.
    pub fn parse(payload: &[u8]) -> Result<MetricsSnapshot, FrameError> {
        let (snap, rest) = Self::parse_prefix(payload)?;
        if !rest.is_empty() {
            return Err(FrameError::Malformed(
                "metrics snapshot has trailing bytes",
            ));
        }
        Ok(snap)
    }

    /// Parse one snapshot off the front of `payload`, returning the
    /// remaining bytes — wire v3 `MetricsResp` payloads append a
    /// telemetry block after the snapshot
    /// (`obs::export::parse_telemetry_prefix` consumes the rest).
    pub fn parse_prefix(
        payload: &[u8],
    ) -> Result<(MetricsSnapshot, &[u8]), FrameError> {
        let (vals, rest) = parse_u64_block(payload)?;
        Ok((Self::from_block(&vals)?, rest))
    }

    /// Rebuild from a decoded `[counters..][buckets..]` block.
    /// Append-only tolerance: a pre-`exec_threads` peer sends
    /// [`COUNTERS_V1`] counters (missing tail defaults to 0), a newer
    /// one may send more than [`COUNTERS`] (extras ignored).
    fn from_block(vals: &U64Block) -> Result<MetricsSnapshot, FrameError> {
        if vals.counters.len() < COUNTERS_V1 {
            return Err(FrameError::Malformed(
                "metrics snapshot counter count mismatch",
            ));
        }
        let c = |i: usize| vals.counters.get(i).copied().unwrap_or(0);
        Ok(MetricsSnapshot {
            requests: c(0),
            responses: c(1),
            batches: c(2),
            batched_items: c(3),
            padded_slots: c(4),
            dense_bytes: c(5),
            stored_bytes: c(6),
            index_bytes: c(7),
            shipped_spill_bytes: c(8),
            exec_threads: c(9),
            shed_low: c(10),
            shed_normal: c(11),
            shed_high: c(12),
            deadline_miss: c(13),
            queue_depth: c(14),
            failed: c(15),
            latency_buckets: vals.buckets.clone(),
        })
    }
}

/// Decoded `[n_counters][n_buckets][values...]` block + what follows.
struct U64Block {
    counters: Vec<u64>,
    buckets: Vec<u64>,
}

/// Parse one counted u64 block off the front of `payload`; returns the
/// block and the remaining bytes. Declared counts are bounded (u16)
/// and validated against the available bytes before any slicing.
fn parse_u64_block(payload: &[u8]) -> Result<(U64Block, &[u8]), FrameError> {
    if payload.len() < 4 {
        return Err(FrameError::Malformed("metrics block too short"));
    }
    let n_counters =
        u16::from_le_bytes([payload[0], payload[1]]) as usize;
    let n_buckets = u16::from_le_bytes([payload[2], payload[3]]) as usize;
    // Bucket index i maps to an upper bound of 2^i us; anything past
    // 63 buckets cannot be a real histogram from any protocol version
    // and would overflow the percentile shift downstream.
    if n_counters > 64 || n_buckets > 64 {
        return Err(FrameError::Malformed(
            "metrics block declares an absurd counter/bucket count",
        ));
    }
    let need = 4 + 8 * (n_counters + n_buckets);
    if payload.len() < need {
        return Err(FrameError::Malformed(
            "metrics block shorter than its declared counts",
        ));
    }
    let mut vals = payload[4..need].chunks_exact(8).map(|c| {
        u64::from_le_bytes(c.try_into().expect("8 bytes"))
    });
    let counters: Vec<u64> = vals.by_ref().take(n_counters).collect();
    let buckets: Vec<u64> = vals.collect();
    Ok((U64Block { counters, buckets }, &payload[need..]))
}

/// Router-level counters + the cluster-wide aggregate — the
/// `MetricsResp` payload a router returns to clients (`zebra loadgen`
/// prints this).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ClusterStats {
    /// Sum of every reachable worker's snapshot.
    pub aggregate: MetricsSnapshot,
    pub workers_total: u64,
    pub workers_alive: u64,
    /// Submits the router accepted and dispatched.
    pub routed: u64,
    /// Re-dispatches after a worker failure.
    pub retries: u64,
    /// Total submits refused terminally (sheds + faults). The finer
    /// split below satisfies `shed_low + shed_normal + shed_high +
    /// failed == rejected`, and per router
    /// `requests == responses + rejected` up to in-flight work.
    pub rejected: u64,
    /// Router-side sheds per priority class (admission caps hit on
    /// every candidate worker, or workers shed and retries exhausted).
    pub shed_low: u64,
    pub shed_normal: u64,
    pub shed_high: u64,
    /// Router-side terminal faults (every attempt errored).
    pub failed: u64,
    /// `SpillShip` frames (and their `.zspill` payload bytes) received
    /// from workers. `spill_bytes_in` matching the aggregate's
    /// `shipped_spill_bytes` is the cluster-level Eq. 2 cross-check.
    pub spill_frames_in: u64,
    pub spill_bytes_in: u64,
    /// Router-side latency histogram (dispatch -> response).
    pub router_latency_buckets: Vec<u64>,
}

impl ClusterStats {
    pub fn router_percentile_us(&self, p: f64) -> u64 {
        percentile_from_buckets(&self.router_latency_buckets, p)
    }

    /// Router-side sheds across all priority classes.
    pub fn shed_total(&self) -> u64 {
        self.shed_low + self.shed_normal + self.shed_high
    }

    /// One-line summary for CLIs.
    pub fn summary(&self) -> String {
        format!(
            "workers {}/{} alive | routed={} retries={} rejected={} \
             shed={}/{}/{} failed={} | \
             cluster: responses={} exec_threads={} mean_batch={:.2} \
             p50={}us p95={}us p99={}us bw_reduction={:.1}% | spills: \
             shipped={}B received={}B ({} frames)",
            self.workers_alive,
            self.workers_total,
            self.routed,
            self.retries,
            self.rejected,
            self.shed_low,
            self.shed_normal,
            self.shed_high,
            self.failed,
            self.aggregate.responses,
            self.aggregate.exec_threads,
            self.aggregate.mean_batch(),
            self.aggregate.latency_percentile_us(0.5),
            self.aggregate.latency_percentile_us(0.95),
            self.aggregate.latency_percentile_us(0.99),
            self.aggregate.reduction_pct(),
            self.aggregate.shipped_spill_bytes,
            self.spill_bytes_in,
            self.spill_frames_in,
        )
    }

    /// Wire encoding: the aggregate snapshot block, then a second
    /// counted block of router counters + router latency buckets.
    /// Router counters follow the same append-only rule as the
    /// snapshot's: the shed/failed split (7–10) was appended by the
    /// continuous-batching PR.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = self.aggregate.encode();
        let counters = [
            self.workers_total,
            self.workers_alive,
            self.routed,
            self.retries,
            self.rejected,
            self.spill_frames_in,
            self.spill_bytes_in,
            self.shed_low,
            self.shed_normal,
            self.shed_high,
            self.failed,
        ];
        out.extend_from_slice(&(counters.len() as u16).to_le_bytes());
        out.extend_from_slice(
            &(self.router_latency_buckets.len() as u16).to_le_bytes(),
        );
        for v in counters {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &self.router_latency_buckets {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Strict parse of [`ClusterStats::encode`] output.
    pub fn parse(payload: &[u8]) -> Result<ClusterStats, FrameError> {
        let (stats, tail) = Self::parse_prefix(payload)?;
        if !tail.is_empty() {
            return Err(FrameError::Malformed(
                "cluster stats have trailing bytes",
            ));
        }
        Ok(stats)
    }

    /// Parse cluster stats off the front of `payload`, returning the
    /// remaining bytes (the wire v3 telemetry block, if any).
    pub fn parse_prefix(
        payload: &[u8],
    ) -> Result<(ClusterStats, &[u8]), FrameError> {
        let (agg, rest) = parse_u64_block(payload)?;
        let aggregate = MetricsSnapshot::from_block(&agg)?;
        let (router, tail) = parse_u64_block(rest)?;
        if router.counters.len() < 7 {
            return Err(FrameError::Malformed(
                "cluster stats router counter count mismatch",
            ));
        }
        let c = |i: usize| router.counters.get(i).copied().unwrap_or(0);
        let stats = ClusterStats {
            aggregate,
            workers_total: c(0),
            workers_alive: c(1),
            routed: c(2),
            retries: c(3),
            rejected: c(4),
            spill_frames_in: c(5),
            spill_bytes_in: c(6),
            shed_low: c(7),
            shed_normal: c(8),
            shed_high: c(9),
            failed: c(10),
            router_latency_buckets: router.buckets.clone(),
        };
        Ok((stats, tail))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::LATENCY_BUCKETS;

    fn snap(scale: u64) -> MetricsSnapshot {
        let mut buckets = vec![0u64; LATENCY_BUCKETS];
        buckets[7] = 10 * scale; // ~128 us
        buckets[17] = scale; // ~131 ms
        MetricsSnapshot {
            requests: 100 * scale,
            responses: 99 * scale,
            batches: 25 * scale,
            batched_items: 99 * scale,
            padded_slots: scale,
            dense_bytes: 1000 * scale,
            stored_bytes: 400 * scale,
            index_bytes: 100 * scale,
            shipped_spill_bytes: 555 * scale,
            exec_threads: 2 * scale,
            shed_low: 7 * scale,
            shed_normal: 3 * scale,
            shed_high: scale,
            deadline_miss: 2 * scale,
            queue_depth: 4 * scale,
            failed: scale,
            latency_buckets: buckets,
        }
    }

    #[test]
    fn snapshot_roundtrips_on_the_wire() {
        let s = snap(3);
        let back = MetricsSnapshot::parse(&s.encode()).unwrap();
        assert_eq!(back, s);
        // Truncations and trailing garbage error.
        let bytes = s.encode();
        for cut in 0..bytes.len() {
            assert!(MetricsSnapshot::parse(&bytes[..cut]).is_err());
        }
        let mut noisy = bytes.clone();
        noisy.push(0);
        assert!(MetricsSnapshot::parse(&noisy).is_err());
    }

    #[test]
    fn legacy_nine_counter_snapshots_still_parse() {
        // A pre-exec_threads peer (9 counters): parses with the
        // appended gauge defaulting to 0. Fewer than the original 9
        // counters is malformed.
        let mut legacy = Vec::new();
        legacy.extend_from_slice(&9u16.to_le_bytes());
        legacy.extend_from_slice(&0u16.to_le_bytes());
        for v in 1u64..=9 {
            legacy.extend_from_slice(&v.to_le_bytes());
        }
        let s = MetricsSnapshot::parse(&legacy).unwrap();
        assert_eq!(s.requests, 1);
        assert_eq!(s.shipped_spill_bytes, 9);
        assert_eq!(s.exec_threads, 0, "appended counter defaults to 0");
        assert_eq!(s.shed_total(), 0, "appended shed counters default to 0");
        assert_eq!(s.failed, 0);
        // A future peer with an extra appended counter also parses.
        let mut future = Vec::new();
        future.extend_from_slice(&11u16.to_le_bytes());
        future.extend_from_slice(&0u16.to_le_bytes());
        for v in 1u64..=11 {
            future.extend_from_slice(&v.to_le_bytes());
        }
        let s = MetricsSnapshot::parse(&future).unwrap();
        assert_eq!(s.exec_threads, 10);
        // 8 counters is genuinely malformed.
        let mut short = Vec::new();
        short.extend_from_slice(&8u16.to_le_bytes());
        short.extend_from_slice(&0u16.to_le_bytes());
        for v in 1u64..=8 {
            short.extend_from_slice(&v.to_le_bytes());
        }
        assert!(MetricsSnapshot::parse(&short).is_err());
    }

    #[test]
    fn absurd_bucket_counts_are_rejected() {
        // A well-framed snapshot claiming 65 buckets would map bucket
        // 64 to 2^64 us — reject it outright (shift-overflow guard).
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&10u16.to_le_bytes());
        bytes.extend_from_slice(&65u16.to_le_bytes());
        for _ in 0..(10 + 65) {
            bytes.extend_from_slice(&1u64.to_le_bytes());
        }
        assert!(MetricsSnapshot::parse(&bytes).is_err());
        // 64 buckets (the cap itself) still parses.
        let mut ok = Vec::new();
        ok.extend_from_slice(&10u16.to_le_bytes());
        ok.extend_from_slice(&64u16.to_le_bytes());
        for _ in 0..(10 + 64) {
            ok.extend_from_slice(&1u64.to_le_bytes());
        }
        let s = MetricsSnapshot::parse(&ok).unwrap();
        // And its percentiles stay shift-safe at the top bucket.
        assert!(s.latency_percentile_us(0.99) > 0);
    }

    #[test]
    fn snapshot_freezes_live_metrics() {
        let m = Metrics::new();
        m.requests.store(5, Ordering::Relaxed);
        m.dense_bytes.store(800, Ordering::Relaxed);
        m.stored_bytes.store(200, Ordering::Relaxed);
        m.record_latency_us(100);
        m.record_latency_us(100);
        let s = MetricsSnapshot::from_metrics(&m);
        assert_eq!(s.requests, 5);
        assert_eq!(s.responses, 2);
        assert_eq!(s.latency_buckets.iter().sum::<u64>(), 2);
        assert_eq!(
            s.latency_percentile_us(0.5),
            m.latency_percentile_us(0.5)
        );
        assert!((s.reduction_pct() - m.reduction_pct()).abs() < 1e-9);
    }

    #[test]
    fn merge_sums_counters_and_buckets() {
        let mut a = snap(1);
        a.merge(&snap(2));
        assert_eq!(a.requests, 300);
        assert_eq!(a.shipped_spill_bytes, 555 * 3);
        assert_eq!(a.exec_threads, 2 * 3, "thread gauges sum across nodes");
        assert_eq!(a.shed_total(), 11 * 3, "shed counters sum class-wise");
        assert_eq!(a.deadline_miss, 2 * 3);
        assert_eq!(a.failed, 3);
        assert_eq!(a.latency_buckets[7], 30);
        assert_eq!(a.latency_buckets[17], 3);
        // Merged percentiles come from merged buckets: the p99 must
        // see the slow bucket.
        assert!(a.latency_percentile_us(0.99) >= 1 << 17);
        assert!(a.latency_percentile_us(0.5) <= 256);
        assert!((a.mean_batch() - 99.0 / 25.0).abs() < 1e-9);
    }

    #[test]
    fn cluster_stats_roundtrip() {
        let stats = ClusterStats {
            aggregate: snap(2),
            workers_total: 3,
            workers_alive: 2,
            routed: 123,
            retries: 4,
            rejected: 6,
            spill_frames_in: 9,
            spill_bytes_in: 555 * 2,
            shed_low: 3,
            shed_normal: 1,
            shed_high: 1,
            failed: 1,
            router_latency_buckets: vec![1; LATENCY_BUCKETS],
        };
        assert_eq!(stats.shed_total() + stats.failed, stats.rejected);
        let back = ClusterStats::parse(&stats.encode()).unwrap();
        assert_eq!(back, stats);
        let bytes = stats.encode();
        for cut in 0..bytes.len() {
            assert!(ClusterStats::parse(&bytes[..cut]).is_err());
        }
        assert!(stats.summary().contains("2/3 alive"), "{}", stats.summary());
        assert!(stats.summary().contains("p95="), "{}", stats.summary());
        assert!(
            stats.summary().contains("shed=3/1/1"),
            "{}",
            stats.summary()
        );
    }

    #[test]
    fn legacy_seven_counter_router_blocks_still_parse() {
        // A pre-admission-control router (7 counters in the second
        // block): parses with the appended shed/failed split at 0.
        let mut bytes = snap(1).encode();
        bytes.extend_from_slice(&7u16.to_le_bytes());
        bytes.extend_from_slice(&0u16.to_le_bytes());
        for v in 1u64..=7 {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let stats = ClusterStats::parse(&bytes).unwrap();
        assert_eq!(stats.workers_total, 1);
        assert_eq!(stats.spill_bytes_in, 7);
        assert_eq!(stats.shed_total(), 0);
        assert_eq!(stats.failed, 0);
        // Fewer than the original 7 is genuinely malformed.
        let mut short = snap(1).encode();
        short.extend_from_slice(&6u16.to_le_bytes());
        short.extend_from_slice(&0u16.to_le_bytes());
        for v in 1u64..=6 {
            short.extend_from_slice(&v.to_le_bytes());
        }
        assert!(ClusterStats::parse(&short).is_err());
    }
}
